"""Fused whole-detector MLP kernel: oracle equivalence, fused-vs-per-layer
parity at the real serving shapes, and the single-dispatch guarantee."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import layers as L
from repro.core import quantize, sequential
from repro.kernels import fused_mlp as fused_mlp_mod
from repro.kernels import ops
from repro.serving import StreamEngine
from repro.serving.streams import _dense_batched
from repro.sim import build_autoencoder, build_detector, fleet_readings
from repro.sim.detector import batched_forward

SCHEMES = ("REAL", "SINT", "INT", "DINT")


dense_stack = ops.dense_stack


def detector_params(scheme, seed=0):
    model = build_detector()
    params = model.init_params(jax.random.PRNGKey(seed))
    if scheme != "REAL":
        calib = [jax.random.normal(jax.random.PRNGKey(100 + i), (400,))
                 for i in range(4)]
        params = quantize.quantize_params(model, params, scheme,
                                          calibration=calib)
    return model, params


def per_layer_forward(x, stack, backend="ref"):
    """The engine's per-layer loop (one dispatch per Dense layer)."""
    for p, act in stack:
        x = _dense_batched(x, p, act, backend)
    return x


class TestFusedVsPerLayer:
    """Issue acceptance: bit-match (REAL) / within-epsilon (SINT/INT/DINT)
    at the detector's real batched-window shapes."""

    @pytest.mark.parametrize("m", (5, 16, 23))
    def test_real_bit_match(self, m):
        model, params = detector_params("REAL")
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(m), (m, 400))
        fused = ops.fused_forward(x, stack, backend="ref")
        per_layer = per_layer_forward(x, stack, backend="ref")
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(per_layer))

    @pytest.mark.parametrize("m", (5, 16, 23))
    @pytest.mark.parametrize("scheme", ("SINT", "INT", "DINT"))
    def test_quantized_within_epsilon(self, m, scheme):
        model, params = detector_params(scheme)
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(m), (m, 400))
        fused = ops.fused_forward(x, stack, backend="ref")
        per_layer = per_layer_forward(x, stack, backend="ref")
        np.testing.assert_allclose(np.asarray(fused), np.asarray(per_layer),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("m", (5, 16, 23))
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_pallas_kernel_matches_per_layer(self, m, scheme):
        """The actual Pallas kernel (interpret mode) against the per-layer
        oracle path, every scheme, fleet-sized M."""
        model, params = detector_params(scheme)
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(7 * m), (m, 400))
        fused = ops.fused_forward(x, stack, backend="pallas")
        per_layer = per_layer_forward(x, stack, backend="ref")
        np.testing.assert_allclose(np.asarray(fused), np.asarray(per_layer),
                                   rtol=1e-5, atol=1e-4)

    def test_dint_saturation_rail_parity(self):
        """Regression: int32's qmax is not f32-representable, so an integer
        round-trip at the DINT clip rail overflows (saturated positives
        flipped to -2^31).  Neither path may cast; they must agree — and
        keep the sign — when the activation grid saturates."""
        p = {"qw": jnp.full((8, 4), 5, jnp.int32),
             "w_scale": jnp.full((4,), 2e-9, jnp.float32),
             "x_scale": jnp.asarray(1e-9, jnp.float32),
             "b": jnp.zeros((4,), jnp.float32)}
        stack = [(p, "linear")]
        x = jnp.full((3, 8), 10.0)          # x / x_scale = 1e10 >> qmax
        per_layer = np.asarray(per_layer_forward(x, stack, backend="ref"))
        fused_ref = np.asarray(ops.fused_forward(x, stack, backend="ref"))
        fused_pl = np.asarray(ops.fused_forward(x, stack, backend="pallas"))
        assert (per_layer > 0).all(), "saturated positives flipped sign"
        np.testing.assert_array_equal(fused_ref, per_layer)
        np.testing.assert_allclose(fused_pl, per_layer, rtol=1e-6)

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_batched_forward_matches_vmapped_apply(self, scheme):
        """sim.detector.batched_forward (the fused evaluation path) against
        per-sample model.apply — f32 batched-vs-matvec reassociation only."""
        model, params = detector_params(scheme)
        x = jax.random.normal(jax.random.PRNGKey(3), (16, 400))
        got = batched_forward(model, params, x)
        want = jax.vmap(model.apply, (None, 0))(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-4)


def count_pallas_calls(jaxpr) -> int:
    """Pallas dispatches in a jaxpr, recursing through pjit/scan/etc."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for u in vs:
                if isinstance(u, jax.core.ClosedJaxpr):
                    n += count_pallas_calls(u.jaxpr)
                elif isinstance(u, jax.core.Jaxpr):
                    n += count_pallas_calls(u)
    return n


class TestSingleDispatch:
    """Issue acceptance: one verdict step of the all-Dense detector is a
    single fused Pallas dispatch (vs one per layer on the per-layer path)."""

    def test_fused_forward_is_one_dispatch(self):
        model, params = detector_params("SINT")
        stack = dense_stack(model, params)
        x = jnp.zeros((16, 400))
        fused = jax.make_jaxpr(
            lambda a: ops.fused_forward(a, stack, backend="pallas"))(x)
        assert count_pallas_calls(fused.jaxpr) == 1

    def test_per_layer_sint_is_four_dispatches(self):
        model, params = detector_params("SINT")
        stack = dense_stack(model, params)
        x = jnp.zeros((16, 400))
        per_layer = jax.make_jaxpr(
            lambda a: per_layer_forward(a, stack, backend="pallas"))(x)
        assert count_pallas_calls(per_layer.jaxpr) == len(stack) == 4

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_engine_verdict_step_is_one_dispatch(self, scheme):
        model, params = detector_params(scheme)
        eng = StreamEngine(model, params, n_streams=16, backend="pallas",
                           fused=True)
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((16, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 1

    def test_per_layer_engine_step_dispatch_count(self):
        model, params = detector_params("SINT")
        eng = StreamEngine(model, params, n_streams=16, backend="pallas",
                           fused=False)
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((16, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 4


def autoencoder_params(scheme, seed=0):
    """The 400-64-16-64-400 reconstruction detector, optionally quantized
    with input-range calibration."""
    model = build_autoencoder()
    params = model.init_params(jax.random.PRNGKey(seed))
    if scheme != "REAL":
        calib = [jax.random.normal(jax.random.PRNGKey(300 + i), (400,))
                 for i in range(4)]
        params = quantize.quantize_params(model, params, scheme,
                                          calibration=calib)
    return model, params


class TestKGriddedFirstLayer:
    """The K grid streams the first layer's input width through VMEM one
    (block_k, N1) slab at a time: parity across split factors, K widths not
    divisible by the slab, exact-at-budget stacks, and wide-input stacks
    the old whole-net-in-VMEM accounting rejected."""

    @pytest.mark.parametrize("scheme", SCHEMES)
    @pytest.mark.parametrize("block_k", (128, 256))
    @pytest.mark.parametrize("build", (detector_params, autoencoder_params))
    def test_kgrid_matches_oracle(self, scheme, block_k, build):
        model, params = build(scheme)
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(block_k), (9, 400))
        want = ops.fused_forward(x, stack, backend="ref")
        got = ops.fused_forward(x, stack, backend="pallas", block_k=block_k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_kgrid_int8_split_is_bit_exact(self):
        """int8 first layers accumulate split-K partials in an int32
        scratch — integer accumulation is associative, so any split factor
        bit-matches the unsplit kernel."""
        model, params = detector_params("SINT")
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 400))
        unsplit = ops.fused_forward(x, stack, backend="pallas")
        for block_k in (128, 256):
            split = ops.fused_forward(x, stack, backend="pallas",
                                      block_k=block_k)
            np.testing.assert_array_equal(np.asarray(split),
                                          np.asarray(unsplit))

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_k_not_divisible_by_grid_block(self, scheme):
        """block_k=384 over the 512-padded 400-wide input: K pads up to 768
        (zero x-lanes times zero weight rows), and parity holds."""
        model, params = autoencoder_params(scheme)
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(5), (7, 400))
        want = ops.fused_forward(x, stack, backend="ref")
        got = ops.fused_forward(x, stack, backend="pallas", block_k=384)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_kgrid_is_still_one_dispatch(self):
        model, params = detector_params("SINT")
        stack = dense_stack(model, params)
        x = jnp.zeros((16, 400))
        jaxpr = jax.make_jaxpr(
            lambda a: ops.fused_forward(a, stack, backend="pallas",
                                        block_k=128))(x)
        assert count_pallas_calls(jaxpr.jaxpr) == 1

    def test_widest_layer_exactly_at_budget_fits(self, monkeypatch):
        """The budget check is <=: a stack whose resident set is EXACTLY the
        VMEM budget fuses (and dispatches); one byte less and it falls back."""
        model, params = detector_params("SINT")
        stack = dense_stack(model, params)
        shapes, bk = ops._padded_shapes(stack, None)
        exact = fused_mlp_mod.fused_vmem_bytes(shapes, block_m=128,
                                               block_k=bk)
        monkeypatch.setattr(fused_mlp_mod, "VMEM_BUDGET_BYTES", exact)
        assert ops.can_fuse(stack)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 400))
        got = ops.fused_forward(x, stack, backend="pallas")
        np.testing.assert_allclose(
            np.asarray(got),
            np.asarray(ops.fused_forward(x, stack, backend="ref")),
            rtol=1e-5, atol=1e-4)
        monkeypatch.setattr(fused_mlp_mod, "VMEM_BUDGET_BYTES", exact - 1)
        assert not ops.can_fuse(stack)
        with pytest.raises(ValueError):
            ops.fused_forward(x, stack, backend="pallas")

    def test_wide_input_fuses_only_via_kgrid(self):
        """An 8192-wide first layer (16 MB f32 — over budget in full) fuses
        now: the K grid keeps one 512-row slab resident.  The old
        whole-net accounting would have rejected it."""
        model = sequential([L.Input(),
                            L.Dense(units=512, activation="relu"),
                            L.Dense(units=2, activation="linear")], (8192,))
        params = model.init_params(jax.random.PRNGKey(0))
        stack = dense_stack(model, params)
        w0 = stack[0][0]["w"]
        assert w0.size * w0.dtype.itemsize > fused_mlp_mod.VMEM_BUDGET_BYTES
        assert ops.can_fuse(stack)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 8192)) * 0.1
        got = ops.fused_forward(x, stack, backend="pallas")
        want = ops.fused_forward(x, stack, backend="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_wide_later_layer_still_falls_back(self):
        """The K grid only streams layer 0 — a later layer past the budget
        keeps the stack on the per-layer path (the widest-layer check)."""
        model = sequential([L.Input(),
                            L.Dense(units=2048, activation="relu"),
                            L.Dense(units=2048, activation="linear")], (128,))
        params = model.init_params(jax.random.PRNGKey(0))
        stack = dense_stack(model, params)
        assert not ops.can_fuse(stack)    # layer 1: 2048x2048 f32 = 16 MB


class TestSingleDispatchAutoencoder:
    """Issue acceptance: the 400-64-16-64-400 autoencoder shape runs as ONE
    fused Pallas dispatch — the 400-wide decoder output rides the same
    kernel as the classifier head."""

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_fused_forward_is_one_dispatch(self, scheme):
        model, params = autoencoder_params(scheme)
        stack = dense_stack(model, params)
        x = jnp.zeros((16, 400))
        jaxpr = jax.make_jaxpr(
            lambda a: ops.fused_forward(a, stack, backend="pallas"))(x)
        assert count_pallas_calls(jaxpr.jaxpr) == 1

    def test_autoencoder_pallas_matches_per_layer(self):
        model, params = autoencoder_params("SINT")
        stack = dense_stack(model, params)
        x = jax.random.normal(jax.random.PRNGKey(9), (23, 400))
        fused = ops.fused_forward(x, stack, backend="pallas")
        per_layer = per_layer_forward(x, stack, backend="ref")
        np.testing.assert_allclose(np.asarray(fused), np.asarray(per_layer),
                                   rtol=1e-5, atol=1e-4)


def small_detector(scheme, seed):
    """A detector-shaped all-Dense stack over a 4-reading window (2 features
    -> 8 inputs), cheap enough for property-test volumes."""
    model = sequential([L.Input(),
                        L.Dense(units=6, activation="relu"),
                        L.Dense(units=2, activation="linear")], (8,))
    params = model.init_params(jax.random.PRNGKey(seed))
    if scheme != "REAL":
        calib = [jax.random.normal(jax.random.PRNGKey(200 + i), (8,)) * 2.0
                 for i in range(4)]
        params = quantize.quantize_params(model, params, scheme,
                                          calibration=calib)
    return model, params


def scenario_readings(n_streams, n_cycles, seed):
    return fleet_readings(n_streams, n_cycles, seed=seed)


def drive_pair(model, params, readings, *, window, stride):
    """Run fused and per-layer engines over the same readings; return both
    verdict streams and final logits."""
    results = {}
    for fused in (True, False):
        eng = StreamEngine(model, params, n_streams=readings.shape[1],
                           n_features=2, window=window, stride=stride,
                           fused=fused)
        verdicts = []
        for c in range(readings.shape[0]):
            verdicts.extend(eng.ingest(readings[c]))
        results[fused] = (verdicts, eng.last_logits)
    return results


class TestEngineFusedVsPerLayer:
    @settings(max_examples=6, deadline=None)
    @given(scheme=st.sampled_from(SCHEMES), seed=st.integers(0, 2**20),
           extra=st.integers(8, 40))
    def test_identical_verdicts_over_wraparound_run(self, scheme, seed,
                                                    extra):
        """Fused and per-layer engines emit identical verdicts over a
        scenario run long enough to wrap the ring several times."""
        model, params = small_detector(scheme, seed % 7)
        window, stride = 4, 3
        readings = scenario_readings(3, window + extra, seed)
        results = drive_pair(model, params, readings, window=window,
                             stride=stride)
        vf, lf = results[True]
        vp, lp = results[False]
        # extra >= 8 guarantees count > 2*window, i.e. the ring wrapped.
        assert len(vf) == len(vp) >= 3 * 3
        assert [(v.stream, v.cycle, v.pred) for v in vf] == \
               [(v.stream, v.cycle, v.pred) for v in vp]
        np.testing.assert_allclose([v.prob for v in vf],
                                   [v.prob for v in vp], rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(lf, lp, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_full_detector_wraparound_regression(self, scheme):
        """Pinned full-size run: 430 cycles wraps the 200-reading ring and
        the two paths must agree verdict for verdict."""
        model, params = detector_params(scheme, seed=1)
        readings = scenario_readings(3, 430, seed=11)
        results = drive_pair(model, params, readings, window=200, stride=10)
        vf, lf = results[True]
        vp, lp = results[False]
        assert [(v.stream, v.cycle, v.pred) for v in vf] == \
               [(v.stream, v.cycle, v.pred) for v in vp]
        np.testing.assert_allclose(lf, lp, rtol=1e-6, atol=1e-6)


class TestFusedGuards:
    def test_softmax_head_not_fusable(self):
        model = sequential([L.Input(),
                            L.Dense(units=4, activation="relu"),
                            L.Dense(units=2, activation="softmax")], (8,))
        params = model.init_params(jax.random.PRNGKey(0))
        stack = dense_stack(model, params)
        assert not ops.can_fuse(stack)
        with pytest.raises(ValueError):
            ops.fused_forward(jnp.zeros((4, 8)), stack)
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2, n_features=2, window=4,
                         fused=True)
        # auto mode falls back to the per-layer loop and still serves
        eng = StreamEngine(model, params, n_streams=2, n_features=2, window=4)
        assert not eng.fused
        for c in range(4):
            eng.ingest(np.zeros((2, 2), np.float32))
        assert eng.last_logits is not None

    def test_fused_flag_default_on_detector(self):
        model, params = detector_params("REAL")
        assert StreamEngine(model, params, n_streams=2).fused
        assert not StreamEngine(model, params, n_streams=2,
                                fused=False).fused

    def test_oversized_stack_falls_back_to_per_layer(self):
        """A fusable-shaped stack past the VMEM budget must not auto-fuse
        (the kernel can't keep it resident) — the engine serves it through
        the per-layer loop instead of failing at dispatch time."""
        model = sequential([L.Input(),
                            L.Dense(units=2048, activation="relu"),
                            L.Dense(units=2048, activation="linear")], (2048,))
        params = model.init_params(jax.random.PRNGKey(0))
        stack = dense_stack(model, params)
        assert not ops.can_fuse(stack)        # 2 x 16 MB f32 > 12 MB budget
        eng = StreamEngine(model, params, n_streams=2, n_features=2,
                           window=1024)
        assert not eng.fused
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2, n_features=2,
                         window=1024, fused=True)

    def test_non_dense_model_not_fused(self):
        model = sequential([L.Input(),
                            L.Dense(units=4, activation="relu"),
                            L.Activation(fn="tanh"),
                            L.Dense(units=4, activation="linear")], (4,))
        params = model.init_params(jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2, n_features=2, window=2,
                         fused=True)
