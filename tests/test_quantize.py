"""Quantization (§6.1): Table 2 byte-exact, op counts, error bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layers as L, quantize, sequential

from _hyp import given, settings, st  # hypothesis or fallback shim


class TestTable2:
    """Exact reproduction of the paper's Table 2 (512-in/512-out layer)."""

    def test_sint(self):
        r = quantize.memory_report(512, 512, "SINT")
        assert r == {"weights": 262144, "biases": 2048,
                     "scaling_factors": 2052, "total": 266244}

    def test_int(self):
        assert quantize.memory_report(512, 512, "INT")["total"] == 528388

    def test_dint(self):
        assert quantize.memory_report(512, 512, "DINT")["total"] == 1052676

    def test_real(self):
        r = quantize.memory_report(512, 512, "REAL")
        assert r["total"] == 1050624 and r["scaling_factors"] == 0

    def test_compression_ratios(self):
        # §6.1: SINT −74.66 %, INT −49.71 % vs REAL
        real = quantize.memory_report(512, 512, "REAL")["total"]
        sint = quantize.memory_report(512, 512, "SINT")["total"]
        intq = quantize.memory_report(512, 512, "INT")["total"]
        assert abs((1 - sint / real) * 100 - 74.66) < 0.05
        assert abs((1 - intq / real) * 100 - 49.71) < 0.05


class TestOpCounts:
    """§6.1: quantized inference for the 512x512 layer needs 262,144 int
    mults + 262,144 int adds but only ~1024 float mults + 512 float adds."""

    def test_float(self):
        c = quantize.op_counts(512, 512, quantized=False)
        assert c["float_mul"] == 262_144
        assert c["float_add"] == 262_656   # accumulate + bias
        assert c["int_mul"] == 0

    def test_quantized(self):
        c = quantize.op_counts(512, 512, quantized=True)
        assert c["int_mul"] == 262_144 and c["int_add"] == 262_144
        assert c["float_mul"] == 1024 and c["float_add"] == 512


class TestQuantizeTensor:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(["SINT", "INT", "DINT"]),
           st.booleans())
    def test_property_error_bound(self, seed, scheme, per_channel):
        """|w - dequantize(quantize(w))| <= scale/2 element-wise."""
        w = jax.random.normal(jax.random.PRNGKey(seed % 2**32), (32, 16)) * 3.0
        qt = quantize.quantize_tensor(w, scheme, per_channel=per_channel)
        err = jnp.abs(qt.dequantize() - w)
        bound = quantize.quantization_error_bound(qt.scale)
        assert bool(jnp.all(err <= bound + 1e-6))

    def test_per_channel_tighter_than_per_tensor(self):
        w = jnp.concatenate([jnp.ones((16, 8)) * 0.01, jnp.ones((16, 8)) * 10.0],
                            axis=1)
        pc = quantize.quantize_tensor(w, "SINT", per_channel=True)
        pt = quantize.quantize_tensor(w, "SINT", per_channel=False)
        err_pc = float(jnp.abs(pc.dequantize() - w).max())
        err_pt = float(jnp.abs(pt.dequantize() - w).max())
        assert err_pc < err_pt

    def test_int_dtypes(self):
        w = jnp.ones((4, 4))
        assert quantize.quantize_tensor(w, "SINT").q.dtype == jnp.int8
        assert quantize.quantize_tensor(w, "INT").q.dtype == jnp.int16
        assert quantize.quantize_tensor(w, "DINT").q.dtype == jnp.int32


class TestQuantizedInference:
    def _model(self, key):
        m = sequential([L.Input(),
                        L.Dense(units=64, activation="relu"),
                        L.Dense(units=8, activation="linear")], (32,))
        return m, m.init_params(key)

    def test_quantized_output_close(self, key):
        m, p = self._model(key)
        x = jax.random.normal(jax.random.PRNGKey(1), (32,))
        ref = m.apply(p, x)
        for scheme, tol in (("SINT", 0.1), ("INT", 1e-3), ("DINT", 1e-4)):
            qp = quantize.quantize_params(m, p, scheme, calibration=[x])
            out = m.apply(qp, x)
            assert float(jnp.abs(out - ref).max()) < tol, scheme

    def test_wider_ints_monotonically_better(self, key):
        m, p = self._model(key)
        xs = [jax.random.normal(jax.random.PRNGKey(i), (32,)) for i in range(4)]
        errs = {}
        for scheme in ("SINT", "INT", "DINT"):
            qp = quantize.quantize_params(m, p, scheme, calibration=xs)
            errs[scheme] = max(
                float(jnp.abs(m.apply(qp, x) - m.apply(p, x)).max()) for x in xs)
        assert errs["DINT"] <= errs["INT"] <= errs["SINT"]

    def test_only_nodes_subset(self, key):
        """§6.1 isolates a single layer for quantization."""
        m, p = self._model(key)
        qp = quantize.quantize_params(m, p, "SINT", only_nodes=[1])
        assert "qw" in qp[1] and "qw" not in qp[2]
        assert "w" in qp[2]


class TestModelLinearQuantized:
    """models.common.linear must follow the same §6.1 semantics as
    layers._quantized_matvec: symmetric clip, int8 native accumulation,
    INT/DINT emulated in f32 (int16/int32 products overflow the int32
    accumulator — the old path produced wrapped garbage at 512-wide dots)."""

    @pytest.mark.parametrize("scheme", ("SINT", "INT", "DINT"))
    def test_matches_dequantized_reference(self, scheme):
        from repro.models import common
        p = common.linear_init(jax.random.PRNGKey(0), 512, 64, bias=True,
                               quant=scheme)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 512), jnp.float32)
        y = np.asarray(common.linear(p, x))
        qmax = float(jnp.iinfo(p["qw"].dtype).max)
        xq = jnp.clip(jnp.round(x / p["x_scale"]), -qmax, qmax)
        want = (xq * p["x_scale"]) @ (
            p["qw"].astype(jnp.float32) * p["w_scale"]) + p["b"]
        assert np.isfinite(y).all()
        np.testing.assert_allclose(y, np.asarray(want), rtol=1e-3, atol=1e-3)
