"""Subprocess smoke tests for the example CLIs.

Each example is a user-facing entry point; these prove they launch, run
their quick paths end to end, and exit 0 — with real subprocesses, the way
CI and users invoke them.  Budgets are the ``--smoke`` tiers the examples
expose for exactly this purpose.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = os.path.join(REPO, "examples")


def _run(script, *argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, script), *argv],
        capture_output=True, text=True, timeout=timeout, env=env)


def test_detect_fleet_list():
    res = _run("detect_fleet.py", "--list")
    assert res.returncode == 0, res.stderr
    assert "baseline" in res.stdout
    assert "drift-then-spoof" in res.stdout


def test_detect_fleet_mixed_smoke():
    res = _run("detect_fleet.py", "--mixed", "--smoke")
    assert res.returncode == 0, res.stderr
    assert "per-group verdicts" in res.stdout
    assert "serve stats" in res.stdout


@pytest.mark.parametrize("detector,quant", [("mlp", "SINT"),
                                            ("ae", "REAL")])
def test_export_st_smoke(tmp_path, detector, quant):
    res = _run("export_st.py", "--smoke", "--detector", detector,
               "--quant", quant, "--out-dir", str(tmp_path))
    assert res.returncode == 0, res.stderr
    assert "OK: exported ST serves identically" in res.stdout
    st_file = tmp_path / f"{detector}_{quant.lower()}.st"
    assert st_file.exists()
    text = st_file.read_text()
    assert text.startswith("FUNCTION_BLOCK")
    assert text.rstrip().endswith("END_FUNCTION_BLOCK")


def test_export_st_smoke_reports_contract():
    res = _run("export_st.py", "--smoke", "--detector", "ae", "--quant",
               "SINT", "--out-dir", "/tmp/st-smoke-out")
    assert res.returncode == 0, res.stderr
    assert "bit-exact (SINT) contract" in res.stdout
    assert "verdict parity   : 108/108" in res.stdout
