"""Serving engine + cyclic (multipart) decoding for big models."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serving import CyclicDecoder, Engine, Request


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen3_8b").reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


class TestEngine:
    def test_wave_serves_all(self, dense_setup):
        cfg, api, params = dense_setup
        eng = Engine(api, params, batch_slots=2, cache_len=64)
        reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i,
                        max_new_tokens=6) for i in range(5)]
        done = eng.serve(reqs)
        assert sorted(c.uid for c in done) == [0, 1, 2, 3, 4]
        assert all(len(c.tokens) == 6 for c in done)

    def test_greedy_deterministic(self, dense_setup):
        cfg, api, params = dense_setup
        eng = Engine(api, params, batch_slots=1, cache_len=64)
        r = Request(uid=0, prompt=np.arange(5, dtype=np.int32), max_new_tokens=8)
        a = eng.serve([r])[0].tokens
        b = eng.serve([r])[0].tokens
        np.testing.assert_array_equal(a, b)

    def test_engine_matches_manual_decode(self, dense_setup):
        cfg, api, params = dense_setup
        prompt = np.arange(6, dtype=np.int32)
        eng = Engine(api, params, batch_slots=1, cache_len=64)
        got = eng.serve([Request(uid=0, prompt=prompt, max_new_tokens=5)])[0].tokens

        cache, logits = api.prefill(params, {"tokens": jnp.asarray(prompt[None])}, 64)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        want = [int(cur[0])]
        pos = len(prompt)
        for _ in range(4):
            cache, lg = api.decode(params, cache, {"tokens": cur[:, None]},
                                   jnp.int32(pos))
            cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
            want.append(int(cur[0]))
            pos += 1
        np.testing.assert_array_equal(got, np.asarray(want))


class TestPerRequestSampling:
    def test_mixed_temperatures_in_one_wave(self, dense_setup):
        """Regression: temperature used to be read from reqs[0] only, so a
        greedy and a sampled request in one wave both decoded greedily."""
        cfg, api, params = dense_setup
        eng = Engine(api, params, batch_slots=2, cache_len=64, seed=0)
        prompt = np.arange(5, dtype=np.int32)
        done = eng.serve([
            Request(uid=0, prompt=prompt, max_new_tokens=8, temperature=0.0),
            Request(uid=1, prompt=prompt, max_new_tokens=8, temperature=5.0),
        ])
        got = {c.uid: c.tokens for c in done}
        # greedy slot is unaffected by its sampled neighbour...
        want = Engine(api, params, batch_slots=1, cache_len=64).serve(
            [Request(uid=0, prompt=prompt, max_new_tokens=8)])[0].tokens
        np.testing.assert_array_equal(got[0], want)
        # ...and the hot slot actually sampled (identical prompts diverge)
        assert not np.array_equal(got[1], got[0])

    def test_waves_use_fresh_prng(self, dense_setup):
        """Regression: the PRNG key was hardcoded per wave, so repeated waves
        replayed identical samples."""
        cfg, api, params = dense_setup
        eng = Engine(api, params, batch_slots=1, cache_len=64, seed=0)
        r = Request(uid=0, prompt=np.arange(5, dtype=np.int32),
                    max_new_tokens=16, temperature=5.0)
        a = eng.serve([r])[0].tokens
        b = eng.serve([r])[0].tokens
        assert not np.array_equal(a, b)


class TestCyclicDecoder:
    @pytest.mark.parametrize("n_segments", [1, 2])
    def test_multipart_decode_matches_plain(self, dense_setup, n_segments):
        cfg, api, params = dense_setup
        prompt = jnp.asarray(np.arange(5, dtype=np.int32)[None])
        cache, logits = api.prefill(params, {"tokens": prompt}, 64)
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

        cd = CyclicDecoder(cfg, params, n_segments=n_segments, batch=1,
                           cache_len=64)
        toks, _, stats = cd.decode_tokens(cache, first, 5, 5)
        assert stats.cycles_per_token == n_segments

        cache, _ = api.prefill(params, {"tokens": prompt}, 64)
        cur = first[:, None]
        want = []
        for i in range(5):
            cache, lg = api.decode(params, cache, {"tokens": cur},
                                   jnp.int32(5 + i))
            cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
            want.append(int(cur[0, 0]))
        assert toks == want

    def test_ssm_cyclic(self):
        cfg = get_config("mamba2_370m").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        prompt = jnp.asarray(np.arange(5, dtype=np.int32)[None])
        cache, logits = api.prefill(params, {"tokens": prompt}, 64)
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        cd = CyclicDecoder(cfg, params, n_segments=2, batch=1, cache_len=64)
        toks, _, stats = cd.decode_tokens(cache, first, 5, 4)
        assert len(toks) == 4 and stats.cycles_per_token == 2

    def test_control_task_runs_every_cycle(self, dense_setup):
        cfg, api, params = dense_setup
        prompt = jnp.asarray(np.arange(5, dtype=np.int32)[None])
        cache, logits = api.prefill(params, {"tokens": prompt}, 64)
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        cd = CyclicDecoder(cfg, params, n_segments=2, batch=1, cache_len=64)
        calls = []
        cd.decode_tokens(cache, first, 5, 3, control_task=lambda: calls.append(1))
        assert len(calls) == 3 * 2   # tokens x segments
