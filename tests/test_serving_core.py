"""The unified serving core: one implementation, shared guards.

``StreamEngine`` and ``GroupedStreamEngine`` are thin façades over
``ServingCore`` — ingest/run/warmup/flush and the span/eff_pos/pad
machinery exist exactly once.  This suite pins the structural claim and
the guards both engines must now share word-for-word.
"""

import numpy as np
import pytest

from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine
from repro.serving.core import ServingCore
from test_fused import small_detector
from test_streams import identity_probe


def stream_engine(**kw):
    model, params = small_detector("REAL", seed=0)
    args = dict(n_streams=3, n_features=2, window=4, stride=3, shard=False)
    args.update(kw)
    return StreamEngine(model, params, **args)


def grouped_engine(**kw):
    m1, p1 = small_detector("REAL", seed=0)
    m2, p2 = small_detector("SINT", seed=1)
    args = dict(n_features=2, stride=3, shard=False)
    args.update(kw)
    return GroupedStreamEngine(
        [ModelGroup("a", m1, p1, 2), ModelGroup("b", m2, p2, 1)], **args)


class _Stream:
    def __init__(self):
        self.rng = np.random.default_rng(0)

    def step(self):
        s = self

        class R:
            tb0_meas = float(s.rng.normal())
            wd_meas = float(s.rng.normal())

        return R()


class TestSingleImplementation:
    """Both engines execute the core's methods, not copies of them."""

    @pytest.mark.parametrize("method",
                             ("ingest", "run", "warmup", "flush",
                              "_finalize", "_get_step", "_schedule_keys"))
    def test_engines_share_core_methods(self, method):
        assert getattr(StreamEngine, method) is getattr(ServingCore, method)
        assert getattr(GroupedStreamEngine, method) is \
            getattr(ServingCore, method)

    def test_facades_are_core_subclasses(self):
        assert issubclass(StreamEngine, ServingCore)
        assert issubclass(GroupedStreamEngine, ServingCore)


class TestSharedGuards:
    @pytest.mark.parametrize("make", (stream_engine, grouped_engine))
    def test_run_fleet_size_guard(self, make):
        eng = make()
        with pytest.raises(ValueError, match="fleet size 1 != engine "
                                             "streams 3"):
            eng.run([_Stream()], 5)

    @pytest.mark.parametrize("make", (stream_engine, grouped_engine))
    def test_run_feature_width_guard(self, make):
        """run() reads the MSF 2-feature layout; other widths must point
        users at ingest() — identically for both engines."""
        if make is stream_engine:
            model, params = identity_probe(4, 3)
            eng = StreamEngine(model, params, n_streams=2, n_features=3,
                               window=4, stride=3, shard=False,
                               norm_mean=(0.0,) * 3, norm_std=(1.0,) * 3)
        else:
            model, params = identity_probe(4, 3)
            eng = GroupedStreamEngine(
                [ModelGroup("g", model, params, 2)], n_features=3, stride=3,
                shard=False, norm_mean=(0.0,) * 3, norm_std=(1.0,) * 3)
        with pytest.raises(ValueError, match="use ingest\\(\\) directly"):
            eng.run([_Stream(), _Stream()], 5)

    @pytest.mark.parametrize("make", (stream_engine, grouped_engine))
    def test_fresh_stats_latency_percentile_raises(self, make):
        """A just-built engine has no latencies: latency_p must raise, not
        report a perfect 0 ms tail."""
        eng = make()
        with pytest.raises(ValueError, match="empty latency reservoir"):
            eng.stats.latency_p(99)

    @pytest.mark.parametrize("make", (stream_engine, grouped_engine))
    def test_latency_percentile_after_service(self, make):
        eng = make()
        rng = np.random.default_rng(5)
        for c in range(6):
            eng.ingest(rng.normal(size=(3, 2)).astype(np.float32))
        assert eng.stats.latency_p(99) > 0.0
