"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prune
from repro.kernels import ops, ref
from repro.kernels.qmatmul import qmatmul
from repro.kernels.sparse_matmul import sparse_matmul
from repro.kernels.ssd_scan import ssd_scan


class TestQMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                       (128, 256, 512)])
    @pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
    def test_shapes_dtypes(self, m, k, n, dtype):
        info = jnp.iinfo(dtype)
        lim = min(int(info.max), 127)
        xq = jax.random.randint(jax.random.PRNGKey(0), (m, k), -lim, lim, dtype)
        wq = jax.random.randint(jax.random.PRNGKey(1), (k, n), -lim, lim, dtype)
        scale = jax.random.uniform(jax.random.PRNGKey(2), (n,), jnp.float32,
                                   1e-3, 1e-2)
        bias = jax.random.normal(jax.random.PRNGKey(3), (n,))
        out = qmatmul(xq, wq, scale, bias, interpret=True)
        want = ref.qmatmul_ref(xq, wq, scale, bias)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_int_accumulation_exact(self):
        """The integer part must be bit-exact (pure int32 accumulate)."""
        xq = jax.random.randint(jax.random.PRNGKey(0), (128, 384), -127, 127,
                                jnp.int8)
        wq = jax.random.randint(jax.random.PRNGKey(1), (384, 128), -127, 127,
                                jnp.int8)
        one = jnp.ones((128,), jnp.float32)
        out = qmatmul(xq, wq, one, None, interpret=True)
        want = ref.qmatmul_ref(xq, wq, one, None)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_block_shapes(self):
        xq = jax.random.randint(jax.random.PRNGKey(0), (256, 256), -127, 127,
                                jnp.int8)
        wq = jax.random.randint(jax.random.PRNGKey(1), (256, 256), -127, 127,
                                jnp.int8)
        s = jnp.full((256,), 1e-2, jnp.float32)
        ref_out = ref.qmatmul_ref(xq, wq, s, None)
        for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 256, 256)]:
            out = qmatmul(xq, wq, s, None, block_m=bm, block_n=bn,
                          block_k=bk, interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                       rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("m", [5, 16, 23])
    @pytest.mark.parametrize("k,n", [(400, 64), (64, 32), (32, 16), (16, 2)])
    def test_detector_batched_window_shapes(self, m, k, n):
        """The detection service's real shapes: M = ready streams (not a
        multiple of block_m), K/N = the 400-64-32-16-2 layer dims."""
        xq = jax.random.randint(jax.random.PRNGKey(m), (m, k), -127, 127,
                                jnp.int8)
        wq = jax.random.randint(jax.random.PRNGKey(n), (k, n), -127, 127,
                                jnp.int8)
        scale = jax.random.uniform(jax.random.PRNGKey(2), (n,), jnp.float32,
                                   1e-3, 1e-2)
        bias = jax.random.normal(jax.random.PRNGKey(3), (n,))
        out = ops.quantized_matmul(xq, wq, scale, bias, backend="pallas")
        want = ref.qmatmul_ref(xq, wq, scale, bias)
        assert out.shape == (m, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_wrapper_padding(self):
        """ops.quantized_matmul pads ragged shapes to kernel blocks."""
        xq = jax.random.randint(jax.random.PRNGKey(0), (5, 200), -127, 127,
                                jnp.int8)
        wq = jax.random.randint(jax.random.PRNGKey(1), (200, 70), -127, 127,
                                jnp.int8)
        s = jnp.full((70,), 1e-2, jnp.float32)
        out = ops.quantized_matmul(xq, wq, s, backend="pallas")
        want = ref.qmatmul_ref(xq, wq, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("bias", [
        0.75,                                        # python scalar
        np.float64(0.75),                            # 0-d f64 scalar
        np.linspace(-1, 1, 70).astype(np.float64),   # (n,) f64 vector
        np.float32(0.75) * np.ones((70,), np.float32),
    ], ids=["py-scalar", "f64-scalar", "f64-vector", "f32-vector"])
    def test_bias_normalized_like_ref(self, bias):
        """Regression: the wrapper must normalize bias to a f32 (n,) vector
        before padding — ref.qmatmul_ref broadcasts whatever it gets, and
        scalar / f64 biases used to crash or diverge on the pallas path."""
        xq = jax.random.randint(jax.random.PRNGKey(0), (5, 200), -127, 127,
                                jnp.int8)
        wq = jax.random.randint(jax.random.PRNGKey(1), (200, 70), -127, 127,
                                jnp.int8)
        s = jnp.full((70,), 1e-2, jnp.float32)
        out = ops.quantized_matmul(xq, wq, s, bias, backend="pallas")
        want = ref.qmatmul_ref(xq, wq, s, bias)
        assert out.dtype == jnp.float32 and out.shape == (5, 70)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestSparseMatmul:
    @pytest.mark.parametrize("sparsity", [0.0, 0.3, 0.6, 0.9])
    def test_sparsity_sweep(self, sparsity):
        w = jax.random.normal(jax.random.PRNGKey(0), (512, 768))
        wp = prune.block_magnitude_prune(w, sparsity, (128, 128))
        bs = prune.compress_blocks(wp, (128, 128))
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 512))
        out = sparse_matmul(x, bs, interpret=True)
        want = ref.sparse_matmul_ref(x, bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("block", [(64, 64), (128, 128)])
    def test_block_sizes(self, block):
        w = jax.random.normal(jax.random.PRNGKey(2), (256, 256))
        wp = prune.block_magnitude_prune(w, 0.5, block)
        bs = prune.compress_blocks(wp, block)
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 256))
        out = sparse_matmul(x, bs, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.sparse_matmul_ref(x, bs)),
                                   rtol=1e-4, atol=1e-4)

    def test_flop_skip_accounting(self):
        """The kernel grid is exactly nnz_blocks — pruned blocks cost zero."""
        w = jax.random.normal(jax.random.PRNGKey(4), (512, 512))
        wp = prune.block_magnitude_prune(w, 0.75, (128, 128))
        bs = prune.compress_blocks(wp, (128, 128))
        assert bs.nnz_blocks == 4   # of 16


class TestSSD:
    @pytest.mark.parametrize("t,h,p,n,g", [(128, 2, 32, 16, 1),
                                           (256, 4, 64, 32, 2),
                                           (64, 8, 16, 64, 8)])
    def test_vs_sequential_ref(self, t, h, p, n, g):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (1, t, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, t, h))) * 0.2
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        b = jax.random.normal(ks[3], (1, t, g, n)) * 0.3
        c = jax.random.normal(ks[4], (1, t, g, n)) * 0.3
        want = ops.ssd(x, dt, a, b, c, backend="ref")
        got = ops.ssd(x, dt, a, b, c, backend="pallas", chunk=min(64, t))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("chunk", [16, 32, 128])
    def test_chunk_invariance(self, chunk):
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        t, h, p, n = 128, 2, 16, 8
        x = jax.random.normal(ks[0], (1, t, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, t, h))) * 0.2
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        b = jax.random.normal(ks[3], (1, t, 1, n)) * 0.3
        c = jax.random.normal(ks[4], (1, t, 1, n)) * 0.3
        want = ops.ssd(x, dt, a, b, c, backend="ref")
        got = ops.ssd(x, dt, a, b, c, backend="pallas", chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_chunked_oracle_matches_sequential(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        t, h, p, n = 256, 4, 32, 16
        x = jax.random.normal(ks[0], (2, t, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (2, t, h))) * 0.3
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        b = jax.random.normal(ks[3], (2, t, 2, n)) * 0.3
        c = jax.random.normal(ks[4], (2, t, 2, n)) * 0.3
        seq = ops.ssd(x, dt, a, b, c, backend="ref")
        chk = ops.ssd(x, dt, a, b, c, backend="chunked")
        np.testing.assert_allclose(np.asarray(chk), np.asarray(seq),
                                   rtol=2e-4, atol=2e-5)

    def test_decode_step_matches_scan_tail(self):
        """ssd_update_ref stepping must agree with the full scan."""
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        t, h, p, n = 16, 2, 8, 4
        x = jax.random.normal(ks[0], (t, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (t, h))) * 0.3
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        b = jax.random.normal(ks[3], (t, h, n)) * 0.3
        c = jax.random.normal(ks[4], (t, h, n)) * 0.3
        full = ref.ssd_scan_ref(x, dt, a, b, c)
        state = jnp.zeros((h, p, n))
        for i in range(t):
            state, y = ref.ssd_update_ref(state, x[i], dt[i], a, b[i], c[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[-1]),
                                   rtol=1e-5, atol=1e-6)
