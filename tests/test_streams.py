"""StreamEngine: ring-buffer windowing, batched verdicts, fleet e2e (§7)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import layers as L
from repro.core import quantize, sequential
from repro.serving import LatencyReservoir, StreamEngine
from repro.sim import build_detector, build_fleet


def identity_probe(window: int, n_features: int):
    """A model whose logits ARE the flattened window: Dense with W=I, b=0.

    Lets tests observe the engine's window contents through the real jitted
    step (ring write + modular unroll + forward)."""
    size = window * n_features
    model = sequential([L.Input(), L.Dense(units=size, activation="linear")],
                       (size,))
    params = model.init_params(jax.random.PRNGKey(0))
    (uid,) = [n.uid for n in model.graph.nodes
              if isinstance(n.layer, L.Dense)]
    params[uid]["w"] = jnp.eye(size, dtype=jnp.float32)
    params[uid]["b"] = jnp.zeros((size,), jnp.float32)
    return model, params


def drive(engine, readings):
    """Feed (C, S, F) readings; returns [(cycle, logits)] per verdict batch."""
    out = []
    for c in range(readings.shape[0]):
        if engine.ingest(readings[c]):
            out.append((c, engine.last_logits.copy()))
    return out


class TestWindowing:
    @settings(max_examples=15, deadline=None)
    @given(window=st.integers(3, 10), stride=st.integers(1, 5),
           extra=st.integers(0, 25))
    def test_windows_equal_naive_slicing(self, window, stride, extra):
        """For arbitrary lengths/strides the engine's window contents equal
        naive slicing of the raw stream — including ring wraparound (extra >
        window wraps the ring several times)."""
        n_streams, n_features = 3, 2
        model, params = identity_probe(window, n_features)
        eng = StreamEngine(model, params, n_streams=n_streams,
                           n_features=n_features, window=window, stride=stride,
                           norm_mean=(0.0,) * n_features,
                           norm_std=(1.0,) * n_features)
        n_cycles = window + extra
        rng = np.random.default_rng(window * 100 + stride * 10 + extra)
        readings = rng.normal(size=(n_cycles, n_streams, n_features)) \
            .astype(np.float32)
        batches = drive(eng, readings)
        expected_batches = (n_cycles - window) // stride + 1
        assert len(batches) == expected_batches
        for cycle, logits in batches:
            want = readings[cycle - window + 1:cycle + 1]      # (W, S, F)
            want = want.transpose(1, 0, 2).reshape(n_streams, -1)
            np.testing.assert_allclose(logits, want, rtol=0, atol=0)

    @settings(max_examples=10, deadline=None)
    @given(window=st.integers(2, 8), stride=st.integers(1, 4))
    def test_no_verdicts_before_first_window(self, window, stride):
        model, params = identity_probe(window, 1)
        eng = StreamEngine(model, params, n_streams=2, n_features=1,
                           window=window, stride=stride,
                           norm_mean=(0.0,), norm_std=(1.0,))
        for c in range(window - 1):
            assert eng.ingest(np.zeros((2, 1))) == []
        assert len(eng.ingest(np.zeros((2, 1)))) == 2

    def test_wraparound_regression(self):
        """Pinned case: stride coprime with window, ring wraps twice."""
        window, stride = 5, 3
        model, params = identity_probe(window, 2)
        eng = StreamEngine(model, params, n_streams=1, n_features=2,
                           window=window, stride=stride,
                           norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0))
        readings = np.arange(13 * 2, dtype=np.float32).reshape(13, 1, 2)
        batches = drive(eng, readings)
        assert [c for c, _ in batches] == [4, 7, 10]
        for cycle, logits in batches:
            want = readings[cycle - window + 1:cycle + 1, 0].reshape(1, -1)
            np.testing.assert_array_equal(logits, want)

    def test_stride_longer_than_window(self):
        """stride > window: only the last `window` readings of each pending
        block are scattered (unique indices — deterministic off-CPU too)."""
        window, stride = 3, 5
        model, params = identity_probe(window, 1)
        eng = StreamEngine(model, params, n_streams=2, n_features=1,
                           window=window, stride=stride,
                           norm_mean=(0.0,), norm_std=(1.0,))
        readings = np.arange(13 * 2, dtype=np.float32).reshape(13, 2, 1)
        batches = drive(eng, readings)
        assert [c for c, _ in batches] == [2, 7, 12]
        for cycle, logits in batches:
            want = readings[cycle - window + 1:cycle + 1]
            want = want.transpose(1, 0, 2).reshape(2, -1)
            np.testing.assert_array_equal(logits, want)

    def test_normalization_applied(self):
        model, params = identity_probe(2, 2)
        eng = StreamEngine(model, params, n_streams=1, n_features=2, window=2,
                           stride=1, norm_mean=(10.0, 20.0),
                           norm_std=(2.0, 4.0))
        eng.ingest(np.array([[12.0, 24.0]]))
        eng.ingest(np.array([[14.0, 28.0]]))
        np.testing.assert_allclose(eng.last_logits,
                                   [[1.0, 1.0, 2.0, 2.0]])

    def test_shape_validation(self):
        model, params = identity_probe(4, 2)
        eng = StreamEngine(model, params, n_streams=2, n_features=2, window=4)
        with pytest.raises(ValueError):
            eng.ingest(np.zeros((3, 2)))
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2, n_features=2, window=3)


class TestDetectorServing:
    def _windows_from(self, readings, window, mean, std):
        norm = (readings - mean) / std
        return norm.transpose(1, 0, 2).reshape(readings.shape[1], -1)

    def test_real_logits_match_model_apply(self):
        model = build_detector()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = StreamEngine(model, params, n_streams=4)
        fleet = build_fleet(["baseline", "tb0-spoof"], 4, seed=0)
        eng.run(fleet, 200)
        fleet2 = build_fleet(["baseline", "tb0-spoof"], 4, seed=0)
        readings = np.zeros((200, 4, 2), np.float32)
        for c in range(200):
            for i, s in enumerate(fleet2):
                r = s.step()
                readings[c, i] = (r.tb0_meas, r.wd_meas)
        win = self._windows_from(readings, 200, np.array(eng._mean),
                                 np.array(eng._std))
        want = jax.vmap(model.apply, (None, 0))(params, jnp.asarray(win))
        np.testing.assert_allclose(eng.last_logits, np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("scheme", ("SINT", "INT", "DINT"))
    def test_quantized_logits_match_model_apply(self, scheme):
        """The engine's batched quantized forward equals the per-sample
        quantized evaluation (layers._quantized_matvec) for every scheme."""
        model = build_detector()
        params = model.init_params(jax.random.PRNGKey(1))
        qp = quantize.quantize_params(model, params, scheme)
        eng = StreamEngine(model, qp, n_streams=3)
        fleet = build_fleet(["recycle-starve"], 3, seed=5)
        eng.run(fleet, 200)
        fleet2 = build_fleet(["recycle-starve"], 3, seed=5)
        readings = np.zeros((200, 3, 2), np.float32)
        for c in range(200):
            for i, s in enumerate(fleet2):
                r = s.step()
                readings[c, i] = (r.tb0_meas, r.wd_meas)
        win = self._windows_from(readings, 200, np.array(eng._mean),
                                 np.array(eng._std))
        want = jax.vmap(model.apply, (None, 0))(qp, jnp.asarray(win))
        np.testing.assert_allclose(eng.last_logits, np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_pallas_backend_matches_ref(self):
        model = build_detector()
        params = model.init_params(jax.random.PRNGKey(2))
        qp = quantize.quantize_params(model, params, "SINT")
        logits = {}
        for backend in ("ref", "pallas"):
            eng = StreamEngine(model, qp, n_streams=2, backend=backend)
            eng.run(build_fleet(["wd-spoof"], 2, seed=9), 200)
            logits[backend] = eng.last_logits
        np.testing.assert_allclose(logits["pallas"], logits["ref"],
                                   rtol=1e-5, atol=1e-4)

    def test_stats_accounting(self):
        model = build_detector()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = StreamEngine(model, params, n_streams=4, stride=10)
        eng.warmup()
        verdicts = eng.run(build_fleet(["baseline"], 4, seed=0), 230)
        st_ = eng.stats
        assert st_.cycles == 230
        assert st_.steps == 4                    # cycles 200,210,220,230
        assert st_.windows == 16 == len(verdicts)
        assert len(st_.latencies_s) == st_.steps
        assert st_.deadline_misses <= st_.windows
        assert st_.wall_s > 0 and st_.windows_per_s() > 0
        assert st_.latency_p(99) >= st_.latency_p(50) > 0
        streams = {v.stream for v in verdicts}
        assert streams == {0, 1, 2, 3}
        for v in verdicts:
            assert v.pred in (0, 1) and 0.0 <= v.prob <= 1.0
            assert (v.latency_s > eng.deadline_s) == v.deadline_miss


class TestLatencyReservoir:
    """Satellite regression: StreamStats.latencies_s used to be an unbounded
    list — one float per verdict step for the life of the engine.  The
    reservoir must hold memory at O(capacity) while keeping latency_p
    statistically valid, and stay an EXACT ordered list below capacity
    (the detection bench slices per-pass latency tails)."""

    def test_memory_bounded_at_100k_appends(self):
        r = LatencyReservoir(capacity=512)
        for i in range(100_000):
            r.append(float(i))
        assert len(r) == 512
        assert len(r._items) == 512              # nothing hides elsewhere
        assert r.seen == 100_000

    def test_exact_and_ordered_below_capacity(self):
        r = LatencyReservoir(capacity=64)
        vals = [float(v) for v in np.random.default_rng(0).normal(size=40)]
        for v in vals:
            r.append(v)
        assert list(r) == vals
        assert r[10:20] == vals[10:20]           # bench tail-slicing contract
        assert r.percentile(50) == np.percentile(vals, 50)

    def test_percentiles_stay_valid_past_capacity(self):
        """Uniform reservoir over 0..99999: quantile estimates must land
        near the true stream quantiles, not near the tail the naive
        'keep the last N' policy would see."""
        r = LatencyReservoir(capacity=2048, seed=1)
        for i in range(100_000):
            r.append(float(i))
        for q in (25, 50, 75, 99):
            assert abs(r.percentile(q) - q * 1000.0) < 5000.0

    def test_validation_and_empty(self):
        with pytest.raises(ValueError):
            LatencyReservoir(capacity=0)
        r = LatencyReservoir()
        assert len(r) == 0 and not r
        # An empty reservoir has no latency distribution: the old 0.0
        # return read as a perfect 0 ms p99 for an engine that never fired.
        with pytest.raises(ValueError, match="empty latency reservoir"):
            r.percentile(99)

    def test_engine_stats_hold_memory_over_long_serve(self):
        """The engine-level invariant: steps can exceed the reservoir
        capacity without latencies_s growing past it."""
        model, params = identity_probe(3, 2)
        eng = StreamEngine(model, params, n_streams=2, n_features=2,
                           window=3, stride=1,
                           norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0))
        eng.stats.latencies_s = LatencyReservoir(capacity=16)
        readings = np.random.default_rng(0).normal(
            size=(60, 2, 2)).astype(np.float32)
        for c in range(60):
            eng.ingest(readings[c])
        assert eng.stats.steps == 58             # windows at cycles 3..60
        assert len(eng.stats.latencies_s) == 16
        assert eng.stats.latencies_s.seen == 58
        assert eng.stats.latency_p(99) >= eng.stats.latency_p(50) > 0


@pytest.mark.slow
class TestEndToEndDetection:
    def test_fleet_detection_regression(self):
        """Seeded small-budget train + port + quantize: the serving path must
        flag attacked plants after onset and stay quiet on the benign one,
        across >= 3 scenarios."""
        from repro.core import porting
        from repro.sim import build_dataset, get_scenario, train_detector
        import tempfile

        x, y = build_dataset(normal_cycles=8000, attack_cycles=2500,
                             stride=8, seed=0, jitter=0.015, jitter_plants=2)
        model, res = train_detector(x, y, epochs=40, patience=40, lr=1e-3)
        assert res.test_acc > 0.70
        with tempfile.TemporaryDirectory() as tmp:
            model, params = porting.port_mlp(model, res.params, tmp)
        params = quantize.quantize_params(
            model, params, "SINT",
            calibration=[jnp.asarray(x[i]) for i in range(0, 128, 8)])

        # jitter pinned to 0: the small training budget can't also certify
        # out-of-distribution plant heterogeneity (examples/detect_fleet.py
        # exercises that with the full budget)
        names = ["baseline", "recycle-starve", "tb0-spoof", "steam-throttle"]
        fleet = build_fleet(names, seed=4242, jitter=0.0)
        eng = StreamEngine(model, params, n_streams=len(fleet))
        eng.warmup()
        verdicts = eng.run(fleet, 1400)

        by_stream = {}
        for v in verdicts:
            by_stream.setdefault(v.stream, []).append(v)
        for i, name in enumerate(names):
            onset = get_scenario(name).onset
            vs = by_stream[i]
            if onset is None:
                fp = sum(v.pred != 0 for v in vs) / len(vs)
                assert fp < 0.2, f"{name}: false-positive rate {fp:.2f}"
            else:
                post = [v for v in vs if v.cycle >= onset]
                hits = [v for v in post if v.pred != 0]
                assert hits, f"{name}: attack never flagged"
