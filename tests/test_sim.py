"""MSF plant simulation + scenario library + detector (§7) — fast variants."""

import dataclasses

import numpy as np
import pytest

from repro.sim import build_dataset, simulate
from repro.sim.msf import (ATTACK_NAMES, AttackEvent, PlantParams, PlantStream,
                           adc, make_attack, make_attacks)
from repro.sim.scenarios import (SCENARIOS, Scenario, build_fleet,
                                 get_scenario, jitter_params, list_scenarios,
                                 register_scenario, registered,
                                 unregister_scenario)


class TestPlant:
    def test_settles_at_setpoint(self):
        tr = simulate(2000, seed=0)
        seg = tr.wd_meas[500:]
        assert abs(seg.mean() - 19.18) < 0.05
        assert seg.std() < 0.02

    def test_adc_quantizes(self):
        vals = {adc(19.18 + i * 1e-5, 0.0, 40.0) for i in range(50)}
        assert len(vals) < 50   # visible quantization steps (Fig. 7)

    def test_adc_clamps(self):
        assert adc(500.0, 0.0, 40.0) == 40.0
        assert adc(-5.0, 0.0, 40.0) == 0.0

    @pytest.mark.parametrize("attack_id", list(range(1, 8)))
    def test_attacks_perturb_process(self, attack_id):
        """Every attack family must move the observable state away from the
        normal trajectory (eventually)."""
        normal = simulate(2400, seed=0)
        attacked = simulate(2400, attack_id=attack_id, attack_start=400, seed=0)
        # measure from injection onward: integral PID action fully compensates
        # some actuator attacks at steady state (e.g. water rejection), so the
        # signature is transient — which is also what the detector sees
        d_tb0 = np.abs(attacked.tb0_meas[400:] - normal.tb0_meas[400:]).max()
        d_wd = np.abs(attacked.wd_meas[400:] - normal.wd_meas[400:]).max()
        assert max(d_tb0, d_wd) > 0.05, f"attack {attack_id} invisible"

    @pytest.mark.parametrize("attack_id", list(range(1, 8)))
    def test_attack_labels_flip_at_start(self, attack_id):
        """Labels are 0 before the onset and the attack id from it on, for
        every family."""
        tr = simulate(1000, attack_id=attack_id, attack_start=600, seed=1)
        assert (tr.label[:600] == 0).all()
        assert (tr.label[600:] == attack_id).all()

    def test_defense_hook_called_every_cycle(self):
        seen = []
        simulate(50, defense_hook=lambda c, r: seen.append((c, tuple(r))))
        assert len(seen) == 50
        assert all(len(r) == 2 for _, r in seen)

    def test_deterministic_given_seed(self):
        a = simulate(300, seed=42)
        b = simulate(300, seed=42)
        np.testing.assert_array_equal(a.wd_meas, b.wd_meas)


class TestAttackSchedule:
    def test_events_equivalent_to_single_attack(self):
        a = simulate(800, attack_id=3, attack_start=300, seed=1)
        b = simulate(800, events=[AttackEvent(3, start=300)], seed=1)
        np.testing.assert_array_equal(a.wd_meas, b.wd_meas)
        np.testing.assert_array_equal(a.label, b.label)

    def test_event_duration_bounds_labels(self):
        tr = simulate(900, events=[AttackEvent(4, start=300, duration=200)],
                      seed=2)
        assert (tr.label[:300] == 0).all()
        assert (tr.label[300:500] == 4).all()
        assert (tr.label[500:] == 0).all()

    def test_multi_event_sequence_labels(self):
        tr = simulate(1000, seed=3, events=[
            AttackEvent(1, start=200, duration=100),
            AttackEvent(5, start=600, duration=100)])
        assert (tr.label[200:300] == 1).all()
        assert (tr.label[300:600] == 0).all()
        assert (tr.label[600:700] == 5).all()

    def test_earliest_listed_event_wins_overlap(self):
        tr = simulate(500, seed=4, events=[
            AttackEvent(2, start=100), AttackEvent(6, start=300)])
        assert (tr.label[100:] == 2).all()

    def test_intensity_scales_deviation(self):
        normal = simulate(1200, seed=0)
        devs = []
        for intensity in (0.5, 1.5):
            tr = simulate(1200, seed=0, events=[
                AttackEvent(1, start=300, intensity=intensity)])
            devs.append(np.abs(tr.wd_meas[300:] - normal.wd_meas[300:]).max())
        assert devs[1] > devs[0] * 1.5

    def test_intensity_one_matches_legacy_magnitudes(self):
        """make_attack(i, 1.0) reproduces the §7 magnitudes of make_attacks."""
        for aid in range(1, 8):
            a, b = make_attack(aid, 1.0), make_attacks()[aid]
            for t in (0, 37, 500):
                wa, oa, ba = a(t, 5.0)
                wb, ob, bb = b(t, 5.0)
                assert (wa, oa, ba) == (wb, ob, bb)

    def test_unknown_attack_id_raises(self):
        with pytest.raises(ValueError):
            make_attack(9)

    def test_events_exclusive_with_legacy_interface(self):
        with pytest.raises(ValueError):
            simulate(100, attack_id=1, events=[AttackEvent(2, 10)])
        with pytest.raises(ValueError):
            simulate(100, attack_start=30, events=[AttackEvent(2, 10)])

    def test_stream_matches_simulate(self):
        events = [AttackEvent(6, start=100)]
        stream = PlantStream(events=events, seed=7)
        got = np.array([stream.step().wd_meas for _ in range(400)])
        want = simulate(400, events=events, seed=7).wd_meas
        np.testing.assert_array_equal(got, want)


class TestScenarioRegistration:
    """Satellite: register_scenario finally has a removal path — no test or
    driver needs to leak entries into the process-global library."""

    def _custom(self, name="custom-probe"):
        return Scenario(name=name, description="test-only",
                        events=(AttackEvent(1, start=300),))

    def test_register_unregister_round_trip(self):
        sc = register_scenario(self._custom())
        try:
            assert get_scenario(sc.name) is sc
        finally:
            assert unregister_scenario(sc.name) is sc
        assert sc.name not in SCENARIOS
        with pytest.raises(KeyError):
            unregister_scenario(sc.name)

    def test_builtin_scenarios_protected(self):
        with pytest.raises(ValueError, match="built-in"):
            unregister_scenario("baseline")
        assert "baseline" in SCENARIOS

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_scenario(self._custom("baseline"))

    def test_registered_context_manager(self):
        before = set(SCENARIOS)
        with registered(self._custom()) as sc:
            assert get_scenario("custom-probe") is sc
            # usable by the fleet builders inside the scope
            fleet = build_fleet(["custom-probe"], 2, seed=0)
            assert all(p.name.startswith("custom-probe#") for p in fleet)
        assert set(SCENARIOS) == before

    def test_registered_cleans_up_on_error_and_multi(self):
        before = set(SCENARIOS)
        with pytest.raises(RuntimeError):
            with registered(self._custom("a-probe"), self._custom("b-probe")) \
                    as (a, b):
                assert a.name == "a-probe" and b.name == "b-probe"
                raise RuntimeError("boom")
        assert set(SCENARIOS) == before
        # a clashing second registration unwinds the first
        with pytest.raises(ValueError):
            with registered(self._custom("a-probe"),
                            self._custom("baseline")):
                pass                             # pragma: no cover
        assert set(SCENARIOS) == before

    def test_registered_tolerates_inner_unregister(self):
        before = set(SCENARIOS)
        with registered(self._custom()) as sc:
            unregister_scenario(sc.name)
        assert set(SCENARIOS) == before


class TestScenarioLibrary:
    def test_library_size_and_coverage(self):
        assert len(SCENARIOS) >= 12
        families = {f for s in SCENARIOS.values() for f in s.families}
        assert families == set(range(1, 8))
        assert sum(s.composed for s in SCENARIOS.values()) >= 2

    def test_get_scenario(self):
        s = get_scenario("stealth-drift")
        assert s.families == (7,)
        with pytest.raises(KeyError):
            get_scenario("nope")
        assert set(list_scenarios()) == set(SCENARIOS)

    def test_onset(self):
        assert get_scenario("baseline").onset is None
        assert get_scenario("spoof-then-starve").onset == 300

    def test_jitter_params(self):
        rng = np.random.default_rng(0)
        base = PlantParams()
        j = jitter_params(base, 0.05, rng)
        assert j.tau_tb != base.tau_tb
        assert abs(j.tau_tb / base.tau_tb - 1.0) <= 0.05
        assert j.wd_setpoint == base.wd_setpoint  # setpoint is operator-fixed
        same = jitter_params(base, 0.0, rng)
        assert dataclasses.asdict(same) == dataclasses.asdict(base)

    def test_build_fleet_round_robin_and_seeds(self):
        fleet = build_fleet(["baseline", "tb0-spoof"], 5, seed=3)
        assert [p.name for p in fleet] == [
            "baseline#0", "tb0-spoof#1", "baseline#2", "tb0-spoof#3",
            "baseline#4"]
        # distinct seeds + jitter -> distinct trajectories for same scenario
        a, b = fleet[0], fleet[2]
        ra = [a.step().wd_meas for _ in range(50)]
        rb = [b.step().wd_meas for _ in range(50)]
        assert ra != rb

    def test_fleet_scenarios_runnable(self):
        """Every library scenario drives a stream without error."""
        fleet = build_fleet(seed=0)
        assert len(fleet) == len(SCENARIOS)
        for p in fleet:
            for _ in range(5):
                r = p.step()
            assert np.isfinite(r.wd_meas)

    def test_attack_names_cover_families(self):
        assert set(ATTACK_NAMES) == set(range(1, 8))


class TestDataset:
    def test_window_shape(self):
        x, y = build_dataset(normal_cycles=1500, attack_cycles=700, stride=50,
                             seed=0)
        assert x.shape[1] == 400   # 2 x 200 (§7)
        assert set(np.unique(y)) <= {0, 1}
        assert 0.05 < y.mean() < 0.95

    def test_jittered_normal_plants_extend_dataset(self):
        base = build_dataset(normal_cycles=1500, attack_cycles=700, stride=50,
                             seed=0)
        jit = build_dataset(normal_cycles=1500, attack_cycles=700, stride=50,
                            seed=0, jitter=0.02, jitter_plants=2)
        assert len(jit[0]) > len(base[0])
        # the extra windows are all normal-labeled
        assert jit[1].sum() == base[1].sum()


@pytest.mark.slow
class TestDetectorTraining:
    def test_detector_beats_chance_quickly(self):
        from repro.sim import train_detector
        x, y = build_dataset(normal_cycles=8000, attack_cycles=2500,
                             stride=8, seed=0)
        _, res = train_detector(x, y, epochs=40, patience=40, lr=1e-3)
        assert res.test_acc > 0.70
