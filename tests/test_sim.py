"""MSF plant simulation + detector (§7) — fast variants."""

import numpy as np
import pytest

from repro.sim import build_dataset, simulate
from repro.sim.msf import adc, make_attacks


class TestPlant:
    def test_settles_at_setpoint(self):
        tr = simulate(2000, seed=0)
        seg = tr.wd_meas[500:]
        assert abs(seg.mean() - 19.18) < 0.05
        assert seg.std() < 0.02

    def test_adc_quantizes(self):
        vals = {adc(19.18 + i * 1e-5, 0.0, 40.0) for i in range(50)}
        assert len(vals) < 50   # visible quantization steps (Fig. 7)

    def test_adc_clamps(self):
        assert adc(500.0, 0.0, 40.0) == 40.0
        assert adc(-5.0, 0.0, 40.0) == 0.0

    @pytest.mark.parametrize("attack_id", list(range(1, 8)))
    def test_attacks_perturb_process(self, attack_id):
        """Every attack family must move the observable state away from the
        normal trajectory (eventually)."""
        normal = simulate(2400, seed=0)
        attacked = simulate(2400, attack_id=attack_id, attack_start=400, seed=0)
        # measure from injection onward: integral PID action fully compensates
        # some actuator attacks at steady state (e.g. water rejection), so the
        # signature is transient — which is also what the detector sees
        d_tb0 = np.abs(attacked.tb0_meas[400:] - normal.tb0_meas[400:]).max()
        d_wd = np.abs(attacked.wd_meas[400:] - normal.wd_meas[400:]).max()
        assert max(d_tb0, d_wd) > 0.05, f"attack {attack_id} invisible"

    def test_attack_labels(self):
        tr = simulate(1000, attack_id=3, attack_start=600, seed=1)
        assert (tr.label[:600] == 0).all()
        assert (tr.label[600:] == 3).all()

    def test_defense_hook_called_every_cycle(self):
        seen = []
        simulate(50, defense_hook=lambda c, r: seen.append((c, tuple(r))))
        assert len(seen) == 50
        assert all(len(r) == 2 for _, r in seen)

    def test_deterministic_given_seed(self):
        a = simulate(300, seed=42)
        b = simulate(300, seed=42)
        np.testing.assert_array_equal(a.wd_meas, b.wd_meas)


class TestDataset:
    def test_window_shape(self):
        x, y = build_dataset(normal_cycles=1500, attack_cycles=700, stride=50,
                             seed=0)
        assert x.shape[1] == 400   # 2 x 200 (§7)
        assert set(np.unique(y)) <= {0, 1}
        assert 0.05 < y.mean() < 0.95


@pytest.mark.slow
class TestDetectorTraining:
    def test_detector_beats_chance_quickly(self):
        from repro.sim import train_detector
        x, y = build_dataset(normal_cycles=8000, attack_cycles=2500,
                             stride=8, seed=0)
        _, res = train_detector(x, y, epochs=40, patience=40, lr=1e-3)
        assert res.test_acc > 0.70
