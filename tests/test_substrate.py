"""Substrate tests: optimizer, schedules, data pipeline, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import latest_step, restore, save
from repro.data import DataConfig, SyntheticLM, read_shard, write_shard


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        target = jnp.asarray([1.0, 2.0])
        init, update = optim.adamw(0.1, weight_decay=0.0)
        state = init(params)

        @jax.jit
        def step(p, s):
            g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
            upd, s = update(g, s, p)
            return optim.apply_updates(p, upd), s

        for _ in range(300):
            params, state = step(params, state)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                                   atol=1e-2)

    def test_integer_leaves_untouched(self):
        params = {"qw": jnp.ones((2, 2), jnp.int8), "w": jnp.ones((2,))}
        init, update = optim.adamw(0.1)
        state = init(params)
        grads = {"qw": jnp.zeros((2, 2), jnp.int8), "w": jnp.ones((2,))}
        upd, state = update(grads, state, params)
        assert int(jnp.abs(upd["qw"]).max()) == 0
        assert float(jnp.abs(upd["w"]).max()) > 0

    def test_grad_clip(self):
        params = {"w": jnp.zeros((3,))}
        init, update = optim.adamw(1.0, grad_clip=1.0, weight_decay=0.0)
        state = init(params)
        huge = {"w": jnp.full((3,), 1e6)}
        upd, _ = update(huge, state, params)
        assert np.isfinite(np.asarray(upd["w"])).all()

    def test_schedules(self):
        fn = optim.linear_warmup_cosine(1.0, warmup=10, steps=110)
        assert float(fn(jnp.int32(0))) == 0.0
        assert abs(float(fn(jnp.int32(10))) - 1.0) < 1e-6
        assert float(fn(jnp.int32(110))) < 0.2


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
        a = next(SyntheticLM(cfg).batches())
        b = next(SyntheticLM(cfg).batches())
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
        batch = next(SyntheticLM(cfg).batches())
        assert batch["tokens"].shape == (2, 8)
        assert batch["labels"].shape == (2, 8)

    def test_markov_structure_learnable(self):
        """successor structure exists: P(label==succ[token]) >> 1/vocab."""
        cfg = DataConfig(vocab=64, seq_len=128, global_batch=8, seed=3)
        src = SyntheticLM(cfg)
        batch = next(src.batches())
        succ = src._succ
        hit = (batch["labels"] == succ[batch["tokens"]]).mean()
        assert hit > 0.5

    def test_shard_roundtrip(self, tmp_path):
        tokens = np.random.default_rng(0).integers(0, 99, (10, 17)).astype(np.int32)
        path = str(tmp_path / "shard0.bin")
        write_shard(path, tokens)
        np.testing.assert_array_equal(read_shard(path), tokens)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        save(str(tmp_path), 5, tree)
        assert latest_step(str(tmp_path)) == 5
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        back = restore(str(tmp_path), like)
        np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
        assert back["b"]["c"].dtype == jnp.bfloat16

    def test_shape_mismatch_raises(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            restore(str(tmp_path), {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})

    def test_multiple_steps_latest_wins(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.zeros((2,))})
        save(str(tmp_path), 2, {"a": jnp.ones((2,))})
        like = {"a": jax.ShapeDtypeStruct((2,), jnp.float32)}
        back = restore(str(tmp_path), like)
        np.testing.assert_array_equal(np.asarray(back["a"]), np.ones(2))
