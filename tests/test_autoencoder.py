"""Autoencoder detector workload: head semantics, threshold calibration,
StreamEngine parity (fused vs per-layer, sharded vs unsharded) over
ring-wraparound scenario runs, quantization-calibration parity, and the
on-device score-reduction guarantee."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import layers as L
from repro.core import quantize, sequential
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.serving import StreamEngine
from repro.sim import (ClassifierHead, ReconstructionHead, build_autoencoder,
                       fleet_readings, softmax_np, train_autoencoder)
from repro.sim.detector import batched_forward

from test_fused import autoencoder_params, count_pallas_calls

SCHEMES = ("REAL", "SINT", "INT", "DINT")
N_DEVICES = len(jax.devices())


def small_autoencoder(scheme, seed):
    """An autoencoder-shaped all-Dense stack over a 4-reading window
    (2 features -> 8 inputs -> 8 outputs), cheap for property-test volumes."""
    model = sequential([L.Input(),
                        L.Dense(units=6, activation="relu"),
                        L.Dense(units=8, activation="linear")], (8,))
    params = model.init_params(jax.random.PRNGKey(seed))
    if scheme != "REAL":
        calib = [jax.random.normal(jax.random.PRNGKey(400 + i), (8,)) * 2.0
                 for i in range(4)]
        params = quantize.quantize_params(model, params, scheme,
                                          calibration=calib)
    return model, params


class TestSoftmaxNp:
    """Satellite regression: the host softmax must be batched-stable —
    per-row max subtracted along axis -1 — so extreme logits never overflow
    and rows never contaminate each other."""

    def test_extreme_logits_stable(self):
        logits = np.array([[1e4, -1e4],
                           [-1e4, 1e4],
                           [88.0, 89.0],
                           [0.0, 0.0]], np.float32)
        p = softmax_np(logits)
        assert np.isfinite(p).all()
        np.testing.assert_allclose(p.sum(axis=-1), 1.0, rtol=1e-6)
        assert p[0, 0] > 0.999 and p[1, 1] > 0.999
        np.testing.assert_allclose(p[3], [0.5, 0.5])

    def test_rows_are_independent(self):
        """Each row's softmax equals that row computed alone — the per-row
        (not global) max is what gets subtracted."""
        rng = np.random.default_rng(0)
        logits = rng.normal(scale=200.0, size=(6, 4)).astype(np.float32)
        batched = softmax_np(logits)
        for i in range(len(logits)):
            np.testing.assert_allclose(batched[i],
                                       softmax_np(logits[i][None])[0],
                                       rtol=1e-6, atol=0)

    def test_classifier_head_uses_stable_softmax(self):
        head = ClassifierHead()
        out = np.array([[1e4, 0.0], [0.0, 1e4]], np.float32)
        pred, prob, score, thr = head.host_verdicts(out)
        assert list(pred) == [0, 1]
        assert np.isfinite(prob).all() and (prob > 0.999).all()
        assert score is None and thr is None


class TestReconstructionHead:
    def test_epilogue_reduces_to_one_score_per_stream(self):
        head = ReconstructionHead(threshold=1.0)
        win = jnp.asarray(np.random.default_rng(0).normal(size=(5, 8))
                          .astype(np.float32))
        out = jnp.asarray(np.random.default_rng(1).normal(size=(5, 8))
                          .astype(np.float32))
        red = head.epilogue(win, out)
        assert red.shape == (5, 1)
        np.testing.assert_allclose(
            np.asarray(red)[:, 0],
            np.mean((np.asarray(out) - np.asarray(win)) ** 2, axis=-1),
            rtol=1e-6)

    def test_calibrate_hits_target_fpr(self):
        scores = np.linspace(0.0, 1.0, 1000)
        head = ReconstructionHead().calibrate(scores, target_fpr=0.05)
        realized = np.mean(scores > head.threshold)
        assert abs(realized - 0.05) < 0.01
        # monotone: tighter FPR -> higher threshold
        tighter = ReconstructionHead().calibrate(scores, target_fpr=0.01)
        assert tighter.threshold > head.threshold

    def test_small_sample_calibration_fpr_never_exceeds_target(self):
        """Satellite regression: quantile interpolation used to let the
        calibration-set FPR land ABOVE target_fpr on small score sets (an
        interpolated threshold sits below the next order statistic, so the
        strict > comparison flags more than target_fpr of the very windows
        it was calibrated on).  The conservative (method='higher') quantile
        guarantees realized FPR <= target on the calibration set itself —
        for every small-set size and target."""
        rng = np.random.default_rng(0)
        for n in (5, 7, 13, 50, 99):
            for target in (0.01, 0.05, 0.1, 0.25):
                scores = rng.normal(size=n) ** 2
                head = ReconstructionHead().calibrate(scores,
                                                      target_fpr=target)
                realized = np.mean(scores > head.threshold)
                assert realized <= target, (n, target, realized)
                # the threshold is an actual observed score, never an
                # interpolated value between two of them
                assert head.threshold in scores

    def test_conservative_quantile_shared_by_all_score_heads(self):
        """Margin and forecast heads calibrate through the same
        conservative quantile (the fix is in the ScoreHead base, not
        patched per head)."""
        from repro.sim import ForecastHead, MarginHead, conservative_quantile
        scores = np.array([0.1, 0.2, 0.3, 0.4, 0.5])
        want = conservative_quantile(scores, 0.25)
        assert want == 0.4
        for head in (MarginHead(center=(0.0,)), ForecastHead(),
                     ReconstructionHead()):
            assert head.calibrate(scores, 0.25).threshold == want

    def test_calibrate_validation(self):
        with pytest.raises(ValueError):
            ReconstructionHead().calibrate(np.ones(4), target_fpr=0.0)
        with pytest.raises(ValueError):
            ReconstructionHead().calibrate(np.ones(4), target_fpr=1.5)
        with pytest.raises(ValueError):
            ReconstructionHead().calibrate(np.zeros(0), target_fpr=0.1)

    def test_host_verdicts_threshold_semantics(self):
        head = ReconstructionHead(threshold=0.5)
        pred, prob, score, thr = head.host_verdicts(
            np.array([[0.4], [0.6], [0.5]], np.float32))
        assert list(pred) == [0, 1, 0]          # strict >
        assert prob is None and thr == 0.5
        np.testing.assert_allclose(score, [0.4, 0.6, 0.5])

    def test_uncalibrated_head_rejected(self):
        with pytest.raises(ValueError):
            ReconstructionHead().host_verdicts(np.zeros((2, 1), np.float32))
        model, params = small_autoencoder("REAL", 0)
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2, n_features=2, window=4,
                         head=ReconstructionHead())

    def test_head_model_width_mismatch_rejected(self):
        from repro.sim import build_detector
        model = build_detector()
        params = model.init_params(jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2,
                         head=ReconstructionHead(threshold=1.0))


def drive_ae(eng, readings):
    out = []
    for c in range(readings.shape[0]):
        vs = eng.ingest(readings[c])
        if vs:
            out.append((c, vs, eng.last_logits.copy()))
    return out


def engine_ae(model, params, n_streams, *, window, stride, threshold=0.01,
              **kw):
    return StreamEngine(model, params, n_streams=n_streams, n_features=2,
                        window=window, stride=stride,
                        head=ReconstructionHead(threshold=threshold), **kw)


class TestEngineServesAutoencoder:
    def test_scores_match_offline_reconstruction_error(self):
        """The engine's served scores equal the reconstruction error of the
        naively-sliced window through batched_forward — the whole ring/
        scatter/epilogue pipeline against offline math."""
        model, params = autoencoder_params("REAL")
        eng = StreamEngine(model, params, n_streams=3,
                           head=ReconstructionHead(threshold=0.01))
        readings = fleet_readings(3, 200, seed=4)
        for c in range(200):
            vs = eng.ingest(readings[c])
        assert eng.last_logits.shape == (3, 1)
        norm = (readings - np.asarray(eng._mean)) / np.asarray(eng._std)
        win = jnp.asarray(norm.transpose(1, 0, 2).reshape(3, -1))
        recon = batched_forward(model, params, win)
        want = np.mean((np.asarray(recon) - np.asarray(win)) ** 2, axis=-1)
        np.testing.assert_allclose(eng.last_logits[:, 0], want,
                                   rtol=1e-5, atol=1e-6)
        for v in vs:
            assert v.prob is None
            assert v.threshold == 0.01
            assert v.pred == int(v.score > 0.01)

    def test_step_output_is_reduced_on_device(self):
        """The jitted step's verdict output aval is (S, 1) — the (S, 400)
        reconstruction never crosses the device boundary."""
        model, params = autoencoder_params("REAL")
        eng = StreamEngine(model, params, n_streams=16,
                           head=ReconstructionHead(threshold=0.01))
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert jaxpr.out_avals[1].shape == (eng._s_pad, 1)

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_fused_vs_per_layer_wraparound_regression(self, scheme):
        """Pinned full-size run: 430 cycles wraps the 200-reading ring and
        the fused and per-layer autoencoder engines must agree verdict for
        verdict (REAL bit-match, quantized epsilon)."""
        model, params = autoencoder_params(scheme, seed=1)
        readings = fleet_readings(3, 430, seed=11)
        results = {}
        for fused in (True, False):
            eng = engine_ae(model, params, 3, window=200, stride=10,
                            fused=fused)
            results[fused] = drive_ae(eng, readings)
        got, want = results[True], results[False]
        assert len(got) == len(want) == 24
        assert [(c, [(v.stream, v.cycle, v.pred) for v in vs])
                for c, vs, _ in got] == \
               [(c, [(v.stream, v.cycle, v.pred) for v in vs])
                for c, vs, _ in want]
        for (_, gvs, gl), (_, wvs, wl) in zip(got, want):
            if scheme == "REAL":
                np.testing.assert_array_equal(gl, wl)
            else:
                np.testing.assert_allclose(gl, wl, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose([v.score for v in gvs],
                                       [v.score for v in wvs],
                                       rtol=1e-5, atol=1e-6)

    @settings(max_examples=6, deadline=None)
    @given(scheme=st.sampled_from(SCHEMES), seed=st.integers(0, 2**20),
           extra=st.integers(8, 40))
    def test_small_ae_fused_vs_per_layer_property(self, scheme, seed, extra):
        model, params = small_autoencoder(scheme, seed % 7)
        window, stride = 4, 3
        readings = fleet_readings(3, window + extra, seed=seed)
        results = {}
        for fused in (True, False):
            eng = engine_ae(model, params, 3, window=window, stride=stride,
                            fused=fused, threshold=0.5)
            results[fused] = drive_ae(eng, readings)
        got, want = results[True], results[False]
        assert len(got) == len(want) >= 3
        assert [(c, [(v.stream, v.cycle, v.pred) for v in vs])
                for c, vs, _ in got] == \
               [(c, [(v.stream, v.cycle, v.pred) for v in vs])
                for c, vs, _ in want]
        for (_, _, gl), (_, _, wl) in zip(got, want):
            np.testing.assert_allclose(gl, wl, rtol=1e-6, atol=1e-7)

    def test_warmup_and_stats(self):
        model, params = autoencoder_params("SINT")
        eng = StreamEngine(model, params, n_streams=4,
                           head=ReconstructionHead(threshold=0.01))
        eng.warmup()
        readings = fleet_readings(4, 230, seed=2)
        n = 0
        for c in range(230):
            n += len(eng.ingest(readings[c]))
        assert n == eng.stats.windows == 16
        assert eng.stats.steps == 4


@pytest.mark.skipif(N_DEVICES < 2, reason="needs >=2 host devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
class TestShardedAutoencoderParity:
    """Issue acceptance: sharded-vs-unsharded parity for the autoencoder
    fleet over ring-wraparound scenario runs — the head's score reduction
    runs per shard, inside shard_map."""

    @pytest.mark.parametrize("n_streams", (4, 5))   # divisible + pad
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_small_ae_sharded_parity(self, scheme, n_streams):
        n_devices = min(2, N_DEVICES)
        model, params = small_autoencoder(scheme, seed=n_streams)
        readings = fleet_readings(n_streams, 30, seed=13 + n_streams)
        base = engine_ae(model, params, n_streams, window=4, stride=3,
                         threshold=0.5, shard=False)
        shard = engine_ae(model, params, n_streams, window=4, stride=3,
                          threshold=0.5, mesh=make_fleet_mesh(n_devices))
        want = drive_ae(base, readings)
        got = drive_ae(shard, readings)
        assert len(got) == len(want) >= 9           # the ring wrapped
        assert [(c, [(v.stream, v.cycle, v.pred) for v in vs])
                for c, vs, _ in got] == \
               [(c, [(v.stream, v.cycle, v.pred) for v in vs])
                for c, vs, _ in want]
        exact = scheme == "REAL" and shard.shard_streams > 1
        for (_, gvs, gl), (_, wvs, wl) in zip(got, want):
            if exact:
                np.testing.assert_array_equal(gl, wl)
            else:
                np.testing.assert_allclose(gl, wl, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_full_ae_sharded_wraparound_regression(self, scheme):
        """Full-size 400-64-16-64-400 fleet, non-divisible 6-plant fleet on
        the widest mesh, 430 cycles (ring wraps twice)."""
        n_devices = max(n for n in (1, 2, 4) if n <= N_DEVICES)
        model, params = autoencoder_params(scheme, seed=1)
        readings = fleet_readings(6, 430, seed=11)
        base = engine_ae(model, params, 6, window=200, stride=10,
                         shard=False)
        shard = engine_ae(model, params, 6, window=200, stride=10,
                          mesh=make_fleet_mesh(n_devices))
        want = drive_ae(base, readings)
        got = drive_ae(shard, readings)
        assert len(got) == len(want) == 24
        for (_, gvs, gl), (_, wvs, wl) in zip(got, want):
            assert [(v.stream, v.pred) for v in gvs] == \
                   [(v.stream, v.pred) for v in wvs]
            if scheme == "REAL":
                np.testing.assert_array_equal(gl, wl)
            else:
                np.testing.assert_allclose(gl, wl, rtol=1e-5, atol=1e-6)

    def test_sharded_ae_step_is_one_dispatch_per_shard(self):
        model, params = autoencoder_params("SINT")
        eng = StreamEngine(model, params, n_streams=6, backend="pallas",
                           fused=True, head=ReconstructionHead(threshold=0.01),
                           mesh=make_fleet_mesh(min(2, N_DEVICES)))
        ring = jnp.zeros((eng._s_pad, eng.window, 2), jnp.float32)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 1
        assert jaxpr.out_avals[1].shape == (eng._s_pad, 1)


class TestSingleDispatchAEEngine:
    """The engine's autoencoder verdict step — forward AND score epilogue —
    is one fused Pallas dispatch (vs one per layer on the per-layer path)."""

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_ae_verdict_step_is_one_dispatch(self, scheme):
        model, params = autoencoder_params(scheme)
        eng = StreamEngine(model, params, n_streams=16, backend="pallas",
                           fused=True,
                           head=ReconstructionHead(threshold=0.01))
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((16, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 1

    def test_ae_per_layer_step_is_four_dispatches(self):
        model, params = autoencoder_params("SINT")
        eng = StreamEngine(model, params, n_streams=16, backend="pallas",
                           fused=False,
                           head=ReconstructionHead(threshold=0.01))
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((16, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 4


class TestQuantizationCalibration:
    """Satellite: autoencoder output-layer scales must come from benign-
    trace activation ranges — weight absmax alone (the uncalibrated 1/qmax
    default) leaves SINT reconstruction error far from REAL."""

    def _benign_windows(self, n=64):
        readings = fleet_readings(3, 200 + n, seed=0, names=["baseline"])
        norm = ((readings - np.array([89.6, 19.18], np.float32))
                / np.array([2.0, 0.5], np.float32))
        wins = [norm[c:c + 200, s].reshape(-1)
                for s in range(3) for c in range(0, n, 8)]
        return np.stack(wins).astype(np.float32)

    def test_calibrated_sint_scores_within_epsilon_of_real(self):
        model = build_autoencoder()
        params = model.init_params(jax.random.PRNGKey(3))
        # Trained autoencoders carry hidden activations well outside the
        # default's [-1, 1] assumption; scale the init weights to put this
        # stack in that regime without a training run.
        params = {uid: {k: (v * 3.0 if k == "w" else v)
                        for k, v in p.items()}
                  for uid, p in params.items()}
        x = jnp.asarray(self._benign_windows())
        calib = quantize.calibration_samples(np.asarray(x), k=16)
        qp_cal = quantize.quantize_params(model, params, "SINT",
                                          calibration=calib)
        qp_def = quantize.quantize_params(model, params, "SINT")
        head = ReconstructionHead()
        real = np.asarray(head.scores(batched_forward(model, params, x), x))
        cal = np.asarray(head.scores(batched_forward(model, qp_cal, x), x))
        deflt = np.asarray(head.scores(batched_forward(model, qp_def, x), x))
        # Pinned epsilon: calibrated SINT tracks REAL scores closely...
        np.testing.assert_allclose(cal, real, rtol=0.35, atol=5e-3)
        # ...and beats the uncalibrated default by a wide margin.
        err_cal = np.abs(cal - real).mean()
        err_def = np.abs(deflt - real).mean()
        assert err_cal * 10 < err_def, (err_cal, err_def)

    def test_calibration_samples_benign_only(self):
        x = np.arange(20, dtype=np.float32).reshape(10, 2)
        y = np.array([0, 1, 0, 1, 0, 1, 0, 1, 0, 1])
        samples = quantize.calibration_samples(x, y, k=3)
        assert len(samples) == 3
        for s in samples:
            assert float(s[0]) % 4 == 0          # benign rows are even rows
        with pytest.raises(ValueError):
            quantize.calibration_samples(x, np.ones(10), k=3)


class TestTrainAutoencoder:
    def test_train_calibrate_smoke(self):
        """Head-generic training on synthetic benign windows: the result
        carries a calibrated head whose realized FPR is near target."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=(900, 400)).astype(np.float32)
        model, res = train_autoencoder(x, None, epochs=2, batch_size=128,
                                       patience=2, target_fpr=0.05)
        assert res.threshold > 0
        assert res.head.threshold == res.threshold
        assert 0.0 <= res.calib_fpr <= 0.15
        assert len(res.history) >= 1
        assert res.best_val_mse > 0

    def test_labels_drop_attack_windows(self):
        """With labels, attack windows never reach training; detection rate
        is reported against them."""
        rng = np.random.default_rng(1)
        normal = rng.normal(size=(800, 400)).astype(np.float32)
        attacks = rng.normal(loc=25.0, size=(50, 400)).astype(np.float32)
        x = np.concatenate([normal, attacks])
        y = np.concatenate([np.zeros(800, np.int64), np.ones(50, np.int64)])
        model, res = train_autoencoder(x, y, epochs=2, batch_size=128,
                                       patience=2)
        # off-manifold attacks reconstruct badly -> all flagged
        assert res.test_detection_rate == 1.0

    def test_too_few_benign_windows_rejected(self):
        with pytest.raises(ValueError):
            train_autoencoder(np.zeros((10, 400), np.float32), None,
                              batch_size=256)


@pytest.mark.slow
class TestEndToEndAutoencoder:
    def test_fleet_detection_regression(self):
        """Seeded small-budget train -> calibrate -> port -> quantize ->
        serve: the unsupervised path must flag attacked plants after onset
        and respect the FPR budget on the benign one."""
        import tempfile
        from repro.core import porting
        from repro.sim import build_dataset, build_fleet, get_scenario

        x, y = build_dataset(normal_cycles=8000, attack_cycles=2500,
                             stride=8, seed=0)
        model, res = train_autoencoder(x, y, epochs=30, patience=30, lr=1e-3)
        assert res.test_detection_rate > 0.5
        with tempfile.TemporaryDirectory() as tmp:
            model, params = porting.port_mlp(model, res.params, tmp)
        params = quantize.quantize_params(
            model, params, "SINT",
            calibration=quantize.calibration_samples(x, y))
        # threshold re-calibrated on the quantized model's scores over the
        # SAME held-out normal windows the REAL threshold came from
        from repro.sim import recalibrate_threshold
        head, _ = recalibrate_threshold(model, params, res.calib_windows,
                                        target_fpr=0.01)

        names = ["baseline", "recycle-starve", "tb0-spoof", "steam-throttle"]
        fleet = build_fleet(names, seed=4242, jitter=0.0)
        eng = StreamEngine(model, params, n_streams=len(fleet), head=head)
        eng.warmup()
        verdicts = eng.run(fleet, 1400)

        by_stream = {}
        for v in verdicts:
            by_stream.setdefault(v.stream, []).append(v)
        for i, name in enumerate(names):
            onset = get_scenario(name).onset
            vs = by_stream[i]
            if onset is None:
                fp = sum(v.pred != 0 for v in vs) / len(vs)
                assert fp < 0.25, f"{name}: false-positive rate {fp:.2f}"
            else:
                post = [v for v in vs if v.cycle >= onset]
                assert any(v.pred != 0 for v in post), \
                    f"{name}: attack never flagged"
