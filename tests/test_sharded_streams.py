"""Sharded-parity suite for the stream-axis fleet sharding of StreamEngine.

The sharded engine (ring arena + detector step partitioned over a
``("data",)`` fleet mesh, one shard_map'd step per device) must serve
*identically* to the classic unsharded engine: verdicts bit-match under REAL
and epsilon-match under SINT/INT/DINT, over scenario runs long enough to wrap
the ring, at 1/2/4 host devices, and for fleet sizes not divisible by the
device count (the pad-stream contract).

Device counts above the process's visible device count skip; the CI
``tier1-multidevice`` job runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so every count runs.
A subprocess test keeps 4-device coverage alive even in single-device runs.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.launch.mesh import make_fleet_mesh
from repro.serving import StreamEngine
from repro.sim import fleet_readings

from test_fused import count_pallas_calls, detector_params, small_detector
from test_streams import identity_probe

SCHEMES = ("REAL", "SINT", "INT", "DINT")
N_DEVICES = len(jax.devices())
DEVICE_COUNTS = [n for n in (1, 2, 4) if n <= N_DEVICES]


def needs(n_devices):
    return pytest.mark.skipif(
        N_DEVICES < n_devices,
        reason=f"needs {n_devices} host devices "
               "(XLA_FLAGS=--xla_force_host_platform_device_count)")


# (devices, streams) grid: every multi-device count paired with a divisible
# fleet and one that is NOT divisible (pad-stream contract).
DEVICE_FLEETS = [
    pytest.param(1, 3, id="d1-s3"),
    pytest.param(2, 4, id="d2-s4", marks=needs(2)),
    pytest.param(2, 5, id="d2-s5-pad", marks=needs(2)),
    pytest.param(4, 8, id="d4-s8", marks=needs(4)),
    pytest.param(4, 6, id="d4-s6-pad", marks=needs(4)),
    pytest.param(4, 3, id="d4-s3-pad", marks=needs(4)),
]


def drive_batches(eng, readings):
    """[(cycle, verdicts, logits)] per verdict batch over a (C, S, F) run."""
    out = []
    for c in range(readings.shape[0]):
        vs = eng.ingest(readings[c])
        if vs:
            out.append((c, vs, eng.last_logits.copy()))
    return out


def engine_pair(model, params, n_streams, *, n_devices, window, stride,
                **kw):
    """(unsharded, sharded-over-n_devices) engines with identical knobs."""
    base = StreamEngine(model, params, n_streams=n_streams, n_features=2,
                        window=window, stride=stride, shard=False, **kw)
    shard = StreamEngine(model, params, n_streams=n_streams, n_features=2,
                         window=window, stride=stride,
                         mesh=make_fleet_mesh(n_devices), **kw)
    return base, shard


def assert_batches_match(got, want, *, exact):
    assert [(c, [(v.stream, v.cycle, v.pred) for v in vs])
            for c, vs, _ in got] == \
           [(c, [(v.stream, v.cycle, v.pred) for v in vs])
            for c, vs, _ in want]
    for (_, gvs, gl), (_, wvs, wl) in zip(got, want):
        if exact:
            np.testing.assert_array_equal(gl, wl)
            assert [v.prob for v in gvs] == [v.prob for v in wvs]
        else:
            np.testing.assert_allclose(gl, wl, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose([v.prob for v in gvs],
                                       [v.prob for v in wvs],
                                       rtol=1e-5, atol=1e-5)


class TestShardedParity:
    @pytest.mark.parametrize("n_devices,n_streams", DEVICE_FLEETS)
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_small_detector_parity(self, n_devices, n_streams, scheme):
        """Sharded == unsharded verdict-for-verdict over a ring-wraparound
        scenario run, bit-exact under REAL, within epsilon quantized."""
        model, params = small_detector(scheme, seed=n_devices + n_streams)
        window, stride = 4, 3
        readings = fleet_readings(n_streams, window + 26,
                                  seed=17 * n_devices + n_streams)
        base, shard = engine_pair(model, params, n_streams,
                                  n_devices=n_devices, window=window,
                                  stride=stride)
        assert shard.n_shards == n_devices
        want = drive_batches(base, readings)
        got = drive_batches(shard, readings)
        assert len(got) == len(want) >= 9       # the ring wrapped
        # REAL is bit-exact except when a shard holds a single stream: XLA
        # lowers the per-shard M=1 forward as gemv, whose accumulation
        # order differs from the unsharded gemm in the last ulp.
        assert_batches_match(
            got, want,
            exact=(scheme == "REAL" and shard.shard_streams > 1))

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_full_detector_wraparound_regression(self, scheme):
        """Pinned full-size run: 430 cycles wraps the 200-reading ring; the
        widest available mesh serves a non-divisible 6-plant fleet."""
        n_devices = DEVICE_COUNTS[-1]
        model, params = detector_params(scheme, seed=1)
        readings = fleet_readings(6, 430, seed=11)
        base, shard = engine_pair(model, params, 6, n_devices=n_devices,
                                  window=200, stride=10)
        want = drive_batches(base, readings)
        got = drive_batches(shard, readings)
        assert len(got) == len(want) == 24
        assert_batches_match(got, want, exact=(scheme == "REAL"))

    @pytest.mark.parametrize("n_devices,n_streams", DEVICE_FLEETS)
    def test_pad_streams_never_surface(self, n_devices, n_streams):
        """Pad-stream contract: padded arenas emit exactly n_streams
        verdicts per batch, stats count real streams only, and logits are
        sliced to the real fleet."""
        model, params = small_detector("REAL", seed=0)
        eng = StreamEngine(model, params, n_streams=n_streams, n_features=2,
                           window=4, stride=2, mesh=make_fleet_mesh(n_devices))
        pad = -(-n_streams // n_devices) * n_devices
        assert eng.shard_streams * eng.n_shards == pad
        assert eng._ring.shape[0] == pad
        readings = fleet_readings(n_streams, 10, seed=3)
        batches = drive_batches(eng, readings)
        assert len(batches) == 4                 # cycles 3,5,7,9
        for _, vs, logits in batches:
            assert logits.shape[0] == n_streams
            assert {v.stream for v in vs} == set(range(n_streams))
        assert eng.stats.windows == 4 * n_streams
        assert eng.stats.steps == 4

    def test_warmup_compiles_sharded_shapes(self):
        """warmup() on a sharded engine pre-compiles both block lengths with
        the serve-time arena sharding (steady-state steps reuse them)."""
        n_devices = DEVICE_COUNTS[-1]
        model, params = small_detector("SINT", seed=2)
        eng = StreamEngine(model, params, n_streams=5, n_features=2,
                           window=4, stride=3, mesh=make_fleet_mesh(n_devices))
        eng.warmup()
        readings = fleet_readings(5, 12, seed=5)
        assert drive_batches(eng, readings)
        assert eng.stats.steps == 3

    def test_auto_mesh_never_wider_than_fleet(self):
        """Auto-sharding caps the mesh at the fleet size — pure-pad shards
        would burn a dispatch per device on zero streams."""
        model, params = small_detector("REAL", seed=0)
        eng = StreamEngine(model, params, n_streams=2, n_features=2, window=4)
        assert eng.n_shards == (min(2, N_DEVICES) if N_DEVICES > 1 else 1)

    def test_shard_flag_validation(self):
        model, params = small_detector("REAL", seed=0)
        with pytest.raises(ValueError):
            StreamEngine(model, params, n_streams=2, n_features=2, window=4,
                         shard=False, mesh=make_fleet_mesh(1))
        from repro.launch.mesh import make_host_mesh
        # a ("data", "model") mesh is fine while model has size 1
        eng = StreamEngine(model, params, n_streams=2, n_features=2, window=4,
                           mesh=make_host_mesh())
        assert eng.n_shards == 1


class TestShardedWindowing:
    """The identity-probe model of test_streams, re-run through the sharded
    ring scatter: window contents under sharding equal naive slicing of the
    raw stream for random interleavings, including non-divisible fleets."""

    @settings(max_examples=15, deadline=None)
    @given(window=st.integers(3, 8), stride=st.integers(1, 4),
           n_streams=st.integers(1, 6), extra=st.integers(0, 20),
           n_devices=st.sampled_from(DEVICE_COUNTS))
    def test_sharded_windows_equal_naive_slicing(self, window, stride,
                                                 n_streams, extra, n_devices):
        n_features = 2
        model, params = identity_probe(window, n_features)
        eng = StreamEngine(model, params, n_streams=n_streams,
                           n_features=n_features, window=window,
                           stride=stride, mesh=make_fleet_mesh(n_devices),
                           norm_mean=(0.0,) * n_features,
                           norm_std=(1.0,) * n_features)
        n_cycles = window + extra
        rng = np.random.default_rng(
            window * 1000 + stride * 100 + n_streams * 10 + extra + n_devices)
        readings = rng.normal(size=(n_cycles, n_streams, n_features)) \
            .astype(np.float32)
        batches = drive_batches(eng, readings)
        assert len(batches) == (n_cycles - window) // stride + 1
        for cycle, _, logits in batches:
            want = readings[cycle - window + 1:cycle + 1]      # (W, S, F)
            want = want.transpose(1, 0, 2).reshape(n_streams, -1)
            np.testing.assert_allclose(logits, want, rtol=0, atol=0)


class TestShardedDispatch:
    """The single-dispatch guarantee survives sharding: each device shard of
    the verdict step runs ONE pallas_call for all-Dense models (the fused
    kernel executes per shard, inside shard_map)."""

    @pytest.mark.parametrize("n_streams", (16, 6))
    def test_sharded_fused_step_is_one_dispatch_per_shard(self, n_streams):
        model, params = detector_params("SINT")
        eng = StreamEngine(model, params, n_streams=n_streams,
                           backend="pallas", fused=True,
                           mesh=make_fleet_mesh(DEVICE_COUNTS[-1]))
        ring = jnp.zeros((eng._s_pad, eng.window, 2), jnp.float32)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 1

    def test_sharded_per_layer_step_dispatch_count(self):
        model, params = detector_params("SINT")
        eng = StreamEngine(model, params, n_streams=16, backend="pallas",
                           fused=False, mesh=make_fleet_mesh(DEVICE_COUNTS[-1]))
        ring = jnp.zeros((eng._s_pad, eng.window, 2), jnp.float32)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 4


_SUBPROCESS_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
    " --xla_force_host_platform_device_count=4").strip()
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
import jax
assert len(jax.devices()) == 4, jax.devices()
from repro.launch.mesh import make_fleet_mesh
from repro.serving import StreamEngine
from repro.sim import fleet_readings
from test_fused import small_detector

for scheme in ("REAL", "SINT"):
    model, params = small_detector(scheme, seed=3)
    readings = fleet_readings(6, 24, seed=7)           # 6 plants, 4 devices
    logits = {}
    for key, kw in (("base", {"shard": False}),
                    ("shard", {"mesh": make_fleet_mesh(4)})):
        eng = StreamEngine(model, params, n_streams=6, n_features=2,
                           window=4, stride=3, **kw)
        for c in range(readings.shape[0]):
            eng.ingest(readings[c])
        logits[key] = eng.last_logits
    if scheme == "REAL":
        np.testing.assert_array_equal(logits["shard"], logits["base"])
    else:
        np.testing.assert_allclose(logits["shard"], logits["base"],
                                   rtol=1e-5, atol=1e-5)
print("SHARDED_PARITY_OK")
"""


@pytest.mark.skipif(N_DEVICES >= 4,
                    reason="in-process tests already cover 4 devices")
def test_four_device_parity_subprocess():
    """Single-device environments still certify 4-way sharding: a child
    process fans out host devices via XLA_FLAGS and re-checks parity on a
    non-divisible fleet."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.dirname(__file__)] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PARITY],
                         env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "SHARDED_PARITY_OK" in out.stdout
