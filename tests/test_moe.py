"""MoE dispatch: einsum (GShard) vs ragged, routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite_moe_1b_a400m").reduced().with_(
        dtype=jnp.float32, capacity_factor=8.0)  # high cf: no drops
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    return cfg, p, x


class TestDispatchEquivalence:
    def test_einsum_matches_ragged_without_drops(self, setup):
        cfg, p, x = setup
        out_e, aux_e = moe.moe_forward_einsum(p, cfg, x, group=64)
        out_r, aux_r = moe.moe_forward_ragged(p, cfg, x)
        np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(float(aux_e), float(aux_r), rtol=1e-5)

    def test_capacity_drops_tokens(self, setup):
        cfg, p, x = setup
        tight = cfg.with_(capacity_factor=0.25)
        out_tight, _ = moe.moe_forward_einsum(p, tight, x, group=64)
        out_loose, _ = moe.moe_forward_einsum(p, cfg, x, group=64)
        # dropping changes the output
        assert float(jnp.abs(out_tight - out_loose).max()) > 1e-5

    def test_gate_weights_normalized(self, setup):
        cfg, p, x = setup
        gate, idx, aux = moe._route(p, cfg, x.reshape(1, -1, cfg.d_model))
        s = np.asarray(gate.sum(-1))
        np.testing.assert_allclose(s, np.ones_like(s), rtol=1e-5)
        assert float(aux) > 0.0

    def test_topk_indices_valid(self, setup):
        cfg, p, x = setup
        _, idx, _ = moe._route(p, cfg, x.reshape(1, -1, cfg.d_model))
        assert int(idx.max()) < cfg.n_experts
        assert idx.shape[-1] == cfg.top_k


class TestMoEModel:
    def test_aux_loss_in_training_loss(self, setup):
        cfg, _, _ = setup
        params = moe.model_init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        loss = moe.loss_fn(params, cfg, batch)
        logits, aux = moe.forward_logits(params, cfg, toks)
        from repro.models import common as cm
        ce = cm.cross_entropy(logits, toks)
        np.testing.assert_allclose(float(loss), float(ce) + moe.AUX_WEIGHT * float(aux),
                                   rtol=1e-5)
