"""Async double-buffered serving (``async_depth=1``): verdict parity suite.

The contract (serving/core.py): at a ready boundary the async engine first
harvests the previous step's in-flight outputs, then dispatches the new
step and returns — so verdicts arrive one boundary late but must be
**bit-identical** to synchronous mode (same executables, same operands,
same adapt-threshold ordering), across stride/window/adapt/ring-wraparound
compositions, grouped fleets, and sharded meshes.  ``flush()`` drains the
final in-flight step; latency/deadline accounting moves to
dispatch→harvest; the one-dispatch-per-step jaxpr guarantee is untouched.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.launch.mesh import make_fleet_mesh
from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine
from repro.sim import ReconstructionHead, fleet_readings
from test_drift import energy_detector
from test_fused import count_pallas_calls, detector_params, small_detector
from test_streams import identity_probe

N_DEVICES = len(jax.devices())


def verdict_key(v):
    """Everything a verdict says except its timing (latency/deadline are
    mode-dependent by design)."""
    return (v.stream, v.cycle, v.pred, v.prob, v.score, v.threshold, v.group)


def serve(eng, readings, flush=True):
    out = []
    for c in range(readings.shape[0]):
        out.extend(eng.ingest(readings[c]))
    if flush:
        out.extend(eng.flush())
    return out


def assert_verdicts_match(sync_vs, async_vs):
    assert len(sync_vs) == len(async_vs) > 0
    for a, b in zip(sync_vs, async_vs):
        assert verdict_key(a) == verdict_key(b)


class TestAsyncParity:
    @settings(max_examples=15, deadline=None)
    @given(window=st.integers(3, 8), stride=st.integers(1, 5),
           extra=st.integers(0, 20), adapt=st.booleans())
    def test_async_bit_matches_sync(self, window, stride, extra, adapt):
        """The hypothesis property: over arbitrary window/stride/wraparound
        compositions, with and without streaming threshold adaptation, the
        async verdict stream (+ flush) equals the sync one verdict-for-
        verdict — scores, thresholds and live-threshold trajectory
        bit-exact."""
        n_streams, n_feat = 3, 1
        model, params = energy_detector(window, n_feat)
        head_kw = dict(threshold=0.7, target_fpr=0.1)
        kw = dict(n_streams=n_streams, n_features=n_feat, window=window,
                  stride=stride, norm_mean=(0.0,), norm_std=(1.0,),
                  shard=False, adapt=adapt)
        rng = np.random.default_rng(window * 100 + stride * 10 + extra)
        readings = rng.normal(size=(window + extra, n_streams, n_feat)) \
            .astype(np.float32)
        engines = {}
        for depth in (0, 1):
            eng = StreamEngine(model, params,
                               head=ReconstructionHead(**head_kw),
                               async_depth=depth, **kw)
            engines[depth] = (eng, serve(eng, readings))
        (sync, sv), (asy, av) = engines[0], engines[1]
        assert_verdicts_match(sv, av)
        assert sync.stats.windows == asy.stats.windows
        assert sync.stats.steps == asy.stats.steps
        assert sync.live_threshold == asy.live_threshold

    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_classifier_fleet_parity(self, scheme):
        """Scenario fleet + classifier head (small detector), quantized and
        float."""
        model, params = small_detector(scheme, seed=2)
        readings = fleet_readings(4, 33, seed=5)
        kw = dict(n_streams=4, n_features=2, window=4, stride=3, shard=False)
        sync = StreamEngine(model, params, **kw)
        asy = StreamEngine(model, params, async_depth=1, **kw)
        assert_verdicts_match(serve(sync, readings), serve(asy, readings))

    def test_one_boundary_delay_and_flush(self):
        """The async schedule itself: a ready boundary returns the PREVIOUS
        boundary's verdicts (first one returns []), flush returns the final
        in-flight batch exactly once."""
        window, stride, n = 4, 3, 2
        model, params = identity_probe(window, 2)
        eng = StreamEngine(model, params, n_streams=n, n_features=2,
                           window=window, stride=stride, shard=False,
                           norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0),
                           async_depth=1)
        rng = np.random.default_rng(0)
        boundaries = {}
        for c in range(10):                      # ready at cycles 3, 6, 9
            vs = eng.ingest(rng.normal(size=(n, 2)).astype(np.float32))
            if vs:
                boundaries[c] = sorted({v.cycle for v in vs})
        assert boundaries == {6: [3], 9: [6]}    # one boundary late
        assert eng.stats.steps == 3              # cycle 9's step in flight
        assert eng.stats.windows == 2 * n
        flushed = eng.flush()
        assert sorted({v.cycle for v in flushed}) == [9]
        assert eng.stats.windows == 3 * n
        assert eng.flush() == []                 # drain is idempotent

    def test_sync_flush_is_noop(self):
        model, params = identity_probe(3, 2)
        eng = StreamEngine(model, params, n_streams=2, n_features=2,
                           window=3, stride=1, shard=False,
                           norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0))
        assert eng.flush() == []
        rng = np.random.default_rng(1)
        for c in range(5):
            eng.ingest(rng.normal(size=(2, 2)).astype(np.float32))
        assert eng.flush() == []
        assert eng.stats.windows == 3 * 2

    def test_async_depth_validation(self):
        model, params = identity_probe(3, 2)
        with pytest.raises(ValueError, match="async_depth"):
            StreamEngine(model, params, n_streams=2, n_features=2, window=3,
                         shard=False, async_depth=2)

    def test_latency_accounting_is_dispatch_to_harvest(self):
        """Async latencies span the whole inter-boundary interval (the
        overlapped host ingest is genuine verdict-visibility delay), and
        misses are judged against that span."""
        model, params = identity_probe(3, 2)
        eng = StreamEngine(model, params, n_streams=2, n_features=2,
                           window=3, stride=2, shard=False, deadline_s=1e-9,
                           norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0),
                           async_depth=1)
        rng = np.random.default_rng(2)
        vs = serve(eng, rng.normal(size=(7, 2, 2)).astype(np.float32))
        assert all(v.latency_s > 0 for v in vs)
        assert all(v.deadline_miss for v in vs)  # 1ns deadline always missed
        assert eng.stats.deadline_misses == eng.stats.windows == len(vs)
        assert len(eng.stats.latencies_s) == eng.stats.steps


class TestAsyncGrouped:
    def test_grouped_async_matches_sync(self):
        """Mixed-head, mixed-window grouped fleet: async == sync verdict-
        for-verdict, including the adaptive group's threshold trajectory."""
        det_model, det_params = small_detector("SINT", seed=1)
        ae_model, ae_params = energy_detector(6, 2)
        readings = fleet_readings(5, 40, seed=9)

        def make(depth):
            return GroupedStreamEngine(
                [ModelGroup("det", det_model, det_params, 3),
                 ModelGroup("ae", ae_model, ae_params, 2,
                            head=ReconstructionHead(threshold=2.0,
                                                    target_fpr=0.1),
                            adapt=True)],
                n_features=2, stride=3, shard=False, async_depth=depth)

        sync, asy = make(0), make(1)
        assert_verdicts_match(serve(sync, readings), serve(asy, readings))
        assert sync.group_windows() == asy.group_windows()
        assert sync.live_thresholds() == asy.live_thresholds()

    def test_run_interface_with_flush(self):
        """run() drives async engines too (no auto-flush — the final step
        stays in flight until flush())."""
        class _Reading:
            def __init__(self, a, b):
                self.tb0_meas, self.wd_meas = a, b

        class _Stream:
            def __init__(self, seed):
                self.rng = np.random.default_rng(seed)

            def step(self):
                return _Reading(self.rng.normal(), self.rng.normal())

        model, params = small_detector("REAL", seed=0)
        kw = dict(n_streams=2, n_features=2, window=4, stride=2, shard=False)
        sync = StreamEngine(model, params, **kw)
        asy = StreamEngine(model, params, async_depth=1, **kw)
        sv = sync.run([_Stream(0), _Stream(1)], 12)
        av = asy.run([_Stream(0), _Stream(1)], 12)
        assert len(av) == len(sv) - 2            # one boundary in flight
        av += asy.flush()
        assert_verdicts_match(sv, av)


class TestAsyncSharded:
    @pytest.mark.parametrize("n_devices",
                             [n for n in (1, 2, 4) if n <= N_DEVICES])
    def test_sharded_async_matches_sync(self, n_devices):
        """The pipeline composes with the ("data",) mesh: async verdicts on
        a non-divisible padded fleet bit-match the sync sharded engine."""
        model, params = small_detector("REAL", seed=3)
        readings = fleet_readings(5, 30, seed=4)
        engines = {}
        for depth in (0, 1):
            eng = StreamEngine(model, params, n_streams=5, n_features=2,
                               window=4, stride=3,
                               mesh=make_fleet_mesh(n_devices),
                               async_depth=depth)
            eng.warmup()
            engines[depth] = serve(eng, readings)
        assert_verdicts_match(engines[0], engines[1])


class TestAsyncDispatch:
    def test_one_dispatch_per_step_preserved(self):
        """async_depth changes host scheduling only: the traced verdict
        step of an async fused engine is still exactly ONE pallas_call."""
        model, params = detector_params("SINT")
        eng = StreamEngine(model, params, n_streams=4, backend="pallas",
                           fused=True, shard=False, async_depth=1)
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_pallas_calls(jaxpr.jaxpr) == 1
