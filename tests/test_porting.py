"""Porting methodology (§4.3): BINARR/ARRBIN + extract/reconstruct/load."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layers as L, porting, sequential


class TestBinaryIO:
    def test_arrbin_binarr_roundtrip(self, tmp_path):
        arr = np.random.default_rng(0).normal(size=(13, 7)).astype(np.float32)
        path = str(tmp_path / "a.bin")
        nbytes = porting.arrbin(path, arr)
        assert nbytes == arr.nbytes == os.path.getsize(path)
        back = porting.binarr(path, np.float32, (13, 7))
        np.testing.assert_array_equal(back, arr)

    def test_binarr_size_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "b.bin")
        porting.arrbin(path, np.zeros(10, np.float32))
        with pytest.raises(ValueError):
            porting.binarr(path, np.float32, (11,))

    def test_int_dtypes(self, tmp_path):
        arr = np.arange(-8, 8, dtype=np.int8)
        path = str(tmp_path / "c.bin")
        porting.arrbin(path, arr)
        np.testing.assert_array_equal(porting.binarr(path, np.int8, (16,)), arr)


class TestPortMLP:
    def test_roundtrip_bit_identical(self, tmp_path, key):
        trained = sequential(
            [L.Input(),
             L.Dense(units=64, activation="relu"),
             L.Dense(units=32, activation="relu"),
             L.Dense(units=2, activation="linear")], (400,))
        params = trained.init_params(key)
        ported, ported_params = porting.port_mlp(trained, params, str(tmp_path))

        x = jax.random.normal(jax.random.PRNGKey(9), (400,))
        a = trained.apply(params, x)
        b = ported.apply(ported_params, x)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_exported_files_exist(self, tmp_path, key):
        m = sequential([L.Input(), L.Dense(units=4)], (8,))
        p = m.init_params(key)
        paths = porting.export_weights(porting.extract_mlp_weights(p, m),
                                       str(tmp_path))
        assert all(os.path.exists(pth) for pth in paths)
        assert any("L0_weights" in pth for pth in paths)

    def test_build_mlp_shapes(self):
        m = porting.build_mlp([64, 32, 2], 400, ["relu", "relu", "linear"])
        shapes = m.graph.infer_shapes((400,))
        assert shapes[m.graph.output_uid] == (2,)
