"""Per-architecture smoke tests: reduced variant of each assigned config runs
one forward/train step on CPU, asserting output shapes + no NaNs (required
deliverable f), plus prefill/decode consistency for one arch per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import get_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= max(cfg.attn_period, 2)
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = api.init_batch("train", 2, 64, jax.random.PRNGKey(1))

    loss = api.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    opt_init, opt_update = make_optimizer()
    opt_state = opt_init(params)
    step = jax.jit(make_train_step(api, opt_update))
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, ab: acc or bool(jnp.any(ab)),
        jax.tree.map(lambda a, b: jnp.any(a != b)
                     if jnp.issubdtype(a.dtype, jnp.floating) else False,
                     params, params2),
        False)
    assert moved, f"{arch}: train step did not update parameters"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache_len = 32
    pb = api.init_batch("prefill", 2, 16, jax.random.PRNGKey(2))
    cache, logits = api.prefill(params, pb, cache_len)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert not bool(jnp.isnan(logits).any())
    db = api.init_batch("decode", 2, 16, jax.random.PRNGKey(3))
    cache, lg = api.decode(params, cache, db, jnp.int32(16))
    assert lg.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ["qwen3_8b", "mamba2_370m", "mixtral_8x22b"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode must agree with the teacher-forced forward pass."""
    cfg = get_config(arch).reduced().with_(dtype=jnp.float32)
    if cfg.n_experts:
        # no-drop capacity: prefill/decode group tokens differently, so
        # capacity-induced drops would (legitimately) diverge the paths
        cfg = cfg.with_(capacity_factor=8.0)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 17), 0, cfg.vocab)

    if cfg.family == "ssm":
        from repro.models import mamba2 as mod
        full = mod.forward_logits(params, cfg, toks)
    elif cfg.family == "moe":
        from repro.models import moe as mod
        full, _ = mod.forward_logits(params, cfg, toks)
    else:
        from repro.models import transformer as mod
        full = mod.forward_logits(params, cfg, toks)

    cache, lg_pre = api.prefill(params, {"tokens": toks[:, :16]}, 32)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]), np.asarray(full[:, 15]),
                               rtol=2e-4, atol=2e-4)
    cache, lg_dec = api.decode(params, cache, {"tokens": toks[:, 16:17]},
                               jnp.int32(16))
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]), np.asarray(full[:, 16]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_old_tokens():
    """SWA variant must ignore tokens beyond the window."""
    cfg = get_config("qwen3_8b").reduced().with_(dtype=jnp.float32,
                                                 sliding_window=4)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    from repro.models import transformer as tf
    full = tf.forward_logits(params, cfg, toks)
    # perturbing a token outside the window of the last position changes
    # nothing; inside the window it does
    toks_far = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab)
    toks_near = toks.at[0, 10].set((toks[0, 10] + 1) % cfg.vocab)
    out_far = tf.forward_logits(params, cfg, toks_far)
    out_near = tf.forward_logits(params, cfg, toks_near)
    np.testing.assert_allclose(np.asarray(out_far[:, -1]),
                               np.asarray(full[:, -1]), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(out_near[:, -1] - full[:, -1]).max()) > 1e-4


def test_vlm_prefix_changes_text_logits():
    cfg = get_config("llava_next_34b").reduced().with_(dtype=jnp.float32)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b = api.init_batch("train", 1, 32, jax.random.PRNGKey(1))
    loss1 = api.loss(params, b)
    b2 = dict(b, image_emb=b["image_emb"] + 1.0)
    loss2 = api.loss(params, b2)
    assert abs(float(loss1) - float(loss2)) > 1e-6


def test_whisper_cross_attention_sees_frames():
    cfg = get_config("whisper_base").reduced().with_(dtype=jnp.float32)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b = api.init_batch("train", 1, 16, jax.random.PRNGKey(1))
    loss1 = api.loss(params, b)
    loss2 = api.loss(params, dict(b, frames=b["frames"] * 2.0))
    assert abs(float(loss1) - float(loss2)) > 1e-6
