"""Grouped megakernel: the whole heterogeneous fleet in ONE dispatch.

Acceptance: a packable multi-group fleet's verdict step lowers to exactly
ONE ``pallas_call`` — proven in the jaxpr for a 4-group fleet, sharded and
unsharded — and the megakernel's verdicts bit-match (REAL) / epsilon-match
(quantized) the per-group path over ring-wraparound runs for all four head
types.  Sharded REAL agreement is epsilon-level, mirroring the seed
contract of ``test_grouped.TestGroupedParity.test_sharded_matches_unsharded``
(XLA rounds 1 ulp differently across fusion contexts), which is why the
engine auto-packs only unsharded fleets and sharded megakernel serving is
the explicit ``megakernel=True`` opt-in.

Also covered here: the packed-arena VMEM / MXU-mode fuse reasons
(``ops.grouped_fuse_reason``), the in-kernel masked final-layer softmax
(closing the softmax-fold roadmap item), the block-shape step cache +
warmup compile counts, and the ``StreamStats.dispatches`` accounting.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import sequential
from repro.kernels import ops, ref
from repro.launch.mesh import make_fleet_mesh
from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine
from repro.sim import ReconstructionHead

from test_fused import count_pallas_calls
from test_grouped import NO_NORM, SCHEMES, mixed_groups, small_model

N_DEVICES = len(jax.devices())


def drive(engine, n_cycles, *, seed=0):
    """Feed identical pseudo-random readings and collect every verdict
    (flush drains the async tail, a no-op in sync mode)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_cycles):
        r = rng.normal(size=(engine.n_streams, 2)).astype(np.float32)
        out.extend(engine.ingest(r.copy()))
    out.extend(engine.flush())
    return out


def assert_verdicts_match(va, vb, scheme, *, bitwise=None):
    """Same verdict stream from two engine configurations: bit for REAL
    (unless ``bitwise=False`` opts into the sharded epsilon contract),
    epsilon for quantized schemes."""
    bitwise = (scheme == "REAL") if bitwise is None else bitwise
    assert len(va) == len(vb) > 0
    for a, b in zip(va, vb):
        assert (a.stream, a.cycle, a.group) == (b.stream, b.cycle, b.group)
        assert a.threshold == b.threshold
        assert (a.prob is None) == (b.prob is None)
        assert (a.score is None) == (b.score is None)
        if bitwise:
            assert a.pred == b.pred
            assert a.prob == b.prob and a.score == b.score
        else:
            for x, y in ((a.prob, b.prob), (a.score, b.score)):
                if x is not None:
                    np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)


def engine_pair(scheme, *, mega_kw=None, per_kw=None, groups=None, **kw):
    """(megakernel engine, per-group engine) over identical fleets."""
    base = dict(NO_NORM, n_features=2, stride=3, **kw)
    ge = GroupedStreamEngine(groups or mixed_groups(scheme),
                             **dict(base, **(mega_kw or {})))
    pg = GroupedStreamEngine(groups or mixed_groups(scheme),
                             megakernel=False, **dict(base, **(per_kw or {})))
    return ge, pg


class TestMegaParity:
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_matches_pergroup_over_wraparound(self, scheme):
        """7 ring wraps (window 4, 30 cycles, stride 3) across all four
        head types: megakernel verdicts == per-group verdicts, bit for
        REAL, epsilon for quantized schemes."""
        ge, pg = engine_pair(scheme, shard=False)
        assert ge._mega and not pg._mega
        va, vb = drive(ge, 30), drive(pg, 30)
        assert_verdicts_match(va, vb, scheme)
        for name in pg.last_outputs:
            if scheme == "REAL":
                np.testing.assert_array_equal(ge.last_outputs[name],
                                              pg.last_outputs[name])
            else:
                np.testing.assert_allclose(ge.last_outputs[name],
                                           pg.last_outputs[name],
                                           rtol=1e-5, atol=1e-5)

    def test_async_mega_matches_sync(self):
        """The double-buffered megakernel pipeline bit-matches sync mode
        (the serving/core async contract holds for the mega step too)."""
        a = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                stride=3, shard=False, async_depth=1,
                                **NO_NORM)
        s = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                stride=3, shard=False, **NO_NORM)
        assert a._mega and s._mega
        assert_verdicts_match(drive(a, 24), drive(s, 24), "REAL")

    def test_heterogeneous_windows_fall_back_per_boundary(self):
        """Groups whose ring windows differ can never stack: the engine
        packs, but every ready boundary falls back to the per-group step —
        verdicts stay bit-identical and no mega step is ever compiled."""
        def groups():
            return [
                ModelGroup("w4", *small_model(8, 8, "REAL", 0), 2,
                           ReconstructionHead(threshold=0.5)),
                ModelGroup("w5", *small_model(10, 10, "REAL", 1), 2,
                           ReconstructionHead(threshold=0.5)),
            ]
        ge = GroupedStreamEngine(groups(), n_features=2, stride=3,
                                 shard=False, **NO_NORM)
        pg = GroupedStreamEngine(groups(), n_features=2, stride=3,
                                 shard=False, megakernel=False, **NO_NORM)
        assert ge._mega
        assert_verdicts_match(drive(ge, 27), drive(pg, 27), "REAL")
        assert not ge._mega_steps
        assert ge.stats.dispatches == pg.stats.dispatches

    @pytest.mark.skipif(N_DEVICES < 2, reason="needs a multi-device process")
    def test_auto_stays_pergroup_under_mesh(self):
        """Default sharded serving is bit-identical to the seed: the
        megakernel needs the explicit opt-in under a mesh."""
        mesh = make_fleet_mesh(2)
        auto = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                   mesh=mesh, **NO_NORM)
        assert not auto._mega and auto._mega_reason is None
        forced = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                     mesh=mesh, megakernel=True, **NO_NORM)
        assert forced._mega

    @pytest.mark.skipif(N_DEVICES < 2, reason="needs a multi-device process")
    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_forced_sharded_matches_pergroup(self, scheme):
        """``megakernel=True`` on a fleet mesh: one dispatch per step,
        verdicts match the sharded per-group path and the unsharded
        megakernel at the seed's sharded tolerance (rtol 1e-5 — the
        ``test_sharded_matches_unsharded`` contract)."""
        mesh = make_fleet_mesh(2)
        ge, pg = engine_pair(scheme, mesh=mesh,
                             mega_kw={"megakernel": True})
        assert ge._mega and not pg._mega
        vs, vp = drive(ge, 30), drive(pg, 30)
        assert_verdicts_match(vs, vp, scheme, bitwise=False)
        gu = GroupedStreamEngine(mixed_groups(scheme), n_features=2,
                                 stride=3, shard=False, **NO_NORM)
        assert_verdicts_match(vs, drive(gu, 30), scheme, bitwise=False)
        assert ge.stats.dispatches == ge.stats.steps
        assert pg.stats.dispatches == pg.stats.steps * 4

    @pytest.mark.skipif(N_DEVICES < 2, reason="needs a multi-device process")
    def test_pad_stream_contract(self):
        """Group sizes that don't divide the mesh: pad rows ride through the
        stacked mega arena but never surface in verdicts or last_outputs."""
        mesh = make_fleet_mesh(2)
        ge = GroupedStreamEngine(mixed_groups("REAL", n_per=3),
                                 n_features=2, stride=3, mesh=mesh,
                                 megakernel=True, **NO_NORM)
        pg = GroupedStreamEngine(mixed_groups("REAL", n_per=3),
                                 n_features=2, stride=3, shard=False,
                                 megakernel=False, **NO_NORM)
        assert ge._mega
        vs = drive(ge, 18)
        assert all(r.shape[0] == 4 for r in ge._rings)
        assert {v.stream for v in vs} == set(range(12))
        assert all(ge.last_outputs[n].shape[0] == 3 for n in ge.last_outputs)
        assert_verdicts_match(vs, drive(pg, 18), "REAL", bitwise=False)


class TestSingleDispatch:
    """Acceptance: ONE pallas_call per megakernel step for a 4-group fleet,
    in the jaxpr, sharded and unsharded (vs 4 for the per-group step)."""

    def _mega_jaxpr(self, mesh, **kw):
        kwargs = {"mesh": mesh} if mesh is not None else {"shard": False}
        ge = GroupedStreamEngine(mixed_groups("SINT"), n_features=2,
                                 stride=3, backend="pallas", **NO_NORM,
                                 **kwargs, **kw)
        assert ge._mega, ge._mega_reason
        key = tuple((gi, ge.stride) for gi in range(4))
        assert ge._mega_applicable(key)
        step, args = ge._mega_example_args(key)
        return jax.make_jaxpr(step)(*args)

    def test_unsharded_step_is_one_dispatch(self):
        assert count_pallas_calls(self._mega_jaxpr(None).jaxpr) == 1

    def test_sharded_step_is_one_dispatch(self):
        """Under shard_map each device runs the same program: exactly one
        grouped dispatch in the per-shard jaxpr — a 1-wide mesh exercises
        the shard_map path in any process."""
        mesh = make_fleet_mesh(min(N_DEVICES, 2))
        jaxpr = self._mega_jaxpr(mesh, megakernel=True)
        assert count_pallas_calls(jaxpr.jaxpr) == 1

    def test_pergroup_step_is_four(self):
        """The collapsed dispatch count is real: the same fleet's per-group
        step carries one pallas_call per group."""
        ge = GroupedStreamEngine(mixed_groups("SINT"), n_features=2,
                                 stride=3, backend="pallas", shard=False,
                                 megakernel=False, **NO_NORM)
        key = tuple((gi, ge.stride) for gi in range(4))
        step = ge._get_step(key)
        rings = tuple(jnp.zeros_like(r) for r in ge._rings)
        calibs = tuple(jnp.zeros_like(c) for c in ge._calibs)
        counts = tuple(jnp.zeros_like(c) for c in ge._counts)
        blocks = tuple(jnp.zeros((ge._groups[gi].s_pad, n, 2), jnp.float32)
                       for gi, n in key)
        poss = tuple(jnp.int32(0) for _ in key)
        thrs = tuple(ge._thr(ge._groups[gi]) for gi, _ in key)
        jaxpr = jax.make_jaxpr(step)(rings, calibs, counts, blocks, poss,
                                     thrs)
        assert count_pallas_calls(jaxpr.jaxpr) == 4


class TestStepCacheAndWarmup:
    """Satellite: the mega step cache is keyed on BLOCK SHAPE, not ready
    subset — warmup compiles at most one step per shape and the hot path
    never compiles."""

    def test_warmup_compiles_one_step_per_block_shape(self):
        ge = GroupedStreamEngine(mixed_groups("SINT"), n_features=2,
                                 stride=3, shard=False, **NO_NORM)
        assert ge._mega
        ge.warmup()
        # Schedule: fill-in fires all four groups with a 4-long block once,
        # then steady state fires 3-long blocks — two shapes, one pack.
        assert {length for key in ge._schedule_keys()
                for _, length in key} == {3, 4}
        assert len(ge._mega_steps) == 2
        assert len(ge._mega_packs) == 1
        compiled = set(ge._mega_steps)
        rng = np.random.default_rng(0)
        for _ in range(30):
            ge.ingest(rng.normal(size=(8, 2)).astype(np.float32))
        assert set(ge._mega_steps) == compiled
        assert not ge._steps          # per-group path never built
        assert ge.stats.dispatches == ge.stats.steps > 0

    def test_equal_geometry_subsets_share_one_executable(self):
        """Identity-distinct subsets with equal plans (same shapes, dtypes,
        activations, heads) hit one compiled step: the cache key is the
        hashable GroupedPlan + serving geometry, not the unit tuple."""
        groups = [
            ModelGroup(f"g{i}", *small_model(8, 8, "SINT", i), 2,
                       ReconstructionHead(threshold=0.5))
            for i in range(4)
        ]
        ge = GroupedStreamEngine(groups, n_features=2, stride=2,
                                 shard=False, **NO_NORM)
        assert ge._mega
        s01, p01 = ge._get_mega_step((0, 1), 2)
        s23, p23 = ge._get_mega_step((2, 3), 2)
        assert p01 is not p23 and p01.sig == p23.sig
        assert s01 is s23
        assert len(ge._mega_steps) == 1 and len(ge._mega_packs) == 2


class TestDispatchAccounting:
    """Satellite: StreamStats.dispatches counts logical kernel dispatches —
    1 per mega step, n_groups per fused per-group step, len(stack) per
    per-layer unit."""

    def test_mega_one_per_step(self):
        ge = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                 stride=3, shard=False, **NO_NORM)
        drive(ge, 18)
        assert ge.stats.steps > 0
        assert ge.stats.dispatches == ge.stats.steps

    def test_pergroup_counts_each_group(self):
        ge = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                 stride=3, shard=False, megakernel=False,
                                 **NO_NORM)
        drive(ge, 18)
        assert ge.stats.dispatches == ge.stats.steps * 4

    def test_perlayer_unit_charges_stack_length(self):
        """fused=False groups pay one dispatch per layer (the 2-layer test
        models: 2 per group per step)."""
        groups = mixed_groups("REAL")
        for g in groups:
            g.fused = False
        ge = GroupedStreamEngine(groups, n_features=2, stride=3,
                                 shard=False, **NO_NORM)
        assert "fused=False" in ge._mega_reason
        drive(ge, 18)
        assert ge.stats.dispatches == ge.stats.steps * 8

    def test_single_engine_fused_is_one_per_step(self):
        model, params = small_model(8, 2, "REAL", 0)
        eng = StreamEngine(model, params, n_streams=3, n_features=2,
                           stride=3, shard=False, **NO_NORM)
        rng = np.random.default_rng(0)
        for _ in range(12):
            eng.ingest(rng.normal(size=(3, 2)).astype(np.float32))
        assert eng.stats.dispatches == eng.stats.steps > 0


class TestPackReasons:
    """Satellite: ``ops.grouped_fuse_reason`` / engine fallback semantics —
    every non-packable fleet serves per-group with a diagnosable reason,
    and ``megakernel=True`` surfaces it."""

    def test_mixed_dtype_position_rejected_and_served(self):
        groups = mixed_groups("REAL")[:2] + mixed_groups("SINT")[2:]
        ge = GroupedStreamEngine(groups, n_features=2, stride=3,
                                 shard=False, **NO_NORM)
        assert not ge._mega
        assert "mixes weight dtypes" in ge._mega_reason
        assert "one MXU mode per position" in ge._mega_reason
        with pytest.raises(ValueError, match="mixes weight dtypes"):
            GroupedStreamEngine(groups, n_features=2, stride=3,
                                shard=False, megakernel=True, **NO_NORM)
        drive(ge, 12)
        assert ge.stats.dispatches == ge.stats.steps * 4

    def test_vmem_overflow_names_the_widest_slab(self):
        """The packed-arena VMEM message carries the per-group slab bytes,
        the budget, and which group's slab drives the union arena."""
        def stack(name, k, n):
            return [({"w": jnp.zeros((k, n), jnp.float32),
                      "b": jnp.zeros((n,), jnp.float32)}, "relu"),
                    ({"w": jnp.zeros((n, 2), jnp.float32),
                      "b": jnp.zeros((2,), jnp.float32)}, "linear")]
        stacks = [stack("small", 128, 128), stack("big", 2048, 2048)]
        reason = ops.grouped_fuse_reason(stacks, names=["small", "big"])
        assert reason is not None
        assert "packed-arena VMEM resident set" in reason
        assert str(ops._fused_mod.VMEM_BUDGET_BYTES) in reason
        assert "small=" in reason and "big=" in reason
        assert "widest slab 'big'" in reason
        assert "serve this fleet per-group" in reason
        assert not ops.can_fuse_grouped(stacks)

    def test_fused_false_group_pins_perlayer(self):
        groups = mixed_groups("REAL")
        groups[1].fused = False
        with pytest.raises(ValueError, match="fused=False"):
            GroupedStreamEngine(groups, n_features=2, stride=3,
                                shard=False, megakernel=True, **NO_NORM)

    def test_head_without_kernel_epilogue(self):
        class HostOnlyHead(ReconstructionHead):
            def kernel_epilogue(self):
                return None
        groups = mixed_groups("REAL")
        groups[1] = ModelGroup("ae", groups[1].model, groups[1].params, 2,
                               HostOnlyHead(threshold=0.25))
        ge = GroupedStreamEngine(groups, n_features=2, stride=3,
                                 shard=False, **NO_NORM)
        assert "no in-kernel epilogue" in ge._mega_reason
        with pytest.raises(ValueError, match="no in-kernel epilogue"):
            GroupedStreamEngine(groups, n_features=2, stride=3,
                                shard=False, megakernel=True, **NO_NORM)

    def test_custom_prepare_falls_back(self):
        class SlicingHead(ReconstructionHead):
            def prepare(self, win):
                return win[..., :4]
        groups = mixed_groups("REAL")
        groups[1] = ModelGroup("ae", *small_model(4, 4, "REAL", 9), 2,
                               SlicingHead(threshold=0.25))
        ge = GroupedStreamEngine(groups, n_features=2, stride=3,
                                 shard=False, **NO_NORM)
        assert "overrides prepare()" in ge._mega_reason

    def test_single_unit_is_already_single_dispatch(self):
        g = mixed_groups("REAL")[0]
        ge = GroupedStreamEngine([g], n_features=2, stride=3, shard=False,
                                 **NO_NORM)
        assert "single unit" in ge._mega_reason and not ge._mega

    @pytest.mark.skipif(N_DEVICES < 2, reason="needs a multi-device process")
    def test_model_sharded_mesh_cannot_pack(self):
        mesh = make_fleet_mesh(1, model_shards=2)
        with pytest.raises(ValueError, match="model-axis"):
            GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                stride=3, mesh=mesh, megakernel=True,
                                **NO_NORM)


class TestGroupedKernel:
    """Kernel-level contracts of ``ops.grouped_apply``: the ref path is
    bit-identical to the per-group oracle loop, the Pallas (interpret)
    path is epsilon-close, and the final-layer softmax is masked to each
    group's true class count in-kernel (the closed softmax-fold item —
    the single-stack ``fuse_reason`` still rejects softmax)."""

    def _fleet(self, scheme, softmax_clf=False):
        act2 = "softmax" if softmax_clf else "linear"
        models = [small_model(8, 3, scheme, 0),
                  small_model(8, 8, scheme, 1),
                  small_model(6, 2, scheme, 3)]
        if softmax_clf:
            m = sequential([L.Input(),
                            L.Dense(units=6, activation="relu"),
                            L.Dense(units=3, activation=act2)], (8,))
            models[0] = (m, m.init_params(jax.random.PRNGKey(0)))
        stacks = [ops.dense_stack(m, p) for m, p in models]
        kinds = [ops.GROUPED_KIND_LOGITS, ops.GROUPED_KIND_SCORE,
                 ops.GROUPED_KIND_SCORE]
        return models, stacks, kinds

    def _expected(self, models, stacks, kinds, plan, win, tgt):
        exp = np.zeros((len(stacks), win.shape[1], plan.payload_width),
                       np.float32)
        for g, stack in enumerate(stacks):
            h = jnp.asarray(win[g][:, :plan.true_k0s[g]])
            for p, act in stack:
                h = ref.dense_layer_ref(h, p, act)
            if kinds[g] == ops.GROUPED_KIND_LOGITS:
                exp[g, :, :h.shape[1]] = np.asarray(h)
            else:
                n = plan.n_outs[g]
                exp[g, :, 0] = np.asarray(jnp.mean(
                    jnp.square(h - tgt[g][:, :n]), axis=-1))
        return exp

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_ref_bitwise_pallas_close(self, scheme):
        models, stacks, kinds = self._fleet(scheme)
        assert ops.grouped_fuse_reason(stacks, k0=8) is None
        plan, arrays = ops.build_grouped_plan(stacks, kinds, k0=8)
        rng = np.random.default_rng(0)
        win = rng.normal(size=(3, 5, 8)).astype(np.float32)
        tgt = np.zeros((3, 5, plan.n_out), np.float32)
        tgt[1, :, :8] = win[1]                       # ae: window target
        tgt[2, :, :2] = win[2][:, -2:]               # forecast: tail target
        exp = self._expected(models, stacks, kinds, plan, win,
                             jnp.asarray(tgt))
        pay_ref = ops.grouped_apply(jnp.asarray(win), plan, arrays,
                                    jnp.asarray(tgt), backend="ref")
        pay_pal = ops.grouped_apply(jnp.asarray(win), plan, arrays,
                                    jnp.asarray(tgt), backend="pallas")
        np.testing.assert_array_equal(np.asarray(pay_ref), exp)
        np.testing.assert_allclose(np.asarray(pay_pal), exp, rtol=2e-5,
                                   atol=2e-5)

    def test_masked_final_softmax(self):
        """A 3-class softmax classifier packed beside an 8-wide group: the
        in-kernel softmax normalizes over the TRUE class count (pad lanes
        annihilated before the exp), so probabilities sum to 1 — while the
        single-stack fuse path still rejects softmax entirely."""
        models, stacks, kinds = self._fleet("REAL", softmax_clf=True)
        assert ops.fuse_reason(stacks[0]) is not None      # single: reject
        assert ops.grouped_fuse_reason(stacks, k0=8) is None
        plan, arrays = ops.build_grouped_plan(stacks, kinds, k0=8)
        rng = np.random.default_rng(1)
        win = jnp.asarray(rng.normal(size=(3, 5, 8)).astype(np.float32))
        tgt = jnp.zeros((3, 5, plan.n_out))
        tgt = tgt.at[1, :, :8].set(win[1])
        tgt = tgt.at[2, :, :2].set(win[2][:, -2:])
        pay_ref = ops.grouped_apply(win, plan, arrays, tgt, backend="ref")
        pay_pal = ops.grouped_apply(win, plan, arrays, tgt,
                                    backend="pallas")
        probs = np.asarray(pay_ref)[0, :, :3]
        assert (probs > 0).all()
        np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(pay_ref)[0, :, 3:], 0.0)
        np.testing.assert_allclose(np.asarray(pay_pal),
                                   np.asarray(pay_ref), rtol=2e-5,
                                   atol=2e-5)

    def test_non_final_softmax_rejected(self):
        _, stacks, _ = self._fleet("REAL")
        stacks[0][0] = (stacks[0][0][0], "softmax")
        reason = ops.grouped_fuse_reason(stacks, names=["a", "b", "c"])
        assert reason is not None and "softmax" in reason
