import os

# Tests run single-device (the dry-run sets its own device count in its own
# process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite the golden Structured Text exports under tests/golden/"
             " instead of comparing against them")


@pytest.fixture
def update_golden(request):
    return request.config.getoption("--update-golden")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
