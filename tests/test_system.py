"""End-to-end behaviour tests for the paper's system (§4.3 pipeline + §6
optimizations wired together), plus training-loop integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (MultipartInference, layers as L, porting, prune,
                        quantize, sequential)
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import get_model


class TestPortingPipeline:
    """§4.3: train -> extract -> binary -> reconstruct -> load -> infer."""

    def test_end_to_end_with_quantization_and_multipart(self, tmp_path, key):
        trained = sequential(
            [L.Input(),
             L.Dense(units=64, activation="relu"),
             L.Dense(units=32, activation="relu"),
             L.Dense(units=2, activation="linear")], (400,))
        params = trained.init_params(key)

        ported, pparams = porting.port_mlp(trained, params, str(tmp_path))
        x = jax.random.normal(jax.random.PRNGKey(2), (400,)) * 0.5

        # 1. port is lossless ('without sacrificing inference accuracy')
        np.testing.assert_array_equal(np.asarray(trained.apply(params, x)),
                                      np.asarray(ported.apply(pparams, x)))

        # 2. quantize (§6.1) — output stays close
        qparams = quantize.quantize_params(ported, pparams, "SINT",
                                           calibration=[x])
        ref, q = ported.apply(pparams, x), ported.apply(qparams, x)
        assert float(jnp.abs(ref - q).max()) < 0.2

        # 3. multipart (§6.3) on the quantized model — exact vs single shot
        mi = MultipartInference(ported, qparams, 3)
        np.testing.assert_array_equal(np.asarray(mi.run_all(x)),
                                      np.asarray(ported.apply_planned(qparams, x)))

    def test_pruned_model_still_ports(self, tmp_path, key):
        m = sequential([L.Input(), L.Dense(units=128, activation="relu"),
                        L.Dense(units=2)], (128,))
        p = m.init_params(key)
        p = prune.prune_model(m, p, 0.5)
        ported, pp = porting.port_mlp(m, p, str(tmp_path))
        assert prune.sparsity_of(pp[1]["w"]) >= 0.49


class TestTrainingIntegration:
    """Train a reduced model on the synthetic stream: loss must drop."""

    @pytest.mark.slow
    def test_loss_decreases(self):
        cfg = get_config("qwen3_8b").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        opt_init, opt_update = make_optimizer(3e-3, warmup=5, steps=60)
        opt = opt_init(params)
        step = jax.jit(make_train_step(api, opt_update), donate_argnums=(0, 1))
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=8, seed=0)).batches()
        losses = []
        for _ in range(40):
            b = next(data)
            params, opt, m = step(params, opt,
                                  {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses

    def test_checkpoint_resume_bitexact(self, tmp_path):
        from repro.checkpoint import restore, save
        cfg = get_config("mamba2_370m").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        opt_init, opt_update = make_optimizer()
        opt = opt_init(params)
        step = jax.jit(make_train_step(api, opt_update))
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=4, seed=0)).batches()
        batches = [next(data) for _ in range(4)]

        def run(params, opt, batches):
            for b in batches:
                params, opt, m = step(params, opt,
                                      {k: jnp.asarray(v) for k, v in b.items()})
            return params, opt, float(m["loss"])

        params1, opt1, _ = run(params, opt, batches[:2])
        save(str(tmp_path), 2, {"params": params1})
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            {"params": params1})
        params1r = restore(str(tmp_path), like)["params"]
        _, _, loss_a = run(params1, opt1, batches[2:])
        _, _, loss_b = run(params1r, opt1, batches[2:])
        assert loss_a == loss_b


class TestQuantizedServing:
    def test_quantized_decode_close_to_fp(self):
        """ICSML quantization as a first-class serving feature on a big-arch
        (reduced) model: int8 weights, finite logits, mostly-agreeing argmax."""
        cfg = get_config("qwen3_8b").reduced().with_(dtype=jnp.float32)
        api_fp = get_model(cfg)
        params = api_fp.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)

        from repro.models import transformer as tf
        fp_logits = tf.forward_logits(params, cfg, toks)

        from repro.core.quantize import quantize_tensor

        n_layers = cfg.n_layers

        def quantize_tree(t):
            if isinstance(t, dict):
                if "w" in t and t["w"].ndim == 3 and "g" not in t:
                    # stacked (L, in, out): per-layer scales keep every leaf
                    # with a leading L axis so lax.scan can slice them
                    def qfn(w):
                        qt = quantize_tensor(w, "SINT")
                        return qt.q, qt.scale
                    q, scale = jax.vmap(qfn)(t["w"].astype(jnp.float32))
                    out = {k: v for k, v in t.items() if k != "w"}
                    out.update(qw=q, w_scale=scale,
                               x_scale=jnp.full((n_layers,), 0.05, jnp.float32))
                    return out
                return {k: quantize_tree(v) for k, v in t.items()}
            return t

        qparams = dict(params)
        qparams["blocks"] = quantize_tree(params["blocks"])
        q_logits = tf.forward_logits(qparams, cfg, toks)
        agree = float(jnp.mean(jnp.argmax(fp_logits, -1) == jnp.argmax(q_logits, -1)))
        assert agree >= 0.5
        assert np.isfinite(np.asarray(q_logits)).all()
