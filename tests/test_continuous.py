"""Continuous-batching engine: per-slot scheduling correctness.

Parity tests compare against references that take the *same* fp path where
exactness is expected (dense attention is cache-index-exact), and against a
manual split-prefill reference for the SSM (whose chunked-prefill vs stepwise
paths differ in the last bf16 bits by design).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serving import ContinuousEngine, Engine, Request


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("qwen3_8b").reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _mixed_requests(n, temperature=0.0):
    return [Request(uid=i, prompt=np.arange(3 + i, dtype=np.int32),
                    max_new_tokens=4 + 2 * i, temperature=temperature)
            for i in range(n)]


class TestContinuousParity:
    def test_greedy_matches_single_request_engine(self, dense_setup):
        """Per-request outputs equal the wave engine run one request at a
        time — slots never leak state into each other."""
        cfg, api, params = dense_setup
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        reqs = _mixed_requests(3)
        got = {c.uid: c.tokens for c in ce.serve(reqs)}
        single = Engine(api, params, batch_slots=1, cache_len=64)
        for r in reqs:
            want = single.serve([Request(uid=r.uid, prompt=r.prompt,
                                         max_new_tokens=r.max_new_tokens)])[0]
            np.testing.assert_array_equal(got[r.uid], want.tokens)

    def test_slot_reuse_more_requests_than_slots(self, dense_setup):
        """5 requests through 2 slots: every uid completes with its own
        correct tokens (slot-level admission/eviction)."""
        cfg, api, params = dense_setup
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        reqs = _mixed_requests(5)
        done = ce.serve(reqs)
        assert sorted(c.uid for c in done) == list(range(5))
        assert ce.last_stats.admitted == 5
        # continuous scheduling: total steps well under serial execution
        assert ce.last_stats.steps < sum(r.max_new_tokens for r in reqs)
        single = Engine(api, params, batch_slots=1, cache_len=64)
        for r in reqs:
            want = single.serve([Request(uid=r.uid, prompt=r.prompt,
                                         max_new_tokens=r.max_new_tokens)])[0]
            np.testing.assert_array_equal(
                {c.uid: c.tokens for c in done}[r.uid], want.tokens)

    def test_eos_retires_slot_early(self, dense_setup):
        cfg, api, params = dense_setup
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        probe = ce.serve([Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                                  max_new_tokens=6)])[0]
        eos = int(probe.tokens[2])
        # greedy decode may repeat tokens; the slot retires at the *first*
        # occurrence of the eos token
        first = int(np.flatnonzero(probe.tokens == eos)[0])
        got = ce.serve([Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                                max_new_tokens=6, eos_token=eos)])[0]
        np.testing.assert_array_equal(got.tokens, probe.tokens[:first + 1])
        assert got.tokens[-1] == eos

    def test_per_slot_temperatures(self, dense_setup):
        """A greedy and a sampled request share one batch: the greedy slot
        still reproduces the deterministic output."""
        cfg, api, params = dense_setup
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        prompt = np.arange(5, dtype=np.int32)
        done = ce.serve([
            Request(uid=0, prompt=prompt, max_new_tokens=8, temperature=0.0),
            Request(uid=1, prompt=prompt, max_new_tokens=8, temperature=5.0),
        ])
        got = {c.uid: c.tokens for c in done}
        single = Engine(api, params, batch_slots=1, cache_len=64)
        want = single.serve([Request(uid=0, prompt=prompt,
                                     max_new_tokens=8)])[0].tokens
        np.testing.assert_array_equal(got[0], want)
        assert not np.array_equal(got[1], got[0])
        # repeated serve()s draw fresh samples (no per-uid PRNG replay)
        again = {c.uid: c.tokens for c in ce.serve([
            Request(uid=0, prompt=prompt, max_new_tokens=8, temperature=0.0),
            Request(uid=1, prompt=prompt, max_new_tokens=8, temperature=5.0),
        ])}
        np.testing.assert_array_equal(again[0], want)
        assert not np.array_equal(again[1], got[1])


class TestContinuousSSM:
    def test_matches_manual_split_reference(self):
        """Engine output == manual prefill(prompt[:-1]) + stepwise decode —
        the exact fp path the engine takes, so equality is bitwise."""
        cfg = get_config("mamba2_370m").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        reqs = _mixed_requests(3)
        got = {c.uid: c.tokens for c in ce.serve(reqs)}
        for r in reqs:
            cache, _ = api.prefill(
                params, {"tokens": jnp.asarray(r.prompt[None, :-1])}, 64)
            cur = jnp.asarray(r.prompt[None, -1:])
            want = []
            for step in range(r.max_new_tokens):
                cache, lg = api.decode_multi(
                    params, cache, {"tokens": cur},
                    jnp.full((1,), len(r.prompt) - 1 + step, jnp.int32))
                cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                want.append(int(cur[0, 0]))
            np.testing.assert_array_equal(got[r.uid], np.asarray(want))


class TestContinuousHybrid:
    def test_matches_manual_split_reference(self):
        """Hybrid (attention + mamba + moe interleave): same split-prefill
        reference as the SSM test — bitwise along the engine's own fp path
        (wave-engine parity is precluded by MoE-router fp sensitivity)."""
        cfg = get_config("jamba_1_5_large_398b").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        reqs = _mixed_requests(2)
        got = {c.uid: c.tokens for c in ce.serve(reqs)}
        for r in reqs:
            cache, _ = api.prefill(
                params, {"tokens": jnp.asarray(r.prompt[None, :-1])}, 64)
            cur = jnp.asarray(r.prompt[None, -1:])
            want = []
            for step in range(r.max_new_tokens):
                cache, lg = api.decode_multi(
                    params, cache, {"tokens": cur},
                    jnp.full((1,), len(r.prompt) - 1 + step, jnp.int32))
                cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                want.append(int(cur[0, 0]))
            np.testing.assert_array_equal(got[r.uid], np.asarray(want))


class TestContinuousMoE:
    def test_moe_slots_complete(self):
        """MoE uses exact-length prefill (bucket pads would compete for
        expert capacity); capacity-grouped routing couples co-scheduled rows
        under any batched engine, so this checks completion, not parity."""
        cfg = get_config("granite_moe_1b_a400m").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        assert ce._bucket is None
        done = ce.serve(_mixed_requests(3))
        assert sorted(c.uid for c in done) == [0, 1, 2]
        assert all(len(c.tokens) == 4 + 2 * c.uid for c in done)


class TestUnsupportedCombos:
    def test_audio_family_rejected_with_clear_error(self):
        api = get_model(get_config("whisper_base").reduced())
        with pytest.raises(NotImplementedError, match="extras"):
            # the guard fires before params are ever touched
            ContinuousEngine(api, None, batch_slots=2, cache_len=64)

    def test_kv_quant_cyclic_rejected(self, dense_setup):
        cfg, _, _ = dense_setup
        api = get_model(cfg.with_(kv_quant=True))
        with pytest.raises(NotImplementedError, match="kv_quant"):
            ContinuousEngine(api, None, batch_slots=2, cache_len=64,
                             cyclic_segments=2)


class TestCyclicComposition:
    def test_multipart_step_matches_plain_continuous(self, dense_setup):
        """§6.3 multipart segments compose with continuous slots: the
        segment-sliced step produces the same tokens as the fused step."""
        cfg, api, params = dense_setup
        reqs = _mixed_requests(3)
        plain = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        cyc = ContinuousEngine(api, params, batch_slots=2, cache_len=64,
                               cyclic_segments=2)
        got_p = {c.uid: c.tokens for c in plain.serve(reqs)}
        got_c = {c.uid: c.tokens for c in cyc.serve(reqs)}
        for uid in got_p:
            np.testing.assert_array_equal(got_c[uid], got_p[uid])


class TestKVQuantContinuous:
    def test_kv_quant_slots_complete(self, dense_setup):
        """int8 KV cache (§6.1) through the per-slot decode path."""
        cfg, _, _ = dense_setup
        cfg_q = cfg.with_(kv_quant=True)
        api = get_model(cfg_q)
        params = api.init(jax.random.PRNGKey(0))
        ce = ContinuousEngine(api, params, batch_slots=2, cache_len=64)
        done = ce.serve(_mixed_requests(3))
        assert sorted(c.uid for c in done) == [0, 1, 2]
        assert all(len(c.tokens) == 4 + 2 * c.uid for c in done)
