"""GroupedStreamEngine: heterogeneous model-group fleet serving.

Acceptance: grouped verdicts bit-match (REAL) / epsilon-match (quantized)
N independent single-model StreamEngines over ring-wraparound runs, with
exactly one fused Pallas dispatch per group per verdict step — sharded and
unsharded — and mixed-head Verdict field invariants (per-group thresholds
never cross-contaminate)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import layers as L
from repro.core import quantize, sequential
from repro.launch.mesh import make_fleet_mesh
from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine
from repro.sim import (ClassifierHead, ForecastHead, MarginHead,
                       ReconstructionHead)

from test_fused import count_pallas_calls

SCHEMES = ("REAL", "SINT", "INT", "DINT")
N_DEVICES = len(jax.devices())
NO_NORM = dict(norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0))


def small_model(n_in, n_out, scheme, seed):
    model = sequential([L.Input(), L.Dense(units=6, activation="relu"),
                        L.Dense(units=n_out, activation="linear")], (n_in,))
    params = model.init_params(jax.random.PRNGKey(seed))
    if scheme != "REAL":
        calib = [jax.random.normal(jax.random.PRNGKey(600 + seed + i),
                                   (n_in,)) * 2.0 for i in range(4)]
        params = quantize.quantize_params(model, params, scheme,
                                          calibration=calib)
    return model, params


def mixed_groups(scheme, n_per=2, seed=0):
    """Four heterogeneous groups over a 4-reading window (2 features):
    classifier, reconstruction, margin, forecast — the forecast group's
    model eats 3 readings and predicts the 4th, so its ring window (4)
    matches the others through a different input geometry."""
    clf = small_model(8, 2, scheme, seed)
    ae = small_model(8, 8, scheme, seed + 1)
    mg = small_model(8, 3, scheme, seed + 2)
    fc = small_model(6, 2, scheme, seed + 3)
    return [
        ModelGroup("clf", *clf, n_per, ClassifierHead()),
        ModelGroup("ae", *ae, n_per, ReconstructionHead(threshold=0.25)),
        ModelGroup("mg", *mg, n_per,
                   MarginHead(threshold=0.5, center=(0.1, -0.2, 0.3))),
        ModelGroup("fc", *fc, n_per,
                   ForecastHead(threshold=0.75, n_features=2)),
    ]


def drive_both(groups, n_cycles, *, stride, seed=0, engine_kw=None,
               single_kw=None):
    """Run a GroupedStreamEngine and per-group independent StreamEngines
    over identical readings; returns (grouped_engine, grouped_verdicts,
    {name: (offset, single_engine, single_verdicts)})."""
    base = dict(NO_NORM, n_features=2, stride=stride)
    single_kw = dict(base, **(single_kw if single_kw is not None
                              else (engine_kw or {})))
    engine_kw = dict(base, **(engine_kw or {}))
    ge = GroupedStreamEngine(groups, **engine_kw)
    singles, off = {}, 0
    for g in groups:
        singles[g.name] = (off, StreamEngine(
            g.model, g.params, n_streams=g.n_streams, head=g.head,
            **single_kw), [])
        off += g.n_streams
    rng = np.random.default_rng(seed)
    readings = rng.normal(size=(n_cycles, ge.n_streams, 2)).astype(np.float32)
    gv = []
    for c in range(n_cycles):
        gv += ge.ingest(readings[c])
        for name, (o, eng, sv) in singles.items():
            sv += eng.ingest(readings[c][o:o + eng.n_streams])
    return ge, gv, singles


def assert_parity(ge, gv, singles, scheme):
    """Grouped verdicts partition exactly into the independent engines'
    verdict streams: bit-match for REAL, epsilon for quantized schemes
    (the grouped step traces all bodies into one XLA program, so fusion
    context may reassociate quantized arithmetic)."""
    assert len(gv) == sum(len(sv) for _, _, sv in singles.values())
    for name, (off, eng, sv) in singles.items():
        mine = [v for v in gv if v.group == name]
        assert len(mine) == len(sv)
        for a, b in zip(mine, sv):
            assert a.stream == off + b.stream
            assert a.cycle == b.cycle
            assert a.threshold == b.threshold
            assert (a.prob is None) == (b.prob is None)
            assert (a.score is None) == (b.score is None)
            if scheme == "REAL":
                assert a.pred == b.pred
                assert a.prob == b.prob and a.score == b.score
            else:
                for x, y in ((a.prob, b.prob), (a.score, b.score)):
                    if x is not None:
                        np.testing.assert_allclose(x, y, rtol=1e-5,
                                                   atol=1e-5)
        if scheme == "REAL":
            np.testing.assert_array_equal(ge.last_outputs[name],
                                          eng.last_logits)
        else:
            np.testing.assert_allclose(ge.last_outputs[name],
                                       eng.last_logits, rtol=1e-5, atol=1e-5)


class TestGroupedParity:
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_matches_independent_engines_over_wraparound(self, scheme):
        """3 ring wraps (window 4, 30 cycles) across all four head types."""
        ge, gv, singles = drive_both(mixed_groups(scheme), 30, stride=3,
                                     engine_kw={"shard": False},
                                     single_kw={"shard": False})
        assert gv
        assert_parity(ge, gv, singles, scheme)

    def test_heterogeneous_windows_fire_on_their_own_cadence(self):
        """Groups whose ring windows differ become ready at different
        cycles; each fires exactly when its own independent engine does."""
        groups = [
            ModelGroup("w4", *small_model(8, 8, "REAL", 0), 2,
                       ReconstructionHead(threshold=0.5)),
            ModelGroup("w5", *small_model(10, 10, "REAL", 1), 3,
                       ReconstructionHead(threshold=0.5)),
        ]
        ge, gv, singles = drive_both(groups, 27, stride=2,
                                     engine_kw={"shard": False},
                                     single_kw={"shard": False})
        assert {v.cycle for v in gv if v.group == "w4"} == \
            set(range(3, 27, 2))
        assert {v.cycle for v in gv if v.group == "w5"} == \
            set(range(4, 27, 2))
        assert_parity(ge, gv, singles, "REAL")

    @settings(max_examples=6, deadline=None)
    @given(scheme=st.sampled_from(SCHEMES), stride=st.integers(1, 5),
           extra=st.integers(0, 18), seed=st.integers(0, 3))
    def test_parity_property(self, scheme, stride, extra, seed):
        """Property form of the acceptance criterion: any stride/length/seed,
        grouped == N independent engines (bit for REAL, epsilon quantized),
        including runs that wrap the ring several times."""
        ge, gv, singles = drive_both(mixed_groups(scheme, seed=seed),
                                     6 + extra, stride=stride, seed=seed,
                                     engine_kw={"shard": False},
                                     single_kw={"shard": False})
        assert_parity(ge, gv, singles, scheme)

    @pytest.mark.skipif(N_DEVICES < 2, reason="needs a multi-device process")
    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    def test_sharded_matches_unsharded(self, scheme):
        """The sharded grouped step (explicit mesh, per-group pad contract)
        against the unsharded one — and both against independent engines on
        the same mesh (same shard widths -> REAL stays bit-exact)."""
        mesh = make_fleet_mesh(2)
        ge_s, gv_s, singles = drive_both(mixed_groups(scheme), 30, stride=3,
                                         engine_kw={"mesh": mesh},
                                         single_kw={"mesh": mesh})
        assert_parity(ge_s, gv_s, singles, scheme)
        ge_u, gv_u, _ = drive_both(mixed_groups(scheme), 30, stride=3,
                                   engine_kw={"shard": False},
                                   single_kw={"shard": False})
        assert len(gv_s) == len(gv_u)
        for a, b in zip(gv_s, gv_u):
            assert (a.stream, a.cycle, a.group) == (b.stream, b.cycle,
                                                    b.group)
            for x, y in ((a.prob, b.prob), (a.score, b.score)):
                if x is not None:
                    np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)

    @pytest.mark.skipif(N_DEVICES < 2, reason="needs a multi-device process")
    def test_pad_stream_contract_per_group(self):
        """Group sizes that don't divide the mesh: pad streams are served
        but never surface in verdicts or last_outputs."""
        groups = mixed_groups("REAL", n_per=3)       # 3 streams per group,
        mesh = make_fleet_mesh(2)                    # padded to 4 per group
        ge, gv, singles = drive_both(groups, 18, stride=3,
                                     engine_kw={"mesh": mesh},
                                     single_kw={"shard": False})
        assert all(r.shape[0] == 4 for r in ge._rings)
        assert {v.stream for v in gv} == set(range(12))
        assert all(ge.last_outputs[n].shape[0] == 3 for n in ge.last_outputs)
        assert_parity(ge, gv, singles, "REAL")


class TestSingleDispatchPerGroup:
    """Acceptance: one fused pallas_call per group per verdict step, in the
    jaxpr, sharded and unsharded."""

    def _dispatch_count(self, mesh):
        groups = mixed_groups("SINT")
        kw = {"mesh": mesh} if mesh is not None else {"shard": False}
        ge = GroupedStreamEngine(groups, n_features=2, stride=3,
                                 backend="pallas", **NO_NORM, **kw)
        key = tuple((gi, ge.stride) for gi in range(len(groups)))
        step = ge._get_step(key)
        rings = tuple(jnp.zeros_like(r) for r in ge._rings)
        calibs = tuple(jnp.zeros_like(c) for c in ge._calibs)
        countss = tuple(jnp.zeros_like(c) for c in ge._counts)
        blocks = tuple(jnp.zeros((ge._groups[gi].s_pad, length, 2),
                                 jnp.float32) for gi, length in key)
        poss = tuple(jnp.int32(0) for _ in key)
        thrs = tuple(ge._thr(ge._groups[gi]) for gi, _ in key)
        jaxpr = jax.make_jaxpr(step)(rings, calibs, countss, blocks, poss,
                                     thrs)
        return count_pallas_calls(jaxpr.jaxpr), len(groups)

    def test_unsharded_step_is_one_dispatch_per_group(self):
        n, n_groups = self._dispatch_count(None)
        assert n == n_groups == 4

    def test_sharded_step_is_one_dispatch_per_group(self):
        """Under shard_map each device runs the same program: still exactly
        one fused dispatch per group in the (per-shard) jaxpr — a 1-wide
        mesh exercises the shard_map path in any process."""
        n, n_groups = self._dispatch_count(make_fleet_mesh(min(N_DEVICES, 2)))
        assert n == n_groups == 4

    def test_partial_ready_step_dispatches_only_ready_groups(self):
        """A fill-in step where only some groups fire compiles a program
        with exactly one dispatch per READY group."""
        groups = mixed_groups("SINT")
        ge = GroupedStreamEngine(groups, n_features=2, stride=3,
                                 backend="pallas", shard=False, **NO_NORM)
        key = ((1, 4), (3, 4))                       # two of four ready
        step = ge._get_step(key)
        rings = tuple(jnp.zeros_like(ge._rings[gi]) for gi, _ in key)
        calibs = tuple(jnp.zeros_like(ge._calibs[gi]) for gi, _ in key)
        countss = tuple(jnp.zeros_like(ge._counts[gi]) for gi, _ in key)
        blocks = tuple(jnp.zeros((ge._groups[gi].s_pad, length, 2),
                                 jnp.float32) for gi, length in key)
        thrs = tuple(ge._thr(ge._groups[gi]) for gi, _ in key)
        jaxpr = jax.make_jaxpr(step)(rings, calibs, countss, blocks,
                                     (jnp.int32(0), jnp.int32(0)), thrs)
        assert count_pallas_calls(jaxpr.jaxpr) == 2

    def test_warmup_precompiles_every_schedule_key(self):
        """After warmup, serving never compiles on the hot path: every
        ready-combination the readiness schedule can produce is already in
        the step cache."""
        groups = [
            ModelGroup("w4", *small_model(8, 8, "REAL", 0), 2,
                       ReconstructionHead(threshold=0.5)),
            ModelGroup("w5", *small_model(10, 10, "REAL", 1), 2,
                       ReconstructionHead(threshold=0.5)),
        ]
        ge = GroupedStreamEngine(groups, n_features=2, stride=2,
                                 shard=False, **NO_NORM)
        ge.warmup()
        compiled = set(ge._steps)
        rng = np.random.default_rng(0)
        for c in range(30):
            ge.ingest(rng.normal(size=(4, 2)).astype(np.float32))
        assert set(ge._steps) == compiled


class TestMixedVerdictInvariants:
    """Satellite: Verdict field contracts per head type, and per-group
    thresholds never cross-contaminate."""

    def test_verdict_fields_by_head(self):
        ge, gv, _ = drive_both(mixed_groups("REAL"), 12, stride=4,
                               engine_kw={"shard": False},
                               single_kw={"shard": False})
        by_group = {}
        for v in gv:
            by_group.setdefault(v.group, []).append(v)
        assert set(by_group) == {"clf", "ae", "mg", "fc"}
        for v in by_group["clf"]:
            assert v.prob is not None and 0.0 <= v.prob <= 1.0
            assert v.score is None and v.threshold is None
            assert v.pred in (0, 1)
        for name in ("ae", "mg", "fc"):
            for v in by_group[name]:
                assert v.prob is None
                assert v.score is not None and v.threshold is not None
                assert v.pred == int(v.score > v.threshold)

    def test_thresholds_never_cross_contaminate(self):
        """Each score group's verdicts carry ITS calibrated threshold —
        three deliberately different values stay with their groups."""
        ge, gv, _ = drive_both(mixed_groups("REAL"), 12, stride=4,
                               engine_kw={"shard": False},
                               single_kw={"shard": False})
        want = {"ae": 0.25, "mg": 0.5, "fc": 0.75, "clf": None}
        seen = {}
        for v in gv:
            seen.setdefault(v.group, set()).add(v.threshold)
        assert seen == {k: {want[k]} for k in seen}

    def test_stream_attribution(self):
        """Verdict.stream is the GLOBAL fleet index; each group covers its
        contiguous slice exactly."""
        groups = mixed_groups("REAL", n_per=3)
        ge, gv, _ = drive_both(groups, 8, stride=4,
                               engine_kw={"shard": False},
                               single_kw={"shard": False})
        slices = {name: set(range(off, off + n))
                  for name, off, n in ge.groups}
        for v in gv:
            assert v.stream in slices[v.group]
        for name, want in slices.items():
            assert {v.stream for v in gv if v.group == name} == want


class TestGroupedEngineContract:
    def test_validation(self):
        g = mixed_groups("REAL")
        with pytest.raises(ValueError, match="at least one"):
            GroupedStreamEngine([], n_features=2, **NO_NORM)
        with pytest.raises(ValueError, match="duplicate"):
            GroupedStreamEngine(
                [g[0], ModelGroup("clf", g[1].model, g[1].params, 2,
                                  g[1].head)],
                n_features=2, shard=False, **NO_NORM)
        with pytest.raises(ValueError, match="n_streams"):
            GroupedStreamEngine(
                [ModelGroup("x", g[0].model, g[0].params, 0, g[0].head)],
                n_features=2, shard=False, **NO_NORM)
        with pytest.raises(ValueError):
            GroupedStreamEngine(g, n_features=2, stride=0, shard=False,
                                **NO_NORM)

    def test_wrong_reading_shape_rejected(self):
        ge = GroupedStreamEngine(mixed_groups("REAL"), n_features=2,
                                 shard=False, **NO_NORM)
        with pytest.raises(ValueError, match="readings"):
            ge.ingest(np.zeros((3, 2), np.float32))

    def test_stats_accounting(self):
        ge, gv, _ = drive_both(mixed_groups("REAL"), 10, stride=3,
                               engine_kw={"shard": False},
                               single_kw={"shard": False})
        st_ = ge.stats
        # window 4, stride 3 -> steps at cycles 4, 7, 10 (all groups ready
        # together: every group's ring window is 4).
        assert st_.cycles == 10
        assert st_.steps == 3
        assert st_.windows == 3 * 8 == len(gv)
        assert len(st_.latencies_s) == st_.steps
        assert ge.group_windows() == {"clf": 6, "ae": 6, "mg": 6, "fc": 6}
        assert st_.wall_s > 0 and st_.windows_per_s() > 0

    def test_fused_true_on_unfusable_group_raises(self):
        model = sequential([L.Input(),
                            L.Dense(units=6, activation="softmax"),
                            L.Dense(units=2, activation="linear")], (8,))
        params = model.init_params(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="clf.*cannot fuse"):
            GroupedStreamEngine(
                [ModelGroup("clf", model, params, 2, ClassifierHead(),
                            fused=True)],
                n_features=2, shard=False, **NO_NORM)

    def test_run_drives_plant_fleet(self):
        """run() over real PlantStreams: MSF reading layout, group
        attribution intact."""
        from repro.sim import build_autoencoder, build_detector, build_fleet
        clf = build_detector()
        ae = build_autoencoder()
        groups = [
            ModelGroup("clf", clf, clf.init_params(jax.random.PRNGKey(0)), 2),
            ModelGroup("ae", ae, ae.init_params(jax.random.PRNGKey(1)), 2,
                       ReconstructionHead(threshold=1.0)),
        ]
        ge = GroupedStreamEngine(groups, shard=False)
        ge.warmup()
        verdicts = ge.run(build_fleet(["baseline"], 4, seed=0), 210)
        assert {v.group for v in verdicts} == {"clf", "ae"}
        assert {v.stream for v in verdicts} == {0, 1, 2, 3}
        with pytest.raises(ValueError, match="fleet size"):
            ge.run(build_fleet(["baseline"], 3, seed=0), 10)


class TestMarginHead:
    """The one-class margin head (Deep-SVDD style): score = mean squared
    distance of the embedding from a fixed benign center."""

    def test_batch_scores_math(self):
        head = MarginHead(threshold=1.0, center=(1.0, -1.0))
        out = jnp.asarray([[1.0, -1.0], [2.0, 0.0], [0.0, 0.0]])
        np.testing.assert_allclose(
            np.asarray(head.batch_scores(out, out)), [0.0, 1.0, 1.0])

    def test_epilogue_reduces_to_one_score_per_stream(self):
        head = MarginHead(threshold=1.0, center=(0.5, 0.5, 0.5))
        out = jnp.asarray(np.random.default_rng(0)
                          .normal(size=(4, 3)).astype(np.float32))
        red = head.epilogue(jnp.zeros((4, 8)), out)
        assert red.shape == (4, 1)
        np.testing.assert_allclose(
            np.asarray(red)[:, 0],
            np.mean((np.asarray(out) - 0.5) ** 2, axis=-1), rtol=1e-6)

    def test_validate_requires_matching_center(self):
        with pytest.raises(ValueError, match="center"):
            MarginHead(threshold=1.0).validate(8, 3)
        with pytest.raises(ValueError, match="center"):
            MarginHead(threshold=1.0, center=(0.0, 0.0)).validate(8, 3)
        MarginHead(threshold=1.0, center=(0.0, 0.0, 0.0)).validate(8, 3)

    def test_window_geometry_is_default(self):
        head = MarginHead(threshold=1.0, center=(0.0,))
        assert head.ring_window(8, 2) == 4
        assert head.model_input_size(4, 2) == 8
        win = jnp.ones((3, 8))
        assert head.prepare(win) is win


class TestForecastHead:
    """The next-step-prediction head: the ring holds one reading MORE than
    the model eats; the extra (newest) reading is the prediction target."""

    def test_window_geometry(self):
        head = ForecastHead(threshold=1.0, n_features=2)
        assert head.ring_window(6, 2) == 4       # 3 readings in, 1 target
        assert head.model_input_size(4, 2) == 6
        with pytest.raises(ValueError):
            head.ring_window(6, 3)               # engine/head feature clash
        with pytest.raises(ValueError):
            head.ring_window(7, 2)               # not a whole reading count

    def test_prepare_drops_target_reading(self):
        head = ForecastHead(threshold=1.0, n_features=2)
        win = jnp.arange(16.0).reshape(2, 8)
        np.testing.assert_array_equal(np.asarray(head.prepare(win)),
                                      np.asarray(win[:, :-2]))

    def test_batch_scores_against_last_reading(self):
        head = ForecastHead(threshold=1.0, n_features=2)
        win = jnp.asarray(np.random.default_rng(0)
                          .normal(size=(5, 8)).astype(np.float32))
        pred = jnp.asarray(np.random.default_rng(1)
                           .normal(size=(5, 2)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(head.batch_scores(pred, win)),
            np.mean((np.asarray(pred) - np.asarray(win)[:, -2:]) ** 2,
                    axis=-1), rtol=1e-6)

    def test_validate_output_width(self):
        head = ForecastHead(threshold=1.0, n_features=2)
        head.validate(6, 2)
        with pytest.raises(ValueError):
            head.validate(6, 3)

    def test_engine_derives_ring_window_from_head(self):
        """A 6-input forecaster over 2 features rings 4 readings; the
        served window's newest reading is the target the score is
        measured against (identity probe: outputs == model inputs)."""
        model, params = small_model(6, 2, "REAL", 0)
        eng = StreamEngine(model, params, n_streams=2, n_features=2,
                           head=ForecastHead(threshold=1e9, n_features=2),
                           shard=False, **NO_NORM)
        assert eng.window == 4


class TestScoreHeadTraining:
    """Smoke the margin/forecast training recipes on synthetic windows:
    calibrated head comes back thresholded at the target FPR, servable."""

    def _windows(self, n=240, w=400):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(n, w)).astype(np.float32)
        y = np.zeros(n, np.int64)
        y[-40:] = 1
        x[-40:] += 3.0                           # separable "attacks"
        return x, y

    def test_train_one_class_smoke(self):
        from repro.sim import train_one_class
        x, y = self._windows()
        model, res = train_one_class(x, y, epochs=2, batch_size=64,
                                     patience=2)
        assert isinstance(res.head, MarginHead)
        assert res.head.threshold == res.threshold > 0
        assert len(res.head.center) == model.graph.nodes[-1].layer.units
        assert 0.0 <= res.calib_fpr <= 0.015     # conservative: never above
        assert res.calib_windows.ndim == 2

    def test_train_forecaster_smoke(self):
        from repro.sim import train_forecaster
        x, y = self._windows()
        model, res = train_forecaster(x, y, epochs=2, batch_size=64,
                                      patience=2)
        assert isinstance(res.head, ForecastHead)
        assert model.input_shape == (398,)
        assert res.head.threshold == res.threshold > 0
        assert 0.0 <= res.calib_fpr <= 0.015

    def test_trained_heads_serve_in_grouped_engine(self):
        """The full seam: train both score heads, serve them as groups
        beside a classifier, verdicts carry the trained thresholds."""
        from repro.sim import train_forecaster, train_one_class
        x, y = self._windows()
        mg_model, mg_res = train_one_class(x, y, epochs=1, batch_size=64)
        fc_model, fc_res = train_forecaster(x, y, epochs=1, batch_size=64)
        groups = [
            ModelGroup("mg", mg_model, mg_res.params, 2, mg_res.head),
            ModelGroup("fc", fc_model, fc_res.params, 2, fc_res.head),
        ]
        ge = GroupedStreamEngine(groups, shard=False)
        assert ge.max_window == 200
        rng = np.random.default_rng(1)
        gv = []
        for c in range(205):
            gv += ge.ingest(rng.normal(size=(4, 2)).astype(np.float32))
        thr = {v.group: v.threshold for v in gv}
        assert thr == {"mg": mg_res.threshold, "fc": fc_res.threshold}
