"""Pruning + operation skipping (§6.2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prune

from _hyp import given, settings, st  # hypothesis or fallback shim


class TestMagnitudePrune:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.95))
    def test_property_sparsity_achieved(self, seed, sparsity):
        w = jax.random.normal(jax.random.PRNGKey(seed % 2**32), (40, 30))
        wp = prune.magnitude_prune(w, float(sparsity))
        achieved = prune.sparsity_of(wp)
        assert achieved >= sparsity - 1e-6
        # surviving weights unchanged
        mask = np.asarray(wp) != 0
        np.testing.assert_array_equal(np.asarray(wp)[mask], np.asarray(w)[mask])

    def test_keeps_largest(self):
        w = jnp.asarray([[1.0, -5.0], [0.1, 3.0]])
        wp = prune.magnitude_prune(w, 0.5)
        assert float(wp[0, 1]) == -5.0 and float(wp[1, 1]) == 3.0
        assert float(wp[0, 0]) == 0.0 and float(wp[1, 0]) == 0.0


class TestBlockSparse:
    def test_compress_roundtrip(self, key):
        w = jax.random.normal(key, (256, 384))
        wp = prune.block_magnitude_prune(w, 0.5, (128, 128))
        bs = prune.compress_blocks(wp, (128, 128))
        np.testing.assert_allclose(np.asarray(bs.to_dense()), np.asarray(wp))
        assert bs.nnz_blocks == 3  # 6 blocks, 50% pruned
        assert abs(bs.density - 0.5) < 1e-6

    def test_all_zero_keeps_one_block(self):
        bs = prune.compress_blocks(jnp.zeros((128, 128)), (128, 128))
        assert bs.nnz_blocks == 1   # static shape guarantee

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.9))
    def test_property_block_prune_structurally_sparse(self, seed, sparsity):
        w = jax.random.normal(jax.random.PRNGKey(seed % 2**32), (256, 256))
        wp = prune.block_magnitude_prune(w, float(sparsity), (64, 64))
        bs = prune.compress_blocks(wp, (64, 64))
        total_blocks = 16
        expected = total_blocks - round(sparsity * total_blocks)
        assert bs.nnz_blocks <= max(expected, 1)


class TestSkipEconomics:
    """Reproduce the §6.2 findings analytically: with measured WAGO per-op
    costs, the IF-skip loses in float and wins under SINT quantization."""

    # effective per-op costs (arbitrary units) fitted to the §6.2 numbers:
    # float MAC ~ int MAC x1.4; compare ~ int MAC x0.55
    COST = {"float_mac": 1.4, "int_mac": 1.0, "compare": 0.55}

    def _time(self, counts):
        mac_cost = (self.COST["float_mac"] if counts["mac_dtype"] == "float"
                    else self.COST["int_mac"])
        return counts["mac"] * mac_cost + counts["compare"] * self.COST["compare"]

    def test_float_skip_not_profitable(self):
        base = 784 * 512 * self.COST["float_mac"]
        skip = self._time(prune.skip_op_counts(784, 512, 0.3, quantized=False))
        assert skip > base * 0.95   # checks eat the gain (50.84 vs 52.13 ms)

    def test_quantized_skip_profitable(self):
        # paper: 36.39 -> 20.87 ms at full sparsity; breakeven s ~ 0.57
        base = 784 * 512 * self.COST["int_mac"]
        skip_full = self._time(prune.skip_op_counts(784, 512, 1.0, quantized=True))
        assert skip_full < 0.62 * base
        skip_80 = self._time(prune.skip_op_counts(784, 512, 0.8, quantized=True))
        assert skip_80 < base

    def test_two_operand_check_better_with_sparse_inputs(self):
        one = self._time(prune.skip_op_counts(784, 512, 0.8, quantized=True))
        two = self._time(prune.skip_op_counts(784, 512, 0.8, quantized=True,
                                              check_inputs=True,
                                              input_sparsity=0.6))
        assert two < one
