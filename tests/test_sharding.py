"""Sharding rules + sim tests (single device: rules are pure functions)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as sh
from repro.launch.mesh import make_fleet_mesh, make_host_mesh


class FakeMesh:
    """Just enough of a Mesh for the pure rule functions."""
    def __init__(self, data=16, model=16):
        self.shape = {"data": data, "model": model}
        self.axis_names = tuple(self.shape)


@pytest.fixture
def mesh():
    return FakeMesh()


class TestParamSpecRules:
    def test_embed_sharded_on_vocab(self, mesh):
        cfg = get_config("qwen3_8b")
        spec = sh.param_spec("embed/emb", (151936, 4096), cfg, mesh)
        assert spec == P("model", None)

    def test_qkv_out_dim(self, mesh):
        cfg = get_config("qwen3_8b")
        assert sh.param_spec("blocks/attn/wq/w", (36, 4096, 4096), cfg, mesh) \
            == P(None, None, "model")
        assert sh.param_spec("blocks/attn/wo/w", (36, 4096, 4096), cfg, mesh) \
            == P(None, "model", None)

    def test_mlp_dims(self, mesh):
        cfg = get_config("qwen3_8b")
        assert sh.param_spec("blocks/ffn/w_up/w", (36, 4096, 12288), cfg, mesh) \
            == P(None, None, "model")
        assert sh.param_spec("blocks/ffn/w_down/w", (36, 12288, 4096), cfg, mesh) \
            == P(None, "model", None)

    def test_moe_expert_sharding_divisible(self, mesh):
        cfg = get_config("granite_moe_1b_a400m")  # 32 experts
        spec = sh.param_spec("blocks/ffn/w_gate", (24, 32, 1024, 512), cfg, mesh)
        assert spec == P(None, "model", None, None)

    def test_moe_expert_fallback_hidden(self, mesh):
        cfg = get_config("mixtral_8x22b")  # 8 experts < 16-way axis
        spec = sh.param_spec("blocks/ffn/w_gate", (56, 8, 6144, 16384), cfg, mesh)
        assert spec == P(None, None, None, "model")
        spec_d = sh.param_spec("blocks/ffn/w_down", (56, 8, 16384, 6144), cfg, mesh)
        assert spec_d == P(None, None, "model", None)

    def test_norms_replicated(self, mesh):
        cfg = get_config("qwen3_8b")
        assert sh.param_spec("blocks/ln1/g", (36, 4096), cfg, mesh) == P(None, None)

    def test_hybrid_double_stack(self, mesh):
        cfg = get_config("jamba_1_5_large_398b")
        spec = sh.param_spec("blocks/mamba/mixer/in_proj/w",
                             (9, 7, 8192, 35072), cfg, mesh)
        assert spec == P(None, None, None, "model")

    def test_router_replicated(self, mesh):
        cfg = get_config("mixtral_8x22b")
        assert sh.param_spec("blocks/ffn/router", (56, 6144, 8), cfg, mesh) \
            == P(None, None, None)


class TestSanitize:
    def test_nondivisible_dropped(self, mesh):
        spec = sh.sanitize(P("model", None), (50280, 1024), mesh)
        assert spec == P(None, None)

    def test_divisible_kept(self, mesh):
        spec = sh.sanitize(P("model", None), (65536, 1024), mesh)
        assert spec == P("model", None)

    def test_tuple_axes(self, mesh):
        spec = sh.sanitize(P(("data", "model"), None), (256, 8), mesh)
        assert spec == P(("data", "model"), None)
        spec2 = sh.sanitize(P(("data", "model"), None), (100, 8), mesh)
        assert spec2 == P(None, None)


class TestCacheShardings:
    def test_kv_context_parallel(self, mesh):
        cfg = get_config("qwen3_8b")
        specs = {"k": jax.ShapeDtypeStruct((36, 128, 32768, 8, 128), jnp.bfloat16),
                 "v": jax.ShapeDtypeStruct((36, 128, 32768, 8, 128), jnp.bfloat16)}
        out = sh.cache_shardings(specs, cfg, MeshWrap(), batch_size=128)
        assert out["k"].spec == P(None, "data", "model", None, None)

    def test_long_batch1_uses_both_axes(self):
        cfg = get_config("mamba2_370m")
        specs = {"ssm": jax.ShapeDtypeStruct((48, 1, 32, 64, 128), jnp.float32)}
        out = sh.cache_shardings(specs, cfg, MeshWrap(), batch_size=1)
        # heads on model; batch 1 replicated
        assert out["ssm"].spec[2] == "model"


class MeshWrap:
    """Real 1x1 host mesh won't validate 16-way specs; use a device-free
    stand-in that NamedSharding accepts via the real Mesh API."""
    def __new__(cls):
        import numpy as np
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        return jax.sharding.Mesh(devs, ("data", "model"))


class TestFleetMesh:
    def test_default_spans_all_devices(self):
        mesh = make_fleet_mesh()
        assert mesh.axis_names == ("data",)
        assert mesh.shape["data"] == len(jax.devices())

    def test_prefix_subset(self):
        mesh = make_fleet_mesh(1)
        assert mesh.shape["data"] == 1
        assert mesh.devices.ravel()[0] == jax.devices()[0]

    def test_too_many_devices_rejected(self):
        with pytest.raises(RuntimeError, match="fleet mesh"):
            make_fleet_mesh(len(jax.devices()) + 1)
        with pytest.raises(RuntimeError, match="fleet mesh"):
            make_fleet_mesh(0)

    def test_import_never_touches_device_state(self):
        """The module docstring's contract: importing repro.launch.mesh must
        not initialize any jax backend (smoke tests must keep seeing the
        device topology THEY configure).  A child process imports the module
        and then checks that no backend has been instantiated."""
        check = (
            "import repro.launch.mesh, repro.launch.shardings\n"
            "from jax._src import xla_bridge\n"
            "assert not xla_bridge._backends, list(xla_bridge._backends)\n"
            "print('MESH_IMPORT_PURE')\n"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")] +
            env.get("PYTHONPATH", "").split(os.pathsep))
        out = subprocess.run([sys.executable, "-c", check], env=env,
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        assert "MESH_IMPORT_PURE" in out.stdout


class TestEndToEndHostMesh:
    def test_train_step_on_1x1_mesh(self):
        """The full pjit path (shardings, constraints, donation) on the local
        device — semantics identical, sizes tiny."""
        from repro.launch.steps import make_optimizer, make_train_step
        from repro.models import get_model

        cfg = get_config("qwen3_8b").reduced()
        api = get_model(cfg)
        mesh = make_host_mesh()
        sh.install_hook(mesh, batch_sharded=True)
        try:
            p_shard = sh.param_shardings(api.param_specs(), cfg, mesh)
            params = jax.device_put(api.init(jax.random.PRNGKey(0)), p_shard)
            opt_init, opt_update = make_optimizer()
            opt = opt_init(params)
            step = jax.jit(make_train_step(api, opt_update), donate_argnums=(0, 1))
            batch = api.init_batch("train", 2, 32, jax.random.PRNGKey(1))
            with mesh:
                params, opt, metrics = step(params, opt, batch)
            assert np.isfinite(float(metrics["loss"]))
        finally:
            sh.install_hook(None)
