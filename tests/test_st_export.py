"""The Structured Text export backend, held to its bit-exactness contract.

Four layers of evidence that an emitted FUNCTION_BLOCK decides exactly what
the serving engine decides:

* IEC 61131-3 semantics unit tests — the emulator implements the PLC's
  arithmetic (two's-complement wrap, truncating division, dividend-sign MOD,
  half-to-even REAL->int rounding, strict typing, runtime traps), because
  bit-exactness claims are only as strong as the emulator's fidelity.
* Differential fuzz — random all-Dense stacks x REAL/SINT x random inputs,
  emulated output vs. the per-layer JAX oracle (``ref.fused_mlp_ref``):
  bit-equal under SINT, scaled-epsilon under REAL (XLA reassociates dots).
* Golden files — the canonical classifier and autoencoder exports are
  pinned byte-for-byte (modulo whitespace) under ``tests/golden/``;
  regenerate deliberately with ``pytest --update-golden``.
* End-to-end scenario replay — exported detectors replay attack scenarios
  through the emulator while a ``StreamEngine`` serves the same raw
  readings, and every per-window verdict must agree (ring-wraparound-length
  runs, composed attacks included).
"""

import importlib.util
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hyp import given, settings, st  # noqa: E402

from repro.codegen import (STError, STExportError, STFunctionBlock,
                           STRuntimeError, STTypeError, export_st,
                           format_real, numpy_mlp_ref,
                           sequential_f32_mse, stream_windows,
                           window_starts)
from repro.configs import msf_detector as spec
from repro.core import quantize
from repro.core.layers import Dense, Flatten
from repro.core.model import sequential
from repro.kernels import ops, ref
from repro.sim.detector import build_autoencoder, build_detector, \
    recalibrate_threshold
from repro.sim.heads import (ClassifierHead, ForecastHead, MarginHead,
                             ReconstructionHead)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _load_example(name):
    path = os.path.join(os.path.dirname(__file__), "..", "examples", name)
    mod_spec = importlib.util.spec_from_file_location(
        name.replace(".py", "_example"), path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# Emulator: IEC 61131-3 semantics


def _fb(decls, body, name="T"):
    return STFunctionBlock(
        f"FUNCTION_BLOCK {name}\n{decls}\n{body}\nEND_FUNCTION_BLOCK\n")


def test_sint_twos_complement_wrap():
    fb = _fb("VAR_INPUT A : SINT; END_VAR\nVAR_OUTPUT B : SINT; END_VAR",
             "B := A + 1;")
    out = fb.call({"A": np.array([126, 127, -128], np.int8)})
    assert out["B"].dtype == np.int8
    assert list(out["B"]) == [127, -128, -127]


def test_integer_division_truncates_and_mod_takes_dividend_sign():
    fb = _fb("VAR_INPUT A : DINT; B : DINT; END_VAR\n"
             "VAR_OUTPUT Q : DINT; R : DINT; END_VAR",
             "Q := A / B;\nR := A MOD B;")
    out = fb.call({"A": np.array([-7, 7, -7], np.int32),
                   "B": np.array([2, -2, 3], np.int32)})
    assert list(out["Q"]) == [-3, -3, -2]
    assert list(out["R"]) == [-1, 1, -1]


def test_real_to_int_rounds_half_to_even():
    fb = _fb("VAR_INPUT R : REAL; END_VAR\nVAR_OUTPUT S : SINT; END_VAR",
             "S := REAL_TO_SINT(R);")
    out = fb.call({"R": np.array([0.5, 1.5, 2.5, -0.5, -1.5], np.float32)})
    assert list(out["S"]) == [0, 2, 2, 0, -2]


def test_for_loop_negative_step():
    fb = _fb("VAR_OUTPUT S : DINT; END_VAR\nVAR I : DINT; END_VAR",
             "S := 0;\nFOR I := 9 TO 1 BY -2 DO\nS := S + I;\nEND_FOR;")
    assert int(fb.call({})["S"][0]) == 9 + 7 + 5 + 3 + 1


def test_if_with_batch_divergent_condition():
    fb = _fb("VAR_INPUT X : REAL; END_VAR\nVAR_OUTPUT Y : REAL; END_VAR",
             "IF X > 0.0 THEN\nY := 1.0;\nELSIF X < -1.0 THEN\n"
             "Y := -2.0;\nELSE\nY := -1.0;\nEND_IF;")
    out = fb.call({"X": np.array([3.0, -0.5, -4.0], np.float32)})
    assert list(out["Y"]) == [1.0, -1.0, -2.0]


def test_guarded_branch_suppresses_trap_on_inactive_lanes():
    # The zero-divisor lane never executes the division; only active lanes
    # may trap.
    fb = _fb("VAR_INPUT A : DINT; B : DINT; END_VAR\n"
             "VAR_OUTPUT Q : DINT; END_VAR",
             "IF B <> 0 THEN\nQ := A / B;\nELSE\nQ := 0;\nEND_IF;")
    out = fb.call({"A": np.array([8, 8], np.int32),
                   "B": np.array([2, 0], np.int32)})
    assert list(out["Q"]) == [4, 0]


def test_fb_state_persists_across_calls_and_reset():
    fb = _fb("VAR_OUTPUT N : DINT; END_VAR\nVAR C : DINT; END_VAR",
             "C := C + 1;\nN := C;")
    assert int(fb.call({})["N"][0]) == 1
    assert int(fb.call({})["N"][0]) == 2
    fb.reset()
    assert int(fb.call({})["N"][0]) == 1


def test_var_constant_is_write_protected():
    with pytest.raises(STError):
        _fb("VAR CONSTANT K : REAL := 1.0; END_VAR\n"
            "VAR_OUTPUT Y : REAL; END_VAR",
            "K := 2.0;\nY := K;")


def test_strict_typing_rejects_mixed_arithmetic():
    with pytest.raises(STTypeError):
        _fb("VAR_INPUT X : REAL; END_VAR\nVAR_OUTPUT Y : REAL; END_VAR\n"
            "VAR I : DINT; END_VAR",
            "I := 1;\nY := X + I;")


def test_real_to_sint_traps_out_of_range():
    fb = _fb("VAR_INPUT R : REAL; END_VAR\nVAR_OUTPUT S : SINT; END_VAR",
             "S := REAL_TO_SINT(R);")
    with pytest.raises(STRuntimeError):
        fb.call({"R": np.array([200.0], np.float32)})


def test_division_by_zero_traps():
    fb = _fb("VAR_INPUT B : DINT; END_VAR\nVAR_OUTPUT Q : DINT; END_VAR",
             "Q := 8 / B;")
    with pytest.raises(STRuntimeError):
        fb.call({"B": np.array([0], np.int32)})


def test_batch_varying_array_index_traps():
    fb = _fb("VAR_INPUT N : DINT; END_VAR\nVAR_OUTPUT Y : REAL; END_VAR\n"
             "VAR A : ARRAY[0..3] OF REAL; END_VAR",
             "Y := A[N];")
    with pytest.raises(STRuntimeError):
        fb.call({"N": np.array([0, 2], np.int32)})


def test_out_of_range_array_index_traps():
    with pytest.raises(STError):
        fb = _fb("VAR_OUTPUT Y : REAL; END_VAR\n"
                 "VAR A : ARRAY[0..3] OF REAL; END_VAR",
                 "Y := A[5];")
        fb.call({})


def test_out_of_range_int_literal_rejected():
    with pytest.raises(STError):
        fb = _fb("VAR_OUTPUT S : SINT; END_VAR", "S := 300;")
        fb.call({})


def test_format_real_round_trips_f32():
    for v in [0.0, 1.0, -1.5, 0.1, 3.14159265, 1e-8, 2.5e10, -7.03e-4]:
        s = format_real(v)
        assert "." in s or "E" in s
        assert np.float32(float(s)) == np.float32(v)


# ---------------------------------------------------------------------------
# Window schedule / score oracle helpers


def test_window_starts_matches_serving_schedule():
    assert window_starts(30, 10, 5) == [9, 14, 19, 24, 29]
    assert window_starts(8, 10, 5) == []


def test_stream_windows_layout():
    readings = np.arange(24, dtype=np.float32).reshape(12, 2)
    wins = stream_windows(readings, window=4, stride=3)
    assert wins.shape == (3, 8)
    # Oldest reading first, features interleaved per reading.
    assert list(wins[0]) == list(np.arange(8.0))
    assert list(wins[1]) == list(np.arange(6.0, 14.0))
    assert list(wins[2]) == list(np.arange(12.0, 20.0))


def test_sequential_f32_mse_is_order_sensitive_oracle():
    rng = np.random.default_rng(3)
    y = rng.standard_normal((5, 400)).astype(np.float32)
    t = rng.standard_normal((5, 400)).astype(np.float32)
    seq = sequential_f32_mse(y, t)
    vec = np.mean(np.square(y - t), axis=-1)
    assert np.allclose(seq, vec, rtol=1e-4)


# ---------------------------------------------------------------------------
# Differential fuzz: random stacks vs. the JAX oracle


def _random_stack(widths, seed, scheme, acts_pool):
    rng = np.random.default_rng(seed)
    in_width = int(rng.integers(1, 13))
    acts = [str(rng.choice(acts_pool)) for _ in widths]
    model = sequential([Dense(units=w, activation=a)
                        for w, a in zip(widths, acts)], (in_width,))
    params = model.init_params(jax.random.PRNGKey(seed))
    # Non-zero biases and wider weights so quantization rails get exercised.
    params = jax.tree_util.tree_map(
        lambda p: p + 0.1 * jnp.asarray(
            np.random.default_rng(seed + 1).standard_normal(p.shape),
            jnp.float32), params)
    x = rng.standard_normal((5, in_width)).astype(np.float32) * 2.0
    if scheme == "SINT":
        params = quantize.quantize_params(
            model, params, "SINT",
            calibration=quantize.calibration_samples(x, k=4))
    return model, params, x


def _oracle(model, params, x):
    # EAGER per-layer reference: dispatched op by op, so the requantize
    # mul+add stays two separately-rounded f32 ops.  (Jitting it lets XLA
    # FMA-contract the pair once biases are nonzero — not a bit-oracle.)
    stack = ops.dense_stack(model, params)
    out = np.asarray(ref.fused_mlp_ref(jnp.asarray(x), stack))
    if any("qw" in p for p, _ in stack):
        # The pure-numpy §6.1 oracle must agree bit-for-bit with the eager
        # JAX reference — the tie between the two oracle formulations.
        assert np.array_equal(out, numpy_mlp_ref(x, stack))
    return out


@settings(max_examples=25, deadline=None)
@given(widths=st.lists(st.integers(1, 12), min_size=1, max_size=4),
       seed=st.integers(0, 10_000))
def test_fuzz_sint_export_bit_matches_oracle(widths, seed):
    model, params, x = _random_stack(widths, seed, "SINT",
                                     ("relu", "linear"))
    export = export_st(model, params, n_features=1, name="FUZZ")
    out = STFunctionBlock(export.text).call({"X": x})
    oracle = _oracle(model, params, x)
    assert out["Y"].astype(np.float32).shape == oracle.shape
    assert np.array_equal(out["Y"].astype(np.float32), oracle)


@settings(max_examples=25, deadline=None)
@given(widths=st.lists(st.integers(1, 12), min_size=1, max_size=4),
       seed=st.integers(0, 10_000))
def test_fuzz_real_export_epsilon_matches_oracle(widths, seed):
    model, params, x = _random_stack(widths, seed, "REAL",
                                     ("relu", "linear", "sigmoid", "tanh"))
    export = export_st(model, params, n_features=1, name="FUZZ")
    out = STFunctionBlock(export.text).call({"X": x})
    oracle = _oracle(model, params, x)
    diff = np.abs(out["Y"].astype(np.float32) - oracle)
    assert diff.max() <= 1e-5 * (1.0 + np.abs(oracle).max())


def test_fuzz_sint_matches_fused_per_layer_parity():
    # One deep stack, checked against BOTH oracles: bit-exact vs. the
    # per-layer reference (the emitted arithmetic's contract), and to within
    # an ulp of the fused forward — the padded fused XLA program may contract
    # its requantize mul+add into an FMA, so two *JAX* programs already
    # differ in the last bit there; the ST side pins the per-layer form.
    model, params, x = _random_stack([12, 8, 8, 4], 42, "SINT",
                                     ("relu", "linear"))
    export = export_st(model, params, n_features=1, name="FUZZ")
    out = STFunctionBlock(export.text).call({"X": x})["Y"].astype(np.float32)
    oracle = _oracle(model, params, x)
    assert np.array_equal(out, oracle)
    stack = ops.dense_stack(model, params)
    fused = np.asarray(ops.fused_forward(jnp.asarray(x), stack,
                                         backend="jax"))
    assert np.abs(out - fused).max() <= 1e-6 * (1.0 + np.abs(fused).max())


# ---------------------------------------------------------------------------
# Export validation errors


def test_export_rejects_non_dense_graph():
    model = sequential([Flatten(), Dense(units=2)], (4,))
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(STExportError):
        export_st(model, params, n_features=1)


def test_export_rejects_unsupported_activation():
    model = sequential([Dense(units=2, activation="softmax")], (4,))
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(STExportError):
        export_st(model, params, n_features=1)


@pytest.mark.parametrize("scheme", ["INT", "DINT"])
def test_export_rejects_f32_emulated_int_schemes(scheme):
    # INT/DINT quantization accumulates in f32 on the JAX side — there is no
    # PLC arithmetic that reproduces it bit-exactly, so the exporter refuses.
    model = sequential([Dense(units=3, activation="relu")], (4,))
    params = model.init_params(jax.random.PRNGKey(0))
    qparams = quantize.quantize_params(model, params, scheme)
    with pytest.raises(STExportError):
        export_st(model, qparams, n_features=1)


def test_export_rejects_uncalibrated_score_head():
    model = sequential([Dense(units=4, activation="linear")], (4,))
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="threshold"):
        export_st(model, params, head=ReconstructionHead(), n_features=1)


def test_export_rejects_ragged_input_for_feature_count():
    model = sequential([Dense(units=2)], (5,))
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(STExportError):
        export_st(model, params, n_features=2)


# ---------------------------------------------------------------------------
# Head epilogues: margin + forecast (classifier/reconstruction are covered
# end-to-end below)


def test_margin_head_epilogue():
    rng = np.random.default_rng(11)
    model = sequential([Dense(units=6, activation="relu"),
                        Dense(units=4, activation="linear")], (8,))
    params = model.init_params(jax.random.PRNGKey(5))
    x = rng.standard_normal((9, 8)).astype(np.float32)
    center = tuple(float(c) for c in rng.standard_normal(4))
    y = _oracle(model, params, x)
    scores = np.mean(np.square(y - np.asarray(center, np.float32)), axis=-1)
    mid = np.sort(scores)[len(scores) // 2 - 1:len(scores) // 2 + 1]
    head = MarginHead(center=center, threshold=float(mid.mean()))
    export = export_st(model, params, head=head, n_features=1,
                       name="MARGIN")
    out = STFunctionBlock(export.text).call({"X": x})
    assert np.allclose(out["SCORE"], scores, rtol=1e-4)
    thr = np.float32(head.threshold)
    assert np.all(out["THRESHOLD"].astype(np.float32) == thr)
    assert np.array_equal(out["PRED"],
                          (out["SCORE"].astype(np.float32) > thr)
                          .astype(out["PRED"].dtype))
    assert 0 < int(out["PRED"].sum()) < len(scores)


def test_forecast_head_epilogue_ring_asymmetry():
    # The model eats W-1 readings; the block's window carries one more (the
    # forecast target) and scores against it.
    rng = np.random.default_rng(12)
    model = sequential([Dense(units=6, activation="relu"),
                        Dense(units=2, activation="linear")], (8,))
    params = model.init_params(jax.random.PRNGKey(6))
    head = ForecastHead(threshold=0.5)
    export = export_st(model, params, head=head, n_features=2,
                       name="FORECAST")
    assert export.window == 5 and export.window_width == 10
    x = rng.standard_normal((7, 10)).astype(np.float32)
    out = STFunctionBlock(export.text).call({"X": x})
    y = _oracle(model, params, x[:, :8])
    scores = np.mean(np.square(y - x[:, 8:]), axis=-1)
    assert np.allclose(out["SCORE"], scores, rtol=1e-4)
    assert np.array_equal(
        out["PRED"], (out["SCORE"].astype(np.float32)
                      > np.float32(0.5)).astype(out["PRED"].dtype))


# ---------------------------------------------------------------------------
# Golden files: the canonical exports, pinned


def _canonical_calibration():
    rng = np.random.default_rng(2026)
    return rng.standard_normal((64, spec.INPUT_SIZE)).astype(np.float32)


def _golden_export(kind):
    wins = _canonical_calibration()
    if kind == "classifier":
        model = build_detector()
        params = model.init_params(jax.random.PRNGKey(0))
        params = quantize.quantize_params(
            model, params, "SINT",
            calibration=quantize.calibration_samples(wins, k=16))
        head = ClassifierHead()
    else:
        model = build_autoencoder()
        params = model.init_params(jax.random.PRNGKey(1))
        params = quantize.quantize_params(
            model, params, "SINT",
            calibration=quantize.calibration_samples(wins, k=16))
        head, _ = recalibrate_threshold(model, params, wins)
    return export_st(model, params, head=head,
                     name=f"GOLDEN_{kind.upper()}",
                     normalize=(spec.NORM_MEAN, spec.NORM_STD))


@pytest.mark.parametrize("kind,fname", [
    ("classifier", "classifier_sint.st"),
    ("autoencoder", "autoencoder_sint.st"),
])
def test_golden_st_export(kind, fname, update_golden):
    export = _golden_export(kind)
    path = os.path.join(GOLDEN_DIR, fname)
    if update_golden:
        with open(path, "w") as f:
            f.write(export.text)
        pytest.skip(f"rewrote {fname}")
    assert os.path.exists(path), \
        f"missing golden {fname}; generate with pytest --update-golden"
    with open(path) as f:
        golden = f.read()
    # Whitespace-normalized: token stream must be identical.
    assert export.text.split() == golden.split(), (
        f"emitted ST for the canonical {kind} drifted from {fname}; if the "
        "change is intentional, regenerate with pytest --update-golden")


def test_export_is_deterministic():
    a = _golden_export("classifier")
    b = _golden_export("classifier")
    assert a.text == b.text


# ---------------------------------------------------------------------------
# End-to-end: exported detectors replay attack scenarios, verdict parity
# with the StreamEngine over ring-wraparound-length runs


SCENARIO_NAMES = ["baseline", "tb0-spoof", "drift-then-spoof", "steam-pulse"]
E2E_CYCLES = 460  # window 200 + stride 10 ring wraps more than twice


@pytest.fixture(scope="module")
def e2e():
    from repro.sim.scenarios import fleet_readings
    mod = _load_example("export_st.py")
    raw = fleet_readings(len(SCENARIO_NAMES), E2E_CYCLES,
                         names=SCENARIO_NAMES, seed=7)
    calib = mod.calibration_windows(len(SCENARIO_NAMES), E2E_CYCLES, 7,
                                    spec.STRIDE)
    return mod, raw, calib


@pytest.mark.parametrize("kind", ["mlp", "ae"])
def test_e2e_scenario_verdict_parity_sint(kind, e2e):
    mod, raw, calib = e2e
    model, params, head = mod.smoke_detector(kind, "SINT", calib)
    export = export_st(model, params, head=head,
                       name=f"E2E_{kind.upper()}",
                       normalize=(spec.NORM_MEAN, spec.NORM_STD))
    res = mod.verify_export(export, model, params, head, raw, spec.STRIDE)
    n_wins = len(SCENARIO_NAMES) * len(
        window_starts(E2E_CYCLES, spec.WINDOW, spec.STRIDE))
    assert res["windows"] == n_wins
    assert res["failures"] == 0
    assert res["borderline"] == 0
    assert res["max_body_diff"] == 0.0          # bit-exact model outputs
    # Verdict diversity: the attacks fire, the fleet is not saturated.
    assert 0 < res["anomalous"] < res["windows"]


def test_e2e_scenario_verdict_parity_real_ae(e2e):
    mod, raw, calib = e2e
    model, params, head = mod.smoke_detector("ae", "REAL", calib)
    export = export_st(model, params, head=head, name="E2E_AE_REAL",
                       normalize=(spec.NORM_MEAN, spec.NORM_STD))
    res = mod.verify_export(export, model, params, head, raw, spec.STRIDE)
    assert res["failures"] == 0
