"""2-D ``("data", "model")`` fleet mesh: column-sharded wide layers.

``make_fleet_mesh(n, model_shards=m)`` builds an ``(n, m)`` mesh; the
serving core column-shards every Dense layer whose output width reaches
``MODEL_SHARD_MIN_WIDTH`` over the model axis — each rank computes a
full-K dot for its own slice of output columns and one tiled
``all_gather`` recombines them, so sharded serving is **bit-exact**
against the unsharded engine (columns of a matmul are independent).
Pad-stream data sharding composes unchanged; the fused single-dispatch
kernel cannot span the gather, so the model axis forces the per-layer
step.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_fleet_mesh, make_host_mesh
from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine
from repro.serving.core import MODEL_SHARD_MIN_WIDTH
from repro.sim import ReconstructionHead, fleet_readings
from test_drift import energy_detector
from test_fused import detector_params, small_detector
from test_streams import drive, identity_probe

N_DEVICES = len(jax.devices())

needs2 = pytest.mark.skipif(N_DEVICES < 2, reason="needs >= 2 devices")
needs4 = pytest.mark.skipif(N_DEVICES < 4, reason="needs >= 4 devices")


def count_primitive(jaxpr, name):
    """Occurrences of a primitive anywhere in a jaxpr (recursing into
    sub-jaxprs: jit / shard_map / scan bodies)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for u in vs:
                if isinstance(u, jax.core.ClosedJaxpr):
                    n += count_primitive(u.jaxpr, name)
                elif isinstance(u, jax.core.Jaxpr):
                    n += count_primitive(u, name)
    return n


def verdict_key(v):
    return (v.stream, v.cycle, v.pred, v.prob, v.score, v.threshold, v.group)


def serve_all(eng, readings):
    out = []
    for c in range(readings.shape[0]):
        out.extend(eng.ingest(readings[c]))
    return out


class TestMeshConstruction:
    def test_default_stays_1d(self):
        mesh = make_fleet_mesh(1)
        assert mesh.axis_names == ("data",)
        assert mesh.devices.shape == (1,)

    @needs2
    def test_2d_shape_and_axes(self):
        mesh = make_fleet_mesh(1, model_shards=2)
        assert mesh.axis_names == ("data", "model")
        assert mesh.devices.shape == (1, 2)

    @needs4
    def test_2d_data_default_divides(self):
        mesh = make_fleet_mesh(model_shards=2)
        assert mesh.devices.shape == (N_DEVICES // 2, 2)

    def test_model_shards_validation(self):
        with pytest.raises(RuntimeError, match="model_shards"):
            make_fleet_mesh(1, model_shards=0)

    def test_too_many_devices(self):
        with pytest.raises(RuntimeError, match="needs"):
            make_fleet_mesh(N_DEVICES, model_shards=2)


@needs2
class TestModelShardedParity:
    """Sharded-vs-unsharded on the REAL serving shapes (the 400-64-32-16-2
    detector's 64-wide first layer crosses MODEL_SHARD_MIN_WIDTH).
    Full-K-per-column math makes these assertions bit-exact, not epsilon."""

    @pytest.mark.parametrize("scheme", ("REAL", "SINT", "INT", "DINT"))
    def test_detector_parity_model2(self, scheme):
        model, params = detector_params(scheme)
        readings = fleet_readings(3, 230, seed=11)     # ring wraps (W=200)
        logits = {}
        for key, kw in (("base", {"shard": False}),
                        ("shard", {"mesh": make_fleet_mesh(1,
                                                           model_shards=2)})):
            eng = StreamEngine(model, params, n_streams=3, **kw)
            vs = serve_all(eng, readings)
            logits[key] = (eng.last_logits, [verdict_key(v) for v in vs])
        np.testing.assert_array_equal(logits["shard"][0], logits["base"][0])
        assert logits["shard"][1] == logits["base"][1]

    @needs4
    @pytest.mark.parametrize("scheme", ("REAL", "SINT"))
    @pytest.mark.parametrize("n_streams", (4, 5))      # divisible and padded
    def test_detector_parity_data2_model2(self, scheme, n_streams):
        model, params = detector_params(scheme)
        readings = fleet_readings(n_streams, 230, seed=13)
        logits = {}
        for key, kw in (("base", {"shard": False}),
                        ("shard", {"mesh": make_fleet_mesh(2,
                                                           model_shards=2)})):
            eng = StreamEngine(model, params, n_streams=n_streams, **kw)
            serve_all(eng, readings)
            logits[key] = eng.last_logits
        np.testing.assert_array_equal(logits["shard"], logits["base"])

    def test_identity_window_oracle(self):
        """Ground truth, not just parity: a 64-wide identity layer sharded
        over the model axis must still return the exact window contents."""
        window, n_feat, n = 32, 2, 3                   # 64 = min shard width
        assert window * n_feat >= MODEL_SHARD_MIN_WIDTH
        model, params = identity_probe(window, n_feat)
        eng = StreamEngine(model, params, n_streams=n, n_features=n_feat,
                           window=window, stride=5,
                           norm_mean=(0.0, 0.0), norm_std=(1.0, 1.0),
                           mesh=make_fleet_mesh(1, model_shards=2))
        rng = np.random.default_rng(3)
        readings = rng.normal(size=(70, n, n_feat)).astype(np.float32)
        batches = drive(eng, readings)
        assert batches
        for cycle, logits in batches:
            want = readings[cycle - window + 1:cycle + 1]
            want = want.transpose(1, 0, 2).reshape(n, -1)
            np.testing.assert_array_equal(logits, want)

    def test_adaptive_parity(self):
        """Threshold adaptation state is row-local, so it composes with the
        model axis: live-threshold trajectory matches unsharded exactly."""
        model, params = energy_detector(32, 2)         # single 64-wide Dense
        readings = np.random.default_rng(7).normal(
            size=(80, 3, 2)).astype(np.float32)
        results = {}
        for key, kw in (("base", {"shard": False}),
                        ("shard", {"mesh": make_fleet_mesh(1,
                                                           model_shards=2)})):
            eng = StreamEngine(model, params, n_streams=3, n_features=2,
                               window=32, stride=4, norm_mean=(0.0, 0.0),
                               norm_std=(1.0, 1.0),
                               head=ReconstructionHead(threshold=0.8,
                                                       target_fpr=0.1),
                               adapt=True, **kw)
            vs = serve_all(eng, readings)
            results[key] = ([verdict_key(v) for v in vs], eng.live_threshold)
        assert results["shard"] == results["base"]

    def test_grouped_model_mesh_parity(self):
        det_model, det_params = small_detector("SINT", seed=1)
        ae_model, ae_params = energy_detector(32, 2)
        readings = fleet_readings(5, 70, seed=21)

        def make(**kw):
            return GroupedStreamEngine(
                [ModelGroup("det", det_model, det_params, 3),
                 ModelGroup("ae", ae_model, ae_params, 2,
                            head=ReconstructionHead(threshold=2.0))],
                n_features=2, stride=5, **kw)

        base = make(shard=False)
        shard = make(mesh=make_fleet_mesh(1, model_shards=2))
        bk = [verdict_key(v) for v in serve_all(base, readings)]
        sk = [verdict_key(v) for v in serve_all(shard, readings)]
        assert bk == sk
        for name in ("det", "ae"):
            np.testing.assert_array_equal(shard.last_outputs[name],
                                          base.last_outputs[name])


@needs2
class TestFusedInteraction:
    def test_fused_true_rejected_on_model_mesh(self):
        model, params = detector_params("SINT")
        with pytest.raises(ValueError,
                           match="cannot serve on a model-sharded mesh"):
            StreamEngine(model, params, n_streams=4, fused=True,
                         backend="pallas",
                         mesh=make_fleet_mesh(1, model_shards=2))

    def test_fused_auto_resolves_false_on_model_mesh(self):
        model, params = detector_params("SINT")
        eng = StreamEngine(model, params, n_streams=4, backend="pallas",
                           mesh=make_fleet_mesh(1, model_shards=2))
        assert eng.fused is False

    def test_host_mesh_model_axis_of_one_keeps_fusion(self):
        """A size-1 model axis is NOT model sharding — auto-fuse stays on."""
        model, params = detector_params("SINT")
        eng = StreamEngine(model, params, n_streams=4, backend="pallas",
                           mesh=make_host_mesh())
        assert eng.fused is True

    def test_one_all_gather_per_step(self):
        """Minimal-collective recombination: only the 64-wide layer crosses
        MODEL_SHARD_MIN_WIDTH, so the whole detector step carries exactly
        ONE all_gather."""
        model, params = detector_params("REAL")
        eng = StreamEngine(model, params, n_streams=4,
                           mesh=make_fleet_mesh(1, model_shards=2))
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_primitive(jaxpr.jaxpr, "all_gather") == 1

    def test_narrow_model_skips_collectives(self):
        """Every layer under MODEL_SHARD_MIN_WIDTH: the model axis is inert
        and the step stays collective-free."""
        model, params = small_detector("REAL", seed=0)   # widths 6 / 2
        eng = StreamEngine(model, params, n_streams=4, n_features=2,
                           window=4, stride=3,
                           mesh=make_fleet_mesh(1, model_shards=2))
        ring = jnp.zeros_like(eng._ring)
        block = jnp.zeros((eng._s_pad, eng.stride, 2), jnp.float32)
        jaxpr = jax.make_jaxpr(eng._step)(ring, block, jnp.int32(0))
        assert count_primitive(jaxpr.jaxpr, "all_gather") == 0


_SUBPROCESS_PARITY_2D = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
    " --xla_force_host_platform_device_count=4").strip()
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
import jax
assert len(jax.devices()) == 4, jax.devices()
from repro.launch.mesh import make_fleet_mesh
from repro.serving import StreamEngine
from repro.sim import fleet_readings
from test_fused import detector_params

for scheme in ("REAL", "SINT"):
    model, params = detector_params(scheme)
    readings = fleet_readings(5, 230, seed=17)         # 5 plants, (2, 2) mesh
    logits = {}
    for key, kw in (("base", {"shard": False}),
                    ("shard", {"mesh": make_fleet_mesh(2, model_shards=2)})):
        eng = StreamEngine(model, params, n_streams=5, **kw)
        for c in range(readings.shape[0]):
            eng.ingest(readings[c])
        logits[key] = eng.last_logits
    np.testing.assert_array_equal(logits["shard"], logits["base"])
print("MODEL_MESH_PARITY_OK")
"""


@pytest.mark.skipif(N_DEVICES >= 4,
                    reason="in-process tests already cover the (2, 2) mesh")
def test_2x2_parity_subprocess():
    """Single-device environments still certify the (data=2, model=2) mesh:
    a child process fans out 4 host devices and re-checks bit-exact parity
    on a non-divisible fleet."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.dirname(__file__)] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PARITY_2D],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "MODEL_MESH_PARITY_OK" in out.stdout
