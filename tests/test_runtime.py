"""Multipart inference + scan-cycle runtime (§6.3, §7.2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layers as L, runtime, sequential

from _hyp import given, settings, st  # hypothesis or fallback shim


def make_model(sizes=(64, 64, 64, 10), in_dim=32, key=0):
    m = sequential(
        [L.Input()] + [L.Dense(units=s, activation="relu") for s in sizes],
        (in_dim,))
    return m, m.init_params(jax.random.PRNGKey(key))


class TestSegmentBoundaries:
    def test_covers_schedule(self):
        m, _ = make_model()
        for n in (1, 2, 3, 5):
            bounds = runtime.segment_boundaries(m, n)
            assert bounds[0][0] == 0 and bounds[-1][1] == len(m.graph.nodes)
            for (a, b), (c, _) in zip(bounds, bounds[1:]):
                assert b == c and a < b

    def test_clamped_to_node_count(self):
        m, _ = make_model(sizes=(8,))
        bounds = runtime.segment_boundaries(m, 10)
        assert len(bounds) == len(m.graph.nodes)

    def test_flops_roughly_balanced(self):
        m, _ = make_model(sizes=(64,) * 8)
        mi_flops = runtime.segment_boundaries(m, 4)
        flops = list(m.node_flops().values())
        seg = [sum(flops[a:b]) for a, b in mi_flops]
        assert max(seg) <= 2.5 * (sum(flops) / 4)


class TestMultipart:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 7), st.integers(0, 2**31 - 1))
    def test_property_multipart_equals_single_shot(self, n_segments, seed):
        """§6.3: splitting across cycles must not change the output at all."""
        m, p = make_model(key=seed % 2**32)
        x = jax.random.normal(jax.random.PRNGKey((seed + 1) % 2**32), (32,))
        # jit the reference too: segments are jitted, and XLA's fusion may
        # round f32 differently from eager op-by-op execution
        ref = jax.jit(m.apply_planned)(p, x)
        mi = runtime.MultipartInference(m, p, n_segments)
        out = mi.run_all(x)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-6, atol=1e-6)

    def test_step_api(self):
        m, p = make_model()
        mi = runtime.MultipartInference(m, p, 3)
        x = jnp.ones((32,))
        state = mi.start(x)
        steps = 0
        while not state.finished(mi.n_segments):
            state = mi.step(state)
            steps += 1
        assert steps == mi.n_segments
        out = mi.output(state)
        assert out.shape == (10,)

    def test_step_after_finish_raises(self):
        m, p = make_model()
        mi = runtime.MultipartInference(m, p, 2)
        state = mi.start(jnp.ones((32,)))
        state = mi.step(mi.step(state))
        try:
            mi.step(state)
            assert False, "expected RuntimeError"
        except RuntimeError:
            pass

    def test_output_before_finish_raises(self):
        m, p = make_model()
        mi = runtime.MultipartInference(m, p, 2)
        state = mi.start(jnp.ones((32,)))
        try:
            mi.output(state)
            assert False, "expected RuntimeError"
        except RuntimeError:
            pass


class TestScanCycleRuntime:
    def test_control_plus_detection(self):
        m, p = make_model(sizes=(16, 8, 2), in_dim=20)
        det = runtime.SlidingWindowDetector(m, p, window=10, n_features=2,
                                            n_segments=2)
        calls = []

        def control(reading, state):
            calls.append(reading)
            return np.array([reading.sum()]), state

        rt = runtime.ScanCycleRuntime(control, det)
        stream = [np.ones(2, np.float32) * i for i in range(40)]
        log = rt.run(stream)
        assert len(log.cycle_times_s) == 40
        assert len(calls) == 40
        # window (10) fills, then inferences complete every 2 cycles
        assert log.summary()["n_inferences"] >= 10

    def test_detector_latency_counts_cycles(self):
        m, p = make_model(sizes=(16, 2), in_dim=20)
        det = runtime.SlidingWindowDetector(m, p, window=10, n_features=2,
                                            n_segments=3)
        for i in range(10):
            det.push(np.zeros(2, np.float32))
        results = [det.tick(c) for c in range(10)]
        done = [r for r in results if r is not None]
        assert done and all(lat == 3 for _, _, lat in done)
