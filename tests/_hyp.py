"""Hypothesis shim: property tests degrade gracefully when hypothesis is
missing.

When hypothesis is installed (requirements-dev.txt), this module re-exports
the real ``given``/``settings``/``st`` and the property tests run at full
strength.  Otherwise it provides a minimal drop-in: ``@given`` materializes a
small, fixed, deterministic set of examples per test (seeded ``random``), and
``@settings`` is a no-op — so the tier-1 suite always collects and runs.
"""

try:
    from hypothesis import given, settings  # noqa: F401
    import hypothesis.strategies as st      # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _St:
        """The subset of hypothesis.strategies the test-suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda r: [
                elements.draw(r) for _ in range(r.randint(min_size, max_size))
            ])

    st = _St()

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            # the wrapper must hide the strategy parameters from pytest's
            # fixture resolution, so its signature is (self) or () only.
            def run(*bound):
                rnd = random.Random(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    ex = [s.draw(rnd) for s in strategies]
                    kex = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                    fn(*bound, *ex, **kex)

            params = list(inspect.signature(fn).parameters)
            if params and params[0] == "self":
                def wrapper(self):
                    run(self)
            else:
                def wrapper():
                    run()
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn
        return decorate
