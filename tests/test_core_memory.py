"""Static memory planner (dataMem) invariants — unit + property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Graph, Node, chain, layers as L, memory, sequential
from repro.core.graph import GraphError

from _hyp import given, settings, st  # hypothesis or fallback shim


def mlp_graph(sizes):
    return chain([L.Input()] + [L.Dense(units=s, activation="relu") for s in sizes])


class TestGraph:
    def test_forward_reference_rejected(self):
        with pytest.raises(GraphError):
            Graph(nodes=(
                Node(uid=0, layer=L.Input(), inputs=()),
                Node(uid=1, layer=L.Add(), inputs=(0, 2)),   # 2 not yet defined
                Node(uid=2, layer=L.Dense(units=4), inputs=(0,)),
            ))

    def test_duplicate_uid_rejected(self):
        with pytest.raises(GraphError):
            Graph(nodes=(Node(uid=0, layer=L.Input()),
                         Node(uid=0, layer=L.Dense(units=2), inputs=(0,))))

    def test_shapes_propagate(self):
        g = mlp_graph([8, 3])
        shapes = g.infer_shapes((5,))
        assert shapes[g.output_uid] == (3,)

    def test_last_use_covers_consumers(self):
        g = mlp_graph([8, 3])
        last = g.last_use()
        assert last[0] >= 1   # input used by first dense
        assert last[g.output_uid] == len(g.nodes) - 1


class TestPlanner:
    def test_plan_validates(self):
        g = mlp_graph([64, 32, 16])
        plan = memory.plan_memory(g, (128,))
        plan.validate()

    def test_reuse_never_larger(self):
        g = mlp_graph([64, 64, 64, 64, 64])
        packed = memory.plan_memory(g, (64,), reuse=True)
        naive = memory.plan_memory(g, (64,), reuse=False)
        assert packed.arena_size <= naive.arena_size

    def test_deep_chain_reuses_memory(self):
        # A long chain needs O(1) live buffers, so the packed arena should be
        # far smaller than the naive sum.
        g = mlp_graph([256] * 20)
        packed = memory.plan_memory(g, (256,), reuse=True)
        naive = memory.plan_memory(g, (256,), reuse=False)
        assert packed.arena_size <= naive.arena_size / 4

    def test_branching_keeps_producer_alive(self):
        # concat consumes node 1 and node 3; node 1 must survive node 2/3.
        g = Graph(nodes=(
            Node(uid=0, layer=L.Input(), inputs=()),
            Node(uid=1, layer=L.Dense(units=32), inputs=(0,)),
            Node(uid=2, layer=L.Dense(units=32), inputs=(1,)),
            Node(uid=3, layer=L.Dense(units=32), inputs=(2,)),
            Node(uid=4, layer=L.Concat(), inputs=(1, 3)),
        ))
        plan = memory.plan_memory(g, (16,))
        plan.validate()
        b1, b2 = plan.buffers[1], plan.buffers[2]
        assert b1.live[1] >= 4
        # node 2's buffer may not overlap node 1's (both live at step 2)
        assert b1.end <= b2.offset or b2.end <= b1.offset

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(1, 300), min_size=1, max_size=12),
           st.integers(1, 128))
    def test_property_plan_always_valid(self, sizes, in_dim):
        g = mlp_graph(sizes)
        plan = memory.plan_memory(g, (in_dim,))
        plan.validate()   # raises on overlap/out-of-arena

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(1, 64), min_size=1, max_size=6),
           st.integers(1, 32), st.integers(0, 2 ** 31 - 1))
    def test_property_arena_equals_reference(self, sizes, in_dim, seed):
        """Planned (arena) execution is bit-identical to reference execution
        for arbitrary MLPs — the dataMem abstraction never corrupts data."""
        model = sequential(
            [L.Input()] + [L.Dense(units=s, activation="relu") for s in sizes],
            (in_dim,))
        params = model.init_params(jax.random.PRNGKey(seed % 2**32))
        x = jax.random.normal(jax.random.PRNGKey((seed + 1) % 2**32), (in_dim,))
        ref = model.apply(params, x)
        arena = model.apply_planned(params, x)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(arena))


class TestArenaAccessors:
    def test_write_read_roundtrip(self):
        info = memory.BufferInfo(uid=0, offset=128, size=128, shape=(3, 7),
                                 live=(0, 1))
        arena = jnp.zeros((512,), jnp.float32)
        val = jnp.arange(21, dtype=jnp.float32).reshape(3, 7)
        arena = memory.arena_write(arena, info, val)
        out = memory.arena_read(arena, info)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(val))
        # outside the buffer untouched
        assert float(arena[:128].sum()) == 0.0
