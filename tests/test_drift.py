"""Online drift adaptation: ParamDrift plant physics, streaming threshold
recalibration (head hooks + engines), the drift-FPR acceptance run, and the
serving-accounting satellite regressions (reservoir seeds, per-pass latency
tails, stride>window pending cap).

The acceptance question (ISSUE 7): a threshold calibrated once, offline,
floods with false alarms when the plant drifts benignly; the streaming
recalibration must hold the false-positive rate near the calibrated
``target_fpr`` on a drifting fleet while the frozen threshold exceeds 10x —
without touching detection of real attacks (scores beyond the admission
headroom never enter the calibration state).

The detector under test is a zero-weight "autoencoder": reconstruction is
identically zero, so the ReconstructionHead's score is the mean squared
normalized window — an energy detector whose benign score tracks the
operating point, with no training inside the test."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st
from repro.configs import msf_detector as spec
from repro.core import layers as L
from repro.core import quantize, sequential
from repro.launch.mesh import make_fleet_mesh
from repro.serving import (AdaptConfig, GroupedStreamEngine, LatencyReservoir,
                           ModelGroup, StreamEngine)
from repro.sim import (DRIFTABLE, ClassifierHead, ParamDrift, PlantParams,
                       ReconstructionHead, conservative_quantile,
                       fleet_readings, get_scenario, scenario_table)

TARGET_FPR = 0.05
N_DEVICES = len(jax.devices())


def energy_detector(window: int, n_features: int):
    """Zero-weight single-Dense 'autoencoder' (see module docstring)."""
    size = window * n_features
    model = sequential([L.Input(), L.Dense(units=size, activation="linear")],
                       (size,))
    params = model.init_params(jax.random.PRNGKey(0))
    (uid,) = [n.uid for n in model.graph.nodes
              if isinstance(n.layer, L.Dense)]
    params[uid]["w"] = jnp.zeros((size, size), jnp.float32)
    params[uid]["b"] = jnp.zeros((size,), jnp.float32)
    return model, params


def energy_scores(readings, window, stride, mean, std):
    """(steps, S) naive-slicing energy scores — the calibration oracle."""
    norm = (readings - mean) / std
    return np.stack([(norm[c - window + 1:c + 1] ** 2).mean(axis=(0, 2))
                     for c in range(window - 1, readings.shape[0], stride)])


class TestParamDrift:
    def test_validation(self):
        with pytest.raises(ValueError, match="at least one"):
            ParamDrift({})
        with pytest.raises(ValueError, match="cannot drift"):
            ParamDrift({"wd_setpoint": 0.1})
        with pytest.raises(ValueError, match="through zero"):
            ParamDrift({"k_flash": -1.0})
        with pytest.raises(ValueError, match="ramp"):
            ParamDrift({"k_flash": 0.1}, ramp=0)

    def test_dict_normalized_sorted_and_hashable(self):
        d = ParamDrift({"t_sea": 0.1, "k_flash": -0.1})
        assert d.shifts == (("k_flash", -0.1), ("t_sea", 0.1))
        assert hash(d) == hash(ParamDrift({"k_flash": -0.1, "t_sea": 0.1}))

    def test_fraction_ramp(self):
        d = ParamDrift({"k_flash": -0.5}, start=100, ramp=200)
        assert d.fraction(0) == 0.0
        assert d.fraction(100) == 0.0
        assert d.fraction(200) == 0.5
        assert d.fraction(300) == 1.0
        assert d.fraction(10_000) == 1.0     # holds, never overshoots

    def test_apply_multiplicative_and_preonset_identity(self):
        base = PlantParams()
        d = ParamDrift({"k_flash": -0.5}, start=0, ramp=10)
        assert d.apply(base, 0) is base       # pre-onset: no allocation
        drifted = d.apply(base, 10)
        assert drifted.k_flash == pytest.approx(base.k_flash * 0.5)
        # every non-shifted field untouched
        for f in sorted(DRIFTABLE - {"k_flash"}):
            assert getattr(drifted, f) == getattr(base, f)

    def test_seasonal_drift_moves_operating_point(self):
        """The builtin seasonal-drift scenario must move the PID-held TB0
        operating point by >= 1 sigma of the detector normalization — the
        threshold-killer the adaptation exists for."""
        kw = dict(names=["baseline"], seed=7)
        benign = fleet_readings(1, 2600, **kw)
        kw["names"] = ["seasonal-drift"]
        drifted = fleet_readings(1, 2600, **kw)
        delta = abs(drifted[-500:, 0, 0].mean() - benign[-500:, 0, 0].mean())
        assert delta >= 1.0 * spec.NORM_STD[0]

    def test_builtin_drift_scenarios_registered(self):
        assert get_scenario("seasonal-drift").drift is not None
        assert get_scenario("seasonal-drift").onset is None    # benign
        sc = get_scenario("drift-then-throttle")
        assert sc.drift is not None and sc.onset == 1300       # composes
        assert "drift" in scenario_table()


class TestStreamingThresholdProperty:
    """ScoreHead streaming hooks vs a pure-python oracle: the streaming
    threshold IS the conservative quantile of the trailing <= capacity
    admitted scores per stream, pooled fleet-wide — exact below the sketch
    window and across ring wraparound."""

    @settings(max_examples=20, deadline=None)
    @given(n_streams=st.integers(1, 4), capacity=st.integers(1, 6),
           n_steps=st.integers(1, 20), headroom=st.floats(1.0, 4.0),
           seed=st.integers(0, 10_000))
    def test_matches_trailing_quantile_oracle(self, n_streams, capacity,
                                              n_steps, headroom, seed):
        head = ReconstructionHead(threshold=1.0, target_fpr=0.1)
        rng = np.random.default_rng(seed)
        ring, counts = head.calib_state(n_streams, capacity)
        thr = jnp.float32(1.0)
        admitted = [[] for _ in range(n_streams)]
        for _ in range(n_steps):
            # lognormal-ish positives spanning the admission gate
            s = rng.exponential(1.0, size=n_streams).astype(np.float32)
            ring, counts = head.calib_update(ring, counts, jnp.asarray(s),
                                             thr, headroom)
            for i in range(n_streams):
                if s[i] <= headroom * 1.0:
                    admitted[i].append(s[i])
        pooled = np.concatenate(
            [np.asarray(a[-capacity:], np.float32) for a in admitted]
        ) if any(admitted) else np.zeros((0,), np.float32)
        # the pooled valid ring scores are exactly the trailing admitted set
        got = head.streaming_scores(ring, counts)
        np.testing.assert_array_equal(np.sort(got), np.sort(pooled))
        for min_count in (1, pooled.size, pooled.size + 1):
            want = (None if pooled.size < max(min_count, 1)
                    else conservative_quantile(pooled, 0.1))
            assert head.streaming_threshold(
                ring, counts, min_count=min_count) == want

    def test_wraparound_pinned(self):
        """capacity=3, 7 admissions: the ring holds exactly the last 3."""
        head = ReconstructionHead(threshold=1.0, target_fpr=0.25)
        ring, counts = head.calib_state(1, 3)
        for v in (1, 2, 3, 4, 5, 6, 7):
            ring, counts = head.calib_update(
                ring, counts, jnp.asarray([float(v)], jnp.float32),
                jnp.float32(10.0), 1.0)
        np.testing.assert_array_equal(
            np.sort(head.streaming_scores(ring, counts)), [5.0, 6.0, 7.0])
        assert head.streaming_threshold(ring, counts) == 7.0

    def test_requires_target_fpr(self):
        head = ReconstructionHead(threshold=1.0)
        ring, counts = head.calib_state(1, 4)
        with pytest.raises(ValueError, match="target_fpr"):
            head.streaming_threshold(ring, counts)


class TestEngineAdaptation:
    def _drive_with_reference(self, *, stride=1, every=1, n_cycles=40,
                              spike_cycle=None):
        """Drive an adaptive engine on random readings and replay the
        recalibration host-side from the engine's OWN verdict scores: admit
        through the headroom gate at the pre-step live threshold, pool the
        trailing <= capacity scores per stream, conservative-quantile them.
        Every verdict's threshold must equal the oracle's, exactly."""
        window, n_feat, n_streams = 6, 1, 3
        cfg = AdaptConfig(capacity=4, every=every, min_count=3, headroom=2.0)
        model, params = energy_detector(window, n_feat)
        head = ReconstructionHead(threshold=1.0, target_fpr=0.25)
        eng = StreamEngine(model, params, n_streams=n_streams,
                           n_features=n_feat, window=window, stride=stride,
                           norm_mean=(0.0,), norm_std=(1.0,),
                           head=head, adapt=cfg)
        rng = np.random.default_rng(3)
        readings = rng.normal(size=(n_cycles, n_streams, n_feat)) \
            .astype(np.float32)
        if spike_cycle is not None:    # a fat attack burst on stream 0
            readings[spike_cycle:spike_cycle + window, 0] = 50.0
        thr = head.threshold
        admitted = [[] for _ in range(n_streams)]
        fires = 0
        for c in range(n_cycles):
            verdicts = eng.ingest(readings[c])
            if not verdicts:
                continue
            fires += 1
            scores = [v.score for v in verdicts]
            for i, s in enumerate(scores):
                if s <= cfg.headroom * thr:
                    admitted[i].append(np.float32(s))
            if fires % cfg.every == 0:
                pooled = np.concatenate(
                    [np.asarray(a[-cfg.capacity:], np.float32)
                     for a in admitted])
                if pooled.size >= cfg.min_count:
                    thr = conservative_quantile(pooled, head.target_fpr)
            for v in verdicts:
                assert v.threshold == thr
                assert v.pred == int(v.score > thr)
        assert fires > cfg.capacity + 2          # the score rings wrapped
        assert eng.live_threshold == thr
        assert thr != head.threshold             # it actually moved
        return eng, thr, admitted

    def test_live_threshold_matches_host_oracle(self):
        self._drive_with_reference()

    def test_stride_and_cadence_compose(self):
        self._drive_with_reference(stride=3, every=2, n_cycles=70)

    def test_headroom_gate_blocks_attack_scores(self):
        """A 50-sigma burst on stream 0 must never enter the calibration
        state: its admitted-score list stays spike-free, so the fleet
        threshold cannot be dragged up after the attack."""
        eng, thr, admitted = self._drive_with_reference(spike_cycle=20)
        assert max(max(a) for a in admitted) < 10.0
        assert thr < 10.0
        counts = np.asarray(eng._calib_counts)[:3]
        assert counts[0] < counts[1]             # stream 0 skipped admissions

    def test_nonadaptive_score_head_keeps_offline_threshold(self):
        window, n_feat = 4, 1
        model, params = energy_detector(window, n_feat)
        head = ReconstructionHead(threshold=0.5, target_fpr=0.1)
        eng = StreamEngine(model, params, n_streams=2, n_features=n_feat,
                           window=window, stride=1,
                           norm_mean=(0.0,), norm_std=(1.0,), head=head)
        rng = np.random.default_rng(0)
        for c in range(12):
            for v in eng.ingest(rng.normal(size=(2, 1)).astype(np.float32)):
                assert v.threshold == 0.5
        assert eng.live_threshold == 0.5

    def test_adapt_validation(self):
        window, n_feat = 4, 1
        model, params = energy_detector(window, n_feat)
        kw = dict(n_streams=2, n_features=n_feat, window=window,
                  norm_mean=(0.0,), norm_std=(1.0,))
        with pytest.raises(ValueError, match="ScoreHead"):
            StreamEngine(model, params, head=ClassifierHead(), adapt=True,
                         **kw)
        with pytest.raises(ValueError, match="target_fpr"):
            StreamEngine(model, params, adapt=True,
                         head=ReconstructionHead(threshold=1.0), **kw)
        with pytest.raises(ValueError, match="calibrate"):
            StreamEngine(model, params, adapt=True,
                         head=ReconstructionHead(target_fpr=0.1), **kw)
        with pytest.raises(ValueError, match="AdaptConfig"):
            StreamEngine(model, params, adapt="yes",
                         head=ReconstructionHead(threshold=1.0,
                                                 target_fpr=0.1), **kw)
        for bad in (dict(capacity=0), dict(every=0), dict(min_count=0),
                    dict(headroom=0.5)):
            with pytest.raises(ValueError):
                AdaptConfig(**bad)


@pytest.mark.parametrize("n_devices",
                         [n for n in (1, 2, 4) if n <= N_DEVICES])
def test_sharded_adaptation_bit_matches_unsharded(n_devices):
    """Adaptive serving under the ("data",) fleet mesh: calibration state is
    row-local, so verdicts, live thresholds AND the gathered calibration
    state must bit-match the unsharded engine — including a fleet size not
    divisible by the device count (pad rows admit nothing)."""
    window, n_feat, n_streams = 10, 2, 6
    model, params = energy_detector(window, n_feat)
    head = ReconstructionHead(threshold=2.0, target_fpr=0.1)
    cfg = AdaptConfig(capacity=5, min_count=4, headroom=3.0)
    readings = fleet_readings(n_streams, 60, seed=13)
    engines = {}
    for name, mesh_kw in (("unsharded", dict(shard=False)),
                          ("sharded",
                           dict(mesh=make_fleet_mesh(n_devices)))):
        eng = StreamEngine(model, params, n_streams=n_streams,
                           n_features=n_feat, window=window, stride=4,
                           head=head, adapt=cfg, **mesh_kw)
        eng.warmup()
        verdicts = []
        for c in range(60):
            verdicts.extend(eng.ingest(readings[c]))
        engines[name] = (eng, verdicts)
    (u, uv), (s, sv) = engines["unsharded"], engines["sharded"]
    assert len(uv) == len(sv) > 0
    for a, b in zip(uv, sv):
        assert (a.stream, a.cycle, a.pred) == (b.stream, b.cycle, b.pred)
        assert a.score == b.score and a.threshold == b.threshold
    assert u.live_threshold == s.live_threshold != head.threshold
    # pad rows (fleet not divisible by the mesh) are sliced out of the
    # recalibration pool; the real rows of the gathered state must bit-match
    np.testing.assert_array_equal(np.asarray(u._calib_ring)[:n_streams],
                                  np.asarray(s._calib_ring)[:n_streams])
    np.testing.assert_array_equal(np.asarray(u._calib_counts)[:n_streams],
                                  np.asarray(s._calib_counts)[:n_streams])


class TestGroupedAdaptation:
    def test_grouped_matches_standalone_adaptive_engine(self):
        """One GroupedStreamEngine serving an adaptive AE group next to a
        frozen-threshold group must produce, for the adaptive group, exactly
        the verdicts a standalone adaptive StreamEngine produces on the same
        sub-fleet — and leave the frozen group's threshold pinned."""
        window, n_feat, n_per = 8, 2, 3
        model, params = energy_detector(window, n_feat)
        head_a = ReconstructionHead(threshold=2.0, target_fpr=0.2)
        head_b = ReconstructionHead(threshold=2.0, target_fpr=0.2)
        cfg = AdaptConfig(capacity=4, min_count=3, headroom=3.0)
        ge = GroupedStreamEngine(
            [ModelGroup("adapt", model, params, n_per, head_a, adapt=cfg),
             ModelGroup("frozen", model, params, n_per, head_b)],
            stride=3)
        se = StreamEngine(model, params, n_streams=n_per,
                          n_features=n_feat, window=window, stride=3,
                          head=head_a, adapt=cfg)
        readings = fleet_readings(2 * n_per, 50, seed=29)
        gv, sv = [], []
        for c in range(50):
            gv.extend(ge.ingest(readings[c]))
            sv.extend(se.ingest(readings[c][:n_per]))
        ga = [v for v in gv if v.group == "adapt"]
        assert len(ga) == len(sv) > 0
        for a, b in zip(ga, sv):
            assert (a.stream, a.cycle, a.pred) == (b.stream, b.cycle, b.pred)
            assert a.score == b.score and a.threshold == b.threshold
        live = ge.live_thresholds()
        assert live["adapt"] == se.live_threshold != 2.0
        assert live["frozen"] == 2.0
        assert all(v.threshold == 2.0 for v in gv if v.group == "frozen")

    def test_group_adapt_validation(self):
        model, params = energy_detector(4, 1)
        with pytest.raises(ValueError, match="group 'g'"):
            GroupedStreamEngine(
                [ModelGroup("g", model, params, 2,
                            ReconstructionHead(threshold=1.0), adapt=True)],
                norm_mean=(0.0,), norm_std=(1.0,), n_features=1)


@pytest.fixture(scope="module")
def drift_workload():
    """Shared drifting-fleet workload for the acceptance tests: calibrated
    energy head + 12000 cycles of the 16-plant seasonal-drift fleet."""
    window, n_feat, stride, n_streams = 50, 2, 10, 16
    model, params = energy_detector(window, n_feat)
    mean = np.asarray(spec.NORM_MEAN, np.float32)
    std = np.asarray(spec.NORM_STD, np.float32)
    calib = fleet_readings(n_streams, 2000, names=["baseline"], seed=11)
    scores = energy_scores(calib, window, stride, mean, std).ravel()
    head = ReconstructionHead(threshold=None).calibrate(scores, TARGET_FPR)
    drift = fleet_readings(n_streams, 12_000, names=["seasonal-drift"],
                           seed=23)
    return dict(window=window, n_feat=n_feat, stride=stride,
                n_streams=n_streams, model=model, params=params,
                mean=tuple(mean), std=tuple(std), head=head, drift=drift)


@pytest.mark.parametrize("scheme", ("REAL", "SINT"))
def test_drift_fpr_acceptance(drift_workload, scheme):
    """THE acceptance run: on a benignly drifting 16-plant fleet the
    adaptive engine holds false positives within 2x of target_fpr while the
    frozen offline threshold exceeds 10x — under float and quantized
    serving."""
    w = drift_workload
    params = w["params"]
    if scheme == "SINT":
        size = w["window"] * w["n_feat"]
        params = quantize.quantize_params(
            w["model"], params, "SINT",
            calibration=[jnp.zeros((size,), jnp.float32)])
    fpr = {}
    for label, adapt in (("fixed", None),
                         ("adaptive", AdaptConfig(capacity=16, min_count=8))):
        eng = StreamEngine(w["model"], params, n_streams=w["n_streams"],
                           n_features=w["n_feat"], window=w["window"],
                           stride=w["stride"], norm_mean=w["mean"],
                           norm_std=w["std"], head=w["head"], adapt=adapt)
        eng.warmup()
        flags = total = 0
        for c in range(w["drift"].shape[0]):
            for v in eng.ingest(w["drift"][c]):
                total += 1
                flags += v.pred != 0
        fpr[label] = flags / total
    assert fpr["adaptive"] <= 2.0 * TARGET_FPR, fpr
    assert fpr["fixed"] >= 10.0 * TARGET_FPR, fpr


def test_drift_adaptation_preserves_attack_detection(drift_workload):
    """A hard TB0 spoof landing on an already-drifted plant: the adaptive
    engine must cut benign-ramp false alarms well below the frozen
    engine's, flood with flags after onset, and FREEZE its live threshold
    there — the attack scores blow past the admission headroom, so not one
    enters the calibration state.  (During the deterministic monotone drift
    ramp the current score leads its own trailing quantile, so the
    ramp-phase rate is physics, not zero — the steady-state claim is the
    FPR acceptance test.)"""
    from repro.sim import AttackEvent, Scenario, registered
    w = drift_workload
    onset = 1300
    sc = Scenario(name="drift-then-tb0spoof",
                  description="hard TB0 spoof on an already-drifted plant",
                  events=(AttackEvent(4, start=onset, intensity=5.0),),
                  drift=ParamDrift({"k_flash": -0.08}, start=300, ramp=800))
    with registered(sc):
        readings = fleet_readings(4, 2600, names=[sc.name], seed=31)
    rates = {}
    for label, adapt in (("fixed", None),
                         ("adaptive", AdaptConfig(capacity=16, min_count=8))):
        eng = StreamEngine(w["model"], w["params"], n_streams=4,
                           n_features=w["n_feat"], window=w["window"],
                           stride=w["stride"], norm_mean=w["mean"],
                           norm_std=w["std"], head=w["head"], adapt=adapt)
        eng.warmup()
        pre, post = [], []
        thr_onset = None
        for c in range(2600):
            for v in eng.ingest(readings[c]):
                if 600 <= v.cycle < onset - w["window"]:
                    pre.append(v.pred != 0)
                elif v.cycle >= onset + w["window"]:
                    post.append(v.pred != 0)
                if v.cycle >= onset and thr_onset is None:
                    thr_onset = v.threshold
        rates[label] = (float(np.mean(pre)), float(np.mean(post)))
        if adapt is not None:
            # zero admissions after onset -> the streaming quantile is
            # recomputed from an unchanged state: frozen, exactly
            assert eng.live_threshold == thr_onset
    (pre_f, post_f), (pre_a, post_a) = rates["fixed"], rates["adaptive"]
    assert post_a >= 0.98, rates             # detection intact
    assert pre_a <= 0.6, rates               # ramp-phase rate bounded
    assert pre_a <= pre_f - 0.2, rates       # and far below the frozen one


class TestAccountingSatellites:
    """The serving-accounting bugfix sweep riding along with adaptation."""

    def test_slice_past_capacity_raises(self):
        r = LatencyReservoir(capacity=8, seed=0)
        for i in range(8):
            r.append(float(i))
        assert r[2:5] == [2.0, 3.0, 4.0]         # exact below capacity
        r.append(8.0)
        with pytest.raises(ValueError, match="reset_latencies"):
            r[2:5]
        assert isinstance(r[3], float)           # scalar indexing still fine

    def test_reset_latencies_swaps_reservoir(self):
        from repro.serving.streams import StreamStats
        stats = StreamStats(steps=0, cycles=0, windows=0, deadline_misses=0,
                            wall_s=0.0,
                            latencies_s=LatencyReservoir(capacity=4))
        for i in range(9):
            stats.latencies_s.append(float(i))
        old = stats.reset_latencies()
        assert old.seen == 9 and len(old) == 4
        assert stats.latencies_s.seen == 0
        assert stats.latencies_s.capacity == 4
        assert stats.latencies_s.seed != old.seed    # fresh replacement draw
        # the bench per-pass pattern: the new reservoir is an exact list
        stats.latencies_s.append(1.5)
        assert list(stats.latencies_s) == [1.5]

    def test_default_reservoir_seeds_diverge(self):
        """Regression: a shared fixed default seed made split engines
        replace the SAME retained indices in lockstep, correlating their
        percentile estimates.  Default seeds now come from a process
        counter, so identical append sequences retain different samples."""
        r1, r2 = LatencyReservoir(capacity=32), LatencyReservoir(capacity=32)
        assert r1.seed != r2.seed
        for i in range(5000):
            r1.append(float(i))
            r2.append(float(i))
        assert list(r1) != list(r2)
        # explicit seeds stay reproducible
        a, b = LatencyReservoir(capacity=32, seed=5), \
            LatencyReservoir(capacity=32, seed=5)
        for i in range(5000):
            a.append(float(i))
            b.append(float(i))
        assert list(a) == list(b)

    def test_stride_longer_than_window_caps_pending(self):
        """Regression: stride > window used to accumulate `stride` pending
        readings host-side (and compile a stride-long block shape) even
        though only the last `window` can ever land in the ring."""
        from test_streams import drive, identity_probe
        window, stride = 3, 50
        model, params = identity_probe(window, 1)
        eng = StreamEngine(model, params, n_streams=2, n_features=1,
                           window=window, stride=stride,
                           norm_mean=(0.0,), norm_std=(1.0,))
        readings = np.arange(153 * 2, dtype=np.float32).reshape(153, 2, 1)
        peak = 0

        orig = eng.ingest

        def spying_ingest(r):
            nonlocal peak
            out = orig(r)
            peak = max(peak, len(eng._pending))
            return out

        eng.ingest = spying_ingest
        batches = drive(eng, readings)
        assert peak <= window                    # host memory capped
        assert [c for c, _ in batches] == [2, 52, 102, 152]
        for cycle, logits in batches:            # parity with naive slicing
            want = readings[cycle - window + 1:cycle + 1]
            want = want.transpose(1, 0, 2).reshape(2, -1)
            np.testing.assert_array_equal(logits, want)
