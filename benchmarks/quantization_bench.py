"""§6.1 / Fig. 5: dense-layer (512 in / 512 out, ReLU) inference latency under
SINT/INT/DINT/REAL quantization, split into dot-product / activation / other —
plus the analytic op-count decomposition the paper derives.

Paper findings to reproduce directionally: quantization cuts the dot-product
portion (SINT −59.71 %, INT −56.52 %, DINT −37.23 % total latency on the
WAGO); activation time unaffected; dequantization negligible.  On CPU/XLA the
int8 path's advantage is smaller (no MXU), so we report the measured ratios
alongside the §6.1 op counts and the Pallas-kernel grid economics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import layers as L, quantize, sequential
from repro.configs.icsml_mlp import QUANT_LAYER


def main(quick: bool = False):
    rows = []
    n_in, n_out = QUANT_LAYER
    m = sequential([L.Input(),
                    L.Dense(units=n_out, activation="relu")], (n_in,))
    p = m.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (n_in,)) * 0.5

    fn_real = jax.jit(m.apply)
    t_real = time_fn(lambda: fn_real(p, x))
    rows.append({"name": "quantization/REAL_total", "us_per_call": t_real,
                 "derived": "baseline"})

    for scheme in ("SINT", "INT", "DINT"):
        qp = quantize.quantize_params(m, p, scheme, calibration=[x])
        fn_q = jax.jit(m.apply)
        t_q = time_fn(lambda: fn_q(qp, x))
        delta = (1 - t_q / t_real) * 100
        paper = {"SINT": 59.71, "INT": 56.52, "DINT": 37.23}[scheme]
        rows.append({"name": f"quantization/{scheme}_total",
                     "us_per_call": t_q,
                     "derived": f"latency_delta_pct={delta:.1f};paper_pct={paper}"})
        # numerical error vs REAL
        err = float(jnp.abs(m.apply(qp, x) - m.apply(p, x)).max())
        rows.append({"name": f"quantization/{scheme}_abs_err",
                     "us_per_call": err * 1e6,  # report in micro-units
                     "derived": "max_abs_err_x1e6"})

    # analytic op decomposition (§6.1) — asserted in tests, reported here
    for quantized, tag in ((False, "REAL"), (True, "SINT")):
        c = quantize.op_counts(n_in, n_out, quantized=quantized)
        rows.append({"name": f"quantization/op_counts/{tag}",
                     "us_per_call": float(c["int_mul"] + c["float_mul"]),
                     "derived": (f"fmul={c['float_mul']};fadd={c['float_add']};"
                                 f"imul={c['int_mul']};iadd={c['int_add']}")})
    return emit(rows)


if __name__ == "__main__":
    main()
