"""§6.3: multipart inference — per-cycle cost vs number of segments.

The paper runs a MobileNet-style model on a 90 ms scan cycle with 1.17 s
output latency.  We measure (a) the §7 detector and (b) a small conv model
(Conv2D + BatchNorm/ReLU + DepthwiseConv blocks, the paper's multipart demo
family): per-segment wall time must be ≈ total/segments, and output latency
= segments x cycle."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import layers as L, runtime, sequential
from repro.sim.detector import build_detector

SEGMENTS = (1, 2, 4, 8)


def mobilenet_ish():
    layers = [L.Input(features=(16, 16, 3))]
    ch = 8
    for i in range(3):
        layers += [
            L.Conv2D(filters=ch, kernel_size=(3, 3), strides=(2, 2)),
            L.BatchNorm(activation="relu"),
            L.DepthwiseConv2D(kernel_size=(3, 3)),
            L.BatchNorm(activation="relu"),
        ]
        ch *= 2
    layers += [L.GlobalAvgPool(), L.Dense(units=10, activation="softmax")]
    return sequential(layers, (16, 16, 3))


def main(quick: bool = False):
    rows = []
    for tag, model, x_shape in (
        ("detector", build_detector(), (400,)),
        ("conv", mobilenet_ish(), (16, 16, 3)),
    ):
        params = model.init_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), x_shape)
        segs = SEGMENTS[:3] if quick else SEGMENTS
        full = None
        for n in segs:
            mi = runtime.MultipartInference(model, params, n)

            def one_pass():
                state = mi.start(x)
                while not state.finished(mi.n_segments):
                    state = mi.step(state)
                return mi.output(state)

            t_total = time_fn(one_pass, warmup=1, iters=5)
            per_cycle = t_total / mi.n_segments
            if full is None:
                full = t_total
            rows.append({
                "name": f"multipart/{tag}/segments{n}",
                "us_per_call": per_cycle,
                "derived": (f"total_us={t_total:.1f};"
                            f"latency_cycles={mi.n_segments};"
                            f"seg_flops={mi.segment_flops()}")})
    return emit(rows)


if __name__ == "__main__":
    main()
