"""Benchmark harness: one module per paper table/figure (DESIGN.md §6 index).

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks sweeps; the
roofline module additionally needs experiments/dryrun artifacts.

Modules that return their rows also get a machine-readable perf record
``BENCH_<name>.json`` written into ``--out-dir`` (e.g. ``BENCH_detection.json``
for the fleet-detection fused-vs-per-layer comparison, with the serving bench
record alongside) — CI uploads these as artifacts so perf history is diffable
per commit.

``--compare OLD.json`` diffs this run's rows against a baseline record:
every row present in both is printed with its old→new ``us_per_call``
ratio, and any row more than 20% slower than the baseline makes the run
exit nonzero.  With ``--compare-to NEW.json`` no modules run at all — the
two records are diffed directly (the CI wiring: the bench-artifacts job
diffs its fresh ``--quick`` artifact against the committed baseline as a
non-blocking step, so a regression flags the PR without failing it).
"""

import argparse
import json
import os
import sys
import traceback

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

MODULES = [
    ("layer_stacking", "Fig.4/§5.2"),
    ("layer_width", "§5.3"),
    ("memory_bench", "Table2/Fig.3/§5.1"),
    ("quantization_bench", "Fig.5/§6.1"),
    ("pruning_bench", "§6.2"),
    ("multipart_bench", "§6.3"),
    ("perf_gap", "§5.4"),
    ("casestudy_bench", "§7"),
    ("serving_bench", "PR1-continuous"),
    ("detection_bench", "§7-fleet"),
    ("roofline", "§Roofline"),
]


def bench_json_name(module: str) -> str:
    short = module[:-len("_bench")] if module.endswith("_bench") else module
    return f"BENCH_{short}.json"


def write_bench_json(out_dir: str, module: str, ref: str, quick: bool,
                     rows) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, bench_json_name(module))
    with open(path, "w") as f:
        json.dump({"module": module, "paper_ref": ref, "quick": quick,
                   "rows": rows}, f, indent=2)
        f.write("\n")
    return path


REGRESSION_THRESHOLD = 0.20


def load_rows(path: str) -> list:
    with open(path) as f:
        record = json.load(f)
    return record["rows"] if isinstance(record, dict) else record


def compare_rows(old_rows, new_rows, *,
                 threshold: float = REGRESSION_THRESHOLD) -> int:
    """Print per-row old→new ``us_per_call`` ratios; return how many rows
    regressed by more than ``threshold``.

    Rows are matched by name: rows only in the new run are reported as new
    (a --quick run vs a full baseline legitimately differs in row sets),
    baseline rows the new run lacks are listed but never counted as
    regressions — only a matched row that got slower fails the gate."""
    old = {r["name"]: r for r in old_rows}
    new_names = {r["name"] for r in new_rows}
    regressed = 0
    for r in new_rows:
        o = old.get(r["name"])
        if o is None:
            print(f"# compare {r['name']}: no baseline row")
            continue
        if not o.get("us_per_call") or not r.get("us_per_call"):
            continue
        ratio = r["us_per_call"] / o["us_per_call"]
        tag = "REGRESSION" if ratio > 1.0 + threshold else "ok"
        print(f"# compare {r['name']}: {o['us_per_call']:.1f} -> "
              f"{r['us_per_call']:.1f} us/call ({ratio:.2f}x) {tag}")
        regressed += ratio > 1.0 + threshold
    missing = sorted(n for n in old if n not in new_names)
    if missing:
        print(f"# compare: {len(missing)} baseline rows not in this run: "
              + ",".join(missing))
    return regressed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json perf records")
    ap.add_argument("--compare", default=None, metavar="OLD.json",
                    help="baseline perf record; this run's matching rows "
                         f"more than {REGRESSION_THRESHOLD:.0%} slower "
                         "exit nonzero")
    ap.add_argument("--compare-to", default=None, metavar="NEW.json",
                    help="with --compare: diff two records directly, "
                         "running no benchmark modules")
    args = ap.parse_args()

    if args.compare_to:
        if not args.compare:
            sys.exit("--compare-to needs --compare OLD.json")
        regressed = compare_rows(load_rows(args.compare),
                                 load_rows(args.compare_to))
        if regressed:
            sys.exit(f"{regressed} rows regressed more than "
                     f"{REGRESSION_THRESHOLD:.0%} vs {args.compare}")
        return

    only = set(args.only.split(",")) if args.only else None
    failures = 0
    all_rows = []
    for name, ref in MODULES:
        if only and name not in only:
            continue
        print(f"# --- {name} ({ref}) ---", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rows = mod.main(quick=args.quick)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
            continue
        if isinstance(rows, list) and rows and isinstance(rows[0], dict):
            path = write_bench_json(args.out_dir, name, ref, args.quick, rows)
            all_rows.extend(rows)
            print(f"# wrote {path}", flush=True)
    if failures:
        sys.exit(f"{failures} benchmark modules failed")
    if args.compare:
        regressed = compare_rows(load_rows(args.compare), all_rows)
        if regressed:
            sys.exit(f"{regressed} rows regressed more than "
                     f"{REGRESSION_THRESHOLD:.0%} vs {args.compare}")


if __name__ == "__main__":
    main()
