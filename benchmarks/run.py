"""Benchmark harness: one module per paper table/figure (DESIGN.md §6 index).

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks sweeps; the
roofline module additionally needs experiments/dryrun artifacts.

Modules that return their rows also get a machine-readable perf record
``BENCH_<name>.json`` written into ``--out-dir`` (e.g. ``BENCH_detection.json``
for the fleet-detection fused-vs-per-layer comparison, with the serving bench
record alongside) — CI uploads these as artifacts so perf history is diffable
per commit.
"""

import argparse
import json
import os
import sys
import traceback

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

MODULES = [
    ("layer_stacking", "Fig.4/§5.2"),
    ("layer_width", "§5.3"),
    ("memory_bench", "Table2/Fig.3/§5.1"),
    ("quantization_bench", "Fig.5/§6.1"),
    ("pruning_bench", "§6.2"),
    ("multipart_bench", "§6.3"),
    ("perf_gap", "§5.4"),
    ("casestudy_bench", "§7"),
    ("serving_bench", "PR1-continuous"),
    ("detection_bench", "§7-fleet"),
    ("roofline", "§Roofline"),
]


def bench_json_name(module: str) -> str:
    short = module[:-len("_bench")] if module.endswith("_bench") else module
    return f"BENCH_{short}.json"


def write_bench_json(out_dir: str, module: str, ref: str, quick: bool,
                     rows) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, bench_json_name(module))
    with open(path, "w") as f:
        json.dump({"module": module, "paper_ref": ref, "quick": quick,
                   "rows": rows}, f, indent=2)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json perf records")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, ref in MODULES:
        if only and name not in only:
            continue
        print(f"# --- {name} ({ref}) ---", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rows = mod.main(quick=args.quick)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
            continue
        if isinstance(rows, list) and rows and isinstance(rows[0], dict):
            path = write_bench_json(args.out_dir, name, ref, args.quick, rows)
            print(f"# wrote {path}", flush=True)
    if failures:
        sys.exit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
