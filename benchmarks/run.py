"""Benchmark harness: one module per paper table/figure (DESIGN.md §6 index).

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks sweeps; the
roofline module additionally needs experiments/dryrun artifacts.
"""

import argparse
import sys
import traceback

MODULES = [
    ("layer_stacking", "Fig.4/§5.2"),
    ("layer_width", "§5.3"),
    ("memory_bench", "Table2/Fig.3/§5.1"),
    ("quantization_bench", "Fig.5/§6.1"),
    ("pruning_bench", "§6.2"),
    ("multipart_bench", "§6.3"),
    ("perf_gap", "§5.4"),
    ("casestudy_bench", "§7"),
    ("detection_bench", "§7-fleet"),
    ("roofline", "§Roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, ref in MODULES:
        if only and name not in only:
            continue
        print(f"# --- {name} ({ref}) ---", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=args.quick)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
