"""Wave vs continuous batching under a skewed request-length workload.

The workload mixes many short completions with a few long ones (the shape
that breaks wave batching: every wave stalls on its longest request, so
short requests pay the long tail's latency and the slots idle).  Both
engines serve the same requests from the same params; we report aggregate
decode throughput (generated tokens / wall time) and p50/p99 per-request
latency (submit-to-retire, all requests submitted at t0).

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--arch qwen3_8b]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serving import ContinuousEngine, Engine, Request


def skewed_requests(n: int, *, prompt_len: int, short_new: int, long_new: int,
                    long_every: int, vocab: int, seed: int = 0):
    """1-in-`long_every` requests decode `long_new` tokens, the rest
    `short_new` — interleaved so every wave catches a straggler."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        max_new = long_new if i % long_every == 0 else short_new
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, prompt_len).astype(np.int32),
            max_new_tokens=max_new))
    return reqs


def summarize(name: str, done, wall_s: float):
    lat = np.asarray([c.finished_s for c in done])
    toks = sum(len(c.tokens) for c in done)
    tps = toks / wall_s
    print(f"{name}: {toks} tokens in {wall_s:.2f}s -> {tps:.1f} tok/s | "
          f"latency p50={np.percentile(lat, 50) * 1e3:.0f}ms "
          f"p99={np.percentile(lat, 99) * 1e3:.0f}ms")
    return tps, lat


def main(quick: bool = False, arch: str = "qwen3_8b", requests: int = 0,
         slots: int = 4, cache_len: int = 128, prompt_len: int = 8,
         short_new: int = 0, long_new: int = 0, long_every: int = 5):
    requests = requests or (12 if quick else 24)
    short_new = short_new or (6 if quick else 8)
    long_new = long_new or (32 if quick else 64)

    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = skewed_requests(requests, prompt_len=prompt_len,
                           short_new=short_new, long_new=long_new,
                           long_every=long_every, vocab=cfg.vocab)
    total_new = sum(r.max_new_tokens for r in reqs)
    print(f"{cfg.name} (reduced): {requests} requests, "
          f"{total_new} decode tokens, slots={slots}, "
          f"lengths {short_new}/{long_new} "
          f"(1 in {long_every} long)")

    # warmup both engines (compile decode/prefill outside the timed region)
    warm = [Request(uid=-1, prompt=reqs[0].prompt, max_new_tokens=2)]
    wave = Engine(api, params, batch_slots=slots, cache_len=cache_len)
    wave.serve(warm * slots)
    cont = ContinuousEngine(api, params, batch_slots=slots,
                            cache_len=cache_len)
    cont.serve(warm)

    t0 = time.perf_counter()
    done_w = wave.serve(reqs)
    wall_w = time.perf_counter() - t0
    tps_w, lat_w = summarize("wave      ", done_w, wall_w)

    t0 = time.perf_counter()
    done_c = cont.serve(reqs)
    wall_c = time.perf_counter() - t0
    tps_c, lat_c = summarize("continuous", done_c, wall_c)

    speedup = tps_c / tps_w
    print(f"continuous/wave throughput: {speedup:.2f}x "
          f"({cont.last_stats.steps} continuous steps)")
    rows = [
        {"name": "serving_wave",
         "us_per_call": wall_w / total_new * 1e6,
         "derived": f"tok_s={tps_w:.1f};"
                    f"p99_s={np.percentile(lat_w, 99):.2f}"},
        {"name": "serving_continuous",
         "us_per_call": wall_c / total_new * 1e6,
         "derived": f"tok_s={tps_c:.1f};"
                    f"p99_s={np.percentile(lat_c, 99):.2f};"
                    f"speedup={speedup:.2f}x"},
    ]
    # harness contract: name,us_per_call,derived
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.3f},{r['derived']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--short-new", type=int, default=0)
    ap.add_argument("--long-new", type=int, default=0)
    ap.add_argument("--long-every", type=int, default=5)
    a = ap.parse_args()
    main(quick=a.quick, arch=a.arch, requests=a.requests, slots=a.slots,
         cache_len=a.cache_len, prompt_len=a.prompt_len,
         short_new=a.short_new, long_new=a.long_new,
         long_every=a.long_every)
