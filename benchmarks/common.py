"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) and returns its rows for benchmarks.run to aggregate.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

Row = Dict[str, Any]


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(rows: List[Row]) -> List[Row]:
    for r in rows:
        derived = r.get("derived", "")
        print(f"{r['name']},{r['us_per_call']:.3f},{derived}", flush=True)
    return rows


def linear_fit(xs, ys):
    """Least-squares slope/intercept + R^2 (for the paper's linearity claims)."""
    xs = np.asarray(xs, float)
    ys = np.asarray(ys, float)
    slope, intercept = np.polyfit(xs, ys, 1)
    pred = slope * xs + intercept
    ss_res = float(((ys - pred) ** 2).sum())
    ss_tot = float(((ys - ys.mean()) ** 2).sum()) or 1.0
    return float(slope), float(intercept), 1.0 - ss_res / ss_tot
