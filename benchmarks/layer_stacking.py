"""§5.2 / Fig. 4: inference CPU time vs number of stacked 64-neuron dense
layers — ICSML runtime (planned arena execution) vs the XLA baseline (plain
jnp forward, our TFLite stand-in).  The paper's claims: dot-product,
activation and total inference times scale LINEARLY with depth, and the
optimized baseline is a constant factor faster."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, linear_fit, time_fn
from repro.configs.icsml_mlp import BENCH_FEATURES
from repro.core import layers as L, sequential

DEPTHS = (1, 2, 4, 8, 16, 32)


def build(depth: int):
    layers = [L.Input()] + [
        L.Dense(units=BENCH_FEATURES, activation="relu") for _ in range(depth)
    ]
    m = sequential(layers, (BENCH_FEATURES,))
    return m, m.init_params(jax.random.PRNGKey(0))


def main(quick: bool = False):
    rows = []
    depths = DEPTHS[:4] if quick else DEPTHS
    # batched measurement: a modern CPU is dispatch-bound on a 64-wide MLP,
    # so per-sample cost is measured over a vmapped batch (the PLC regime is
    # compute-bound; batching recovers the compute-scaling signal)
    batch = 512
    xb = jax.random.normal(jax.random.PRNGKey(1), (batch, BENCH_FEATURES))

    icsml_t, base_t = [], []
    for depth in depths:
        m, p = build(depth)
        planned = jax.jit(jax.vmap(m.apply_planned, in_axes=(None, 0)))
        baseline = jax.jit(jax.vmap(m.apply, in_axes=(None, 0)))
        t_i = time_fn(lambda: planned(p, xb)) / batch
        t_b = time_fn(lambda: baseline(p, xb)) / batch
        icsml_t.append(t_i)
        base_t.append(t_b)
        rows.append({"name": f"layer_stacking/icsml/L{depth}", "us_per_call": t_i,
                     "derived": f"baseline_us={t_b:.3f}"})

    slope_i, _, r2_i = linear_fit(depths, icsml_t)
    slope_b, _, r2_b = linear_fit(depths, base_t)
    ratio = sum(i / b for i, b in zip(icsml_t, base_t)) / len(depths)
    rows.append({"name": "layer_stacking/us_per_layer_icsml",
                 "us_per_call": slope_i, "derived": f"R2={r2_i:.4f}"})
    rows.append({"name": "layer_stacking/us_per_layer_baseline",
                 "us_per_call": slope_b, "derived": f"R2={r2_b:.4f}"})
    rows.append({"name": "layer_stacking/icsml_vs_baseline_ratio",
                 "us_per_call": ratio,
                 "derived": "paper=29.38x_vs_TFLite"})
    return emit(rows)


if __name__ == "__main__":
    main()
