"""§7: case-study metrics — detection accuracy, detection latency over the
seven attack families, and §7.2 non-intrusiveness (Wd statistics with and
without the defense in the loop)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import SlidingWindowDetector, porting
from repro.sim import build_dataset, simulate, train_detector
from repro.sim.msf import SCAN_DT


def main(quick: bool = False):
    rows = []
    scale = 0.12 if quick else 0.4
    x, y = build_dataset(normal_cycles=int(42_000 * scale),
                         attack_cycles=int(5_700 * scale), stride=8, seed=0)
    model, res = train_detector(x, y, epochs=25 if quick else 80,
                                patience=8 if quick else 15, lr=1e-3)
    rows.append({"name": "casestudy/test_accuracy",
                 "us_per_call": res.test_acc * 100,
                 "derived": "paper=93.68pct"})

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        ported, pparams = porting.port_mlp(model, res.params, tmp)

    # detection latency per attack family, unseen seeds
    attack_start = 600
    for attack_id in range(1, 8):
        detector = SlidingWindowDetector(ported, pparams, window=200,
                                         n_features=2, n_segments=2)
        detections = []

        def hook(cycle, reading):
            r = np.array([(reading[0] - 89.6) / 2.0,
                          (reading[1] - 19.18) / 0.5], np.float32)
            detector.push(r)
            out = detector.tick(cycle)
            if out is not None and out[1] != 0:
                detections.append(out[0])

        simulate(1400 if quick else 2200, attack_id=attack_id,
                 attack_start=attack_start, seed=500 + attack_id,
                 defense_hook=hook)
        first = [d for d in detections if d >= attack_start]
        lat = (first[0] - attack_start) * SCAN_DT if first else float("nan")
        fp = sum(1 for d in detections if d < attack_start)
        rows.append({"name": f"casestudy/detect_latency_s/attack{attack_id}",
                     "us_per_call": lat * 1e6 if first else -1.0,
                     "derived": f"latency_s={lat:.1f};false_pos={fp};paper=5.0s"})

    # §7.2 non-intrusiveness
    n = 1500 if quick else 3000
    off = simulate(n, seed=321)
    det = SlidingWindowDetector(ported, pparams, window=200, n_features=2,
                                n_segments=2)

    def hook2(cycle, reading):
        det.push(np.array([(reading[0] - 89.6) / 2.0,
                           (reading[1] - 19.18) / 0.5], np.float32))
        det.tick(cycle)

    on = simulate(n, seed=321, defense_hook=hook2)
    seg = slice(n // 2, None)
    rows.append({"name": "casestudy/nonintrusive_wd_mean_off",
                 "us_per_call": off.wd_meas[seg].mean() * 1e3,
                 "derived": f"std={off.wd_meas[seg].std():.2e};paper_mean=19.18"})
    rows.append({"name": "casestudy/nonintrusive_wd_mean_on",
                 "us_per_call": on.wd_meas[seg].mean() * 1e3,
                 "derived": (f"std={on.wd_meas[seg].std():.2e};"
                             f"identical={bool(np.allclose(off.wd_meas, on.wd_meas))}")})
    return emit(rows)


if __name__ == "__main__":
    main()
