"""§6.2: pruning + operation skipping, TPU-adapted.

Paper experiment (784-in/512-out dense layer, WAGO): zeroed weights don't
speed up dense dot products (no runtime skipping), per-element IF-skip only
pays under quantization.  TPU adaptation: block-granular skipping — the
Pallas block-sparse kernel's grid shrinks with sparsity, so work drops
structurally.  We measure the XLA dense matvec vs the block-skip path at
several sparsities and report the kernel-grid economics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.icsml_mlp import PRUNE_LAYER
from repro.core import prune
from repro.kernels import ops

SPARSITIES = (0.0, 0.25, 0.5, 0.75)


def main(quick: bool = False):
    rows = []
    n_in, n_out = PRUNE_LAYER          # 784 x 512
    n_in_pad = 896                     # pad 784 -> 7 blocks of 128
    w = jax.random.normal(jax.random.PRNGKey(0), (n_in_pad, n_out))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, n_in_pad))

    dense = jax.jit(lambda x, w: x @ w)
    t_dense = time_fn(lambda: dense(x, w))
    rows.append({"name": "pruning/dense_matmul", "us_per_call": t_dense,
                 "derived": "paper_wago=52.13ms_dense"})

    # zeroed weights, still dense: no automatic skipping (paper: 47.62ms)
    wz = jnp.zeros_like(w)
    t_zero = time_fn(lambda: dense(x, wz))
    rows.append({"name": "pruning/dense_all_zero", "us_per_call": t_zero,
                 "derived": f"speedup={t_dense / max(t_zero, 1e-9):.2f}x;"
                            "paper=no_auto_skip"})

    for s in SPARSITIES:
        wp = prune.block_magnitude_prune(w, s, (128, 128))
        bs = prune.compress_blocks(wp, (128, 128))
        sparse = jax.jit(lambda x: ops.sparse_dense(x, bs, backend="ref"))
        t_s = time_fn(lambda: sparse(x))
        total_blocks = (n_in_pad // 128) * (n_out // 128)
        rows.append({
            "name": f"pruning/block_skip/s{int(s * 100)}",
            "us_per_call": t_s,
            "derived": (f"nnz_blocks={bs.nnz_blocks}/{total_blocks};"
                        f"flop_frac={bs.nnz_blocks / total_blocks:.2f}")})
    return emit(rows)


if __name__ == "__main__":
    main()
