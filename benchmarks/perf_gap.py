"""§5.4: decomposing the ICSML-vs-optimized-framework gap.

The paper attributes its ~20-30x gap to TFLite as ≈2x profiler overhead x
≈4x missing compiler optimizations x ≈3x no optimized math libraries.  Our
analogue: the ICSML-faithful interpretation-style execution (arena reads/
writes per layer, unfused) vs progressively optimized variants:

  A. arena execution, jit disabled        (no compiler: the -O0 analogue)
  B. arena execution, jit                 (compiler on)
  C. reference execution, jit             (no arena copy discipline)
  D. batched vmap execution, jit          (library-grade vectorization)

Ratios A/B ≈ compiler factor, B/C ≈ memory-discipline overhead, C/D ≈
vectorized-library factor.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core import layers as L, sequential


def main(quick: bool = False):
    m = sequential([L.Input()] + [L.Dense(units=64, activation="relu")
                                  for _ in range(8)], (64,))
    p = m.init_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    xb = jax.random.normal(jax.random.PRNGKey(2), (64, 64))

    a = time_fn(lambda: m.apply_planned(p, x), warmup=1, iters=3)
    jit_planned = jax.jit(m.apply_planned)
    b = time_fn(lambda: jit_planned(p, x))
    jit_ref = jax.jit(m.apply)
    c = time_fn(lambda: jit_ref(p, x))
    batched = jax.jit(jax.vmap(m.apply, in_axes=(None, 0)))
    d = time_fn(lambda: batched(p, xb)) / 64.0   # per-sample

    rows = [
        {"name": "perf_gap/A_unjitted_arena", "us_per_call": a, "derived": ""},
        {"name": "perf_gap/B_jit_arena", "us_per_call": b,
         "derived": f"compiler_factor={a / b:.1f}x;paper~4x"},
        {"name": "perf_gap/C_jit_reference", "us_per_call": c,
         "derived": f"arena_overhead={b / c:.2f}x"},
        {"name": "perf_gap/D_jit_vmap_per_sample", "us_per_call": d,
         "derived": f"library_factor={c / d:.1f}x;paper~3x"},
        {"name": "perf_gap/total", "us_per_call": a / d,
         "derived": "paper_total~29x_vs_TFLite"},
    ]
    return emit(rows)


if __name__ == "__main__":
    main()
