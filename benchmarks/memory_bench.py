"""§5.1 + Table 2 + Fig. 3: memory accounting.

* Table 2 byte-exact reproduction for the 512x512 layer under
  SINT/INT/DINT/REAL (analytic, asserted).
* §5.1 linear relation between layer size and memory use.
* Fig. 3 style accounting: which PLCs could hold which Keras-size models,
  plus the dataMem arena-reuse saving our planner provides on top.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import layers as L, memory, quantize, sequential

PAPER_TABLE2 = {
    "SINT": 266_244, "INT": 528_388, "DINT": 1_052_676, "REAL": 1_050_624,
}

# (name, RAM bytes) — from paper Table 1 / Fig. 3
PLCS = [
    ("AB_Micro810", 2 * 1024),
    ("Mitsubishi_iQ-R", 4 * 1024 ** 2),
    ("Schneider_M241", 64 * 1024 ** 2),
    ("WAGO_PFC100", 256 * 1024 ** 2),
    ("WAGO_PFC200", 512 * 1024 ** 2),
]

# (model, parameter count) — Keras Applications (Fig. 3), 32-bit params
KERAS_MODELS = [
    ("MobileNetV2", 3_538_984),
    ("MobileNet", 4_253_864),
    ("EfficientNetB0", 5_330_571),
    ("DenseNet121", 8_062_504),
    ("ResNet50", 25_636_712),
    ("NASNetLarge", 88_949_818),
]


def main(quick: bool = False):
    rows = []

    # ---- Table 2 byte-exact ----
    for scheme, want in PAPER_TABLE2.items():
        got = quantize.memory_report(512, 512, scheme)["total"]
        assert got == want, (scheme, got, want)
        rows.append({"name": f"memory/table2/{scheme}_bytes",
                     "us_per_call": float(got),
                     "derived": f"paper={want};match={got == want}"})

    # ---- §5.1 linearity: layer memory vs size ----
    for width in (64, 128, 256, 512):
        m = sequential([L.Input(),
                        L.Dense(units=width, activation="relu")], (width,))
        plan = m.memory_plan()
        total = m.param_bytes() + plan.arena_bytes
        rows.append({"name": f"memory/layer_total_bytes/W{width}",
                     "us_per_call": float(total),
                     "derived": f"params={m.param_bytes()};arena={plan.arena_bytes}"})

    # ---- Fig. 3: which PLC fits which model (f32 vs SINT) ----
    for mname, n_params in KERAS_MODELS:
        f32 = n_params * 4
        sint = n_params * 1
        fits_f32 = sum(1 for _, ram in PLCS if f32 <= ram)
        fits_sint = sum(1 for _, ram in PLCS if sint <= ram)
        rows.append({"name": f"memory/fig3/{mname}",
                     "us_per_call": float(f32),
                     "derived": f"plcs_fitting_f32={fits_f32};sint={fits_sint}"})

    # ---- dataMem arena reuse (our planner on a deep model) ----
    deep = sequential([L.Input()] + [L.Dense(units=256, activation="relu")
                                     for _ in range(16)], (256,))
    ab = memory.activation_bytes(deep.graph, (256,))
    rows.append({"name": "memory/arena_reuse_saving",
                 "us_per_call": float(ab["naive"] - ab["planned"]),
                 "derived": f"naive={ab['naive']};planned={ab['planned']}"})
    return emit(rows)


if __name__ == "__main__":
    main()
