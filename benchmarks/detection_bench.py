"""Fleet detection serving: fused vs per-layer steps vs naive loop.

Workload: a >=16-plant fleet of mixed scenarios streaming at the scan cycle.
All paths see the identical pre-generated reading matrix (simulation cost is
excluded); we report windows/s and p99 verdict latency for

  * the naive baseline: one float ``model.apply`` jit call per ready stream,
    per-stream np.roll ring maintenance (the §7 single-plant idiom applied
    per plant),
  * the batched StreamEngine under REAL and SINT/INT/DINT (§6.1), each in
    BOTH step flavors: the per-layer loop (one qmatmul/matmul dispatch per
    Dense layer) and the fused whole-MLP kernel (ONE Pallas dispatch per
    verdict step, weights VMEM-resident, in-kernel SINT requantization).

``benchmarks/run.py`` persists the returned rows as ``BENCH_detection.json``
(the fused-vs-per-layer perf record for the 16-plant fleet).

Run:  PYTHONPATH=src python benchmarks/detection_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import msf_detector as spec
from repro.core import quantize
from repro.serving import StreamEngine
from repro.sim import build_detector, build_fleet

Row = dict


def generate_readings(n_streams: int, n_cycles: int, seed: int) -> np.ndarray:
    """(C, S, F) raw sensor readings from a mixed-scenario fleet."""
    fleet = build_fleet(n_plants=n_streams, seed=seed)
    out = np.zeros((n_cycles, n_streams, spec.N_FEATURES), np.float32)
    for c in range(n_cycles):
        for i, s in enumerate(fleet):
            r = s.step()
            out[c, i] = (r.tb0_meas, r.wd_meas)
    return out


def run_engine(model, params, readings, *, stride: int,
               fused: bool = True) -> tuple:
    n_cycles, n_streams, _ = readings.shape
    eng = StreamEngine(model, params, n_streams=n_streams, stride=stride,
                       fused=fused)
    eng.warmup()
    t0 = time.perf_counter()
    for c in range(n_cycles):
        eng.ingest(readings[c])
    wall = time.perf_counter() - t0
    return eng.stats.windows, wall, eng.stats.latency_p(99)


def run_naive(model, params, readings, *, stride: int) -> tuple:
    """Per-stream float loop: np.roll ring + one jit apply per ready stream."""
    n_cycles, n_streams, n_feat = readings.shape
    window = spec.WINDOW
    apply1 = jax.jit(model.apply)
    mean = np.asarray(spec.NORM_MEAN, np.float32)
    std = np.asarray(spec.NORM_STD, np.float32)
    # warmup compile outside the timed region (same courtesy as the engine)
    jax.block_until_ready(apply1(params, jnp.zeros((window * n_feat,))))
    rings = np.zeros((n_streams, window, n_feat), np.float32)
    windows = 0
    latencies = []
    t0 = time.perf_counter()
    for c in range(n_cycles):
        tc = time.perf_counter()
        norm = (readings[c] - mean) / std
        rings = np.roll(rings, -1, axis=1)
        rings[:, -1, :] = norm
        count = c + 1
        if count >= window and (count - window) % stride == 0:
            outs = []
            for i in range(n_streams):
                outs.append(apply1(params, jnp.asarray(rings[i].reshape(-1))))
            for o in outs:
                jax.block_until_ready(o)
            windows += n_streams
            latencies.append(time.perf_counter() - tc)
    wall = time.perf_counter() - t0
    p99 = float(np.percentile(latencies, 99)) if latencies else 0.0
    return windows, wall, p99


def main(quick: bool = False, n_streams: int = 16, n_cycles: int = 0):
    n_cycles = n_cycles or (400 if quick else 1200)
    stride = spec.STRIDE

    print(f"# fleet: {n_streams} plants, {n_cycles} cycles, "
          f"window={spec.WINDOW}, stride={stride}")
    readings = generate_readings(n_streams, n_cycles, seed=0)

    model = build_detector()
    params = model.init_params(jax.random.PRNGKey(0))
    calib = [jnp.asarray(np.random.default_rng(1).normal(size=spec.INPUT_SIZE)
                         .astype(np.float32)) for _ in range(8)]

    rows = []
    w_naive, wall_naive, p99_naive = run_naive(model, params, readings,
                                               stride=stride)
    wps_naive = w_naive / wall_naive
    rows.append({"name": "detect_naive_float",
                 "us_per_call": wall_naive / max(w_naive, 1) * 1e6,
                 "derived": f"windows_s={wps_naive:.0f};"
                            f"p99_ms={p99_naive * 1e3:.2f}"})

    variants = [("REAL", params)]
    for scheme in quantize.SCHEMES:
        variants.append((scheme, quantize.quantize_params(
            model, params, scheme, calibration=calib)))
    speedup_sint = 0.0
    fused_vs_perlayer_sint = 0.0
    for scheme, p in variants:
        w_pl, wall_pl, p99_pl = run_engine(model, p, readings, stride=stride,
                                           fused=False)
        wps_pl = w_pl / wall_pl
        rows.append({"name": f"detect_engine_{scheme.lower()}_perlayer",
                     "us_per_call": wall_pl / max(w_pl, 1) * 1e6,
                     "derived": f"windows_s={wps_pl:.0f};"
                                f"p99_ms={p99_pl * 1e3:.2f};"
                                f"vs_naive={wps_pl / wps_naive:.2f}x"})
        w_f, wall_f, p99_f = run_engine(model, p, readings, stride=stride,
                                        fused=True)
        wps_f = w_f / wall_f
        fused_gain = wps_f / wps_pl
        if scheme == "SINT":
            speedup_sint = wps_f / wps_naive
            fused_vs_perlayer_sint = fused_gain
        rows.append({"name": f"detect_engine_{scheme.lower()}_fused",
                     "us_per_call": wall_f / max(w_f, 1) * 1e6,
                     "derived": f"windows_s={wps_f:.0f};"
                                f"p99_ms={p99_f * 1e3:.2f};"
                                f"vs_naive={wps_f / wps_naive:.2f}x;"
                                f"vs_perlayer={fused_gain:.2f}x"})
    emit(rows)
    print(f"# fused SINT vs naive float: {speedup_sint:.2f}x windows/s; "
          f"fused vs per-layer step: {fused_vs_perlayer_sint:.2f}x")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--streams", type=int, default=16)
    ap.add_argument("--cycles", type=int, default=0)
    a = ap.parse_args()
    main(quick=a.quick, n_streams=a.streams, n_cycles=a.cycles)
