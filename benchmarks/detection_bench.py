"""Fleet detection serving: fused vs per-layer steps vs naive loop, plus
multi-device fleet-sharding scaling rows.

Workload: a >=16-plant fleet of mixed scenarios streaming at the scan cycle.
All paths see the identical pre-generated reading matrix (simulation cost is
excluded); we report windows/s and p99 verdict latency for

  * the naive baseline: one float ``model.apply`` jit call per ready stream,
    per-stream np.roll ring maintenance (the §7 single-plant idiom applied
    per plant),
  * the batched StreamEngine under REAL and SINT/INT/DINT (§6.1), each in
    BOTH step flavors: the per-layer loop (one qmatmul/matmul dispatch per
    Dense layer) and the fused whole-MLP kernel (ONE Pallas dispatch per
    verdict step, weights VMEM-resident, in-kernel SINT requantization).
    The two flavors are timed in *interleaved* passes (``run_engine_pair``)
    so shared-core load transients tax both equally.

**Autoencoder rows** (``detect_ae_*``): the unsupervised 400-64-16-64-400
reconstruction detector on the identical readings, fused vs per-layer at
REAL/SINT (SINT kept under ``--quick`` so the CI artifact always carries
the fused autoencoder row) plus its own ``detect_ae_shard_d<N>``
device-scaling ladder — verdicts via the ReconstructionHead's on-device
score reduction, so sharded hosts gather one float per stream.

**Grouped-fleet rows** (``detect_grouped_*``): the heterogeneous
model-group question — the fleet split four ways across
classifier/autoencoder/margin/forecast groups served by ONE
``GroupedStreamEngine`` (a single jitted step, one fused dispatch per
group — ``megakernel=False`` pins that flavor so the row keeps measuring
it) vs one ``StreamEngine`` per model; ``vs_split`` is the paired-pass
grouped speedup.

**Megakernel rows** (``detect_grouped_*_mega``): the same four-group fleet
served by the single-dispatch grouped megakernel (ONE ``pallas_call`` per
verdict step for the whole fleet — packed weight arena, per-group scales
and in-kernel head epilogues) vs the per-group flavor above, interleaved
paired passes; ``vs_pergroup`` is the paired-median megakernel speedup and
``p99_pergroup_ms`` the comparator's tail from the same pairing.  Dispatch
accounting (1 per mega step vs one per group) is asserted inside the pair
runner, not assumed.

**Sustained-throughput rows** (``detect_sustained_*``): the async
double-buffered pipeline (``async_depth=1``) vs the synchronous engine
under continuous per-cycle arrival — both run the identical fused SINT
step; async overlaps host ingest of cycle N+1 with the device's in-flight
step N and drains with ``flush()`` inside the timed region.  ``vs_sync``
is the paired-median async speedup; the async p99 is dispatch→harvest (a
one-boundary span) by definition, so it is not comparable to the sync p99.

**Device scaling** (``detect_fleet_shard_d<N>`` rows): the stream-axis
sharded engine at 1/2/4/8 devices (1/2 under ``--quick``), each device
owning a ``spec.STREAMS_PER_DEVICE``-plant shard of the fleet (weak
scaling — the fleet grows with the mesh, which is the fleet-service
deployment question: how many plants does a d-device mesh serve?).  Each
device count runs in a child process so ``XLA_FLAGS=
--xla_force_host_platform_device_count`` can fan out host devices; on a
multi-core host the rows show the aggregate windows/s growing with the
mesh, and on real multi-chip hardware each shard runs on its own core.

``benchmarks/run.py`` persists the returned rows as ``BENCH_detection.json``
(the fused-vs-per-layer + device-scaling perf record).

Run:  PYTHONPATH=src python benchmarks/detection_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import msf_detector as spec
from repro.core import quantize
from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine
from repro.sim import (ForecastHead, MarginHead, ReconstructionHead,
                       build_autoencoder, build_detector, build_forecaster,
                       build_margin_model, fleet_readings)

Row = dict

# Serving throughput is content-independent, so bench verdict thresholds
# don't need calibration — any finite cutoff exercises the same score math.
BENCH_AE_THRESHOLD = 1.0


def generate_readings(n_streams: int, n_cycles: int, seed: int) -> np.ndarray:
    """(C, S, F) raw sensor readings from a mixed-scenario fleet."""
    return fleet_readings(n_streams, n_cycles, seed=seed)


def run_engine_pair(model, params, readings, *, stride: int,
                    head=None, reps: int = 12) -> dict:
    """Fused and per-layer engines measured in *interleaved* passes: both
    engines are built, warmed up and ring-filled up front (uncounted), then
    timed steady-state passes alternate flavor, so a load transient on a
    shared CI box taxes both equally (measuring them minutes apart lets
    noise decide the comparison).  Returns {fused: (windows, wall_s, p99_s),
    "ratio": r}: per flavor the best pass is kept (p99 from that same best
    pass's verdict latencies, so latency rows stay comparable with the
    pre-pair BENCH history), and ``ratio`` (fused windows/s over per-layer
    windows/s) is the **median of per-rep paired ratios** — within a rep
    the two passes run back to back, so a load transient scales both walls
    and cancels out of the quotient; independent best-of-N would throw that
    pairing away and let cross-rep load swings decide the comparison."""
    n_cycles, n_streams, _ = readings.shape
    engines = {}
    for fused in (False, True):
        eng = StreamEngine(model, params, n_streams=n_streams, stride=stride,
                           fused=fused, head=head)
        eng.warmup()
        for c in range(min(spec.WINDOW, n_cycles)):
            eng.ingest(readings[c % n_cycles])
        engines[fused] = eng
    best = {False: None, True: None}
    ratios = []
    for rep in range(reps):
        # Alternate which flavor goes first so any systematic first-in-rep
        # effect (cache state, GC debt) cancels instead of biasing one side.
        order = (False, True) if rep % 2 == 0 else (True, False)
        walls = {}
        for fused in order:
            eng = engines[fused]
            w0 = eng.stats.windows
            # Per-pass latency tails come from a per-pass reservoir swap:
            # tail *slices* are silently wrong (and now raise) once the
            # reservoir passes capacity and Algorithm R shuffles retention.
            eng.stats.reset_latencies()
            t0 = time.perf_counter()
            for c in range(n_cycles):
                eng.ingest(readings[c])
            wall = time.perf_counter() - t0
            windows = eng.stats.windows - w0
            walls[fused] = wall
            lats = list(eng.stats.latencies_s)
            if best[fused] is None or wall / max(windows, 1) < \
                    best[fused][1] / max(best[fused][0], 1):
                best[fused] = (windows, wall,
                               float(np.percentile(lats, 99)) if lats
                               else 0.0)
        ratios.append(walls[False] / walls[True])   # = wps_f / wps_pl
    best["ratio"] = float(np.median(ratios))
    return best


def run_sustained_pair(model, params, readings, *, stride: int,
                       reps: int = 12) -> dict:
    """Async double-buffered vs synchronous engine under continuous arrival,
    interleaved-pass discipline (run_engine_pair conventions).  Both engines
    run the identical fused step; the async engine dispatches step N and
    returns to ingest cycle N+1 while the device works, harvesting at the
    next ready boundary, and each timed pass ends with ``flush()`` so every
    dispatched window is also harvested inside its own pass.  Returns
    {0: sync (windows, wall_s, p99_s), 1: async ..., "ratio": r} with
    ``ratio`` = median paired sync-wall / async-wall (async speedup)."""
    n_cycles, n_streams, _ = readings.shape
    engines = {}
    for depth in (0, 1):
        eng = StreamEngine(model, params, n_streams=n_streams, stride=stride,
                           fused=True, async_depth=depth)
        eng.warmup()
        for c in range(min(spec.WINDOW, n_cycles)):   # ring fill, uncounted
            eng.ingest(readings[c % n_cycles])
        eng.flush()          # nothing in flight crosses into the timed reps
        engines[depth] = eng
    best = {0: None, 1: None}
    ratios = []
    for rep in range(reps):
        order = (0, 1) if rep % 2 == 0 else (1, 0)
        walls = {}
        for depth in order:
            eng = engines[depth]
            w0 = eng.stats.windows
            eng.stats.reset_latencies()
            t0 = time.perf_counter()
            for c in range(n_cycles):
                eng.ingest(readings[c])
            eng.flush()
            wall = time.perf_counter() - t0
            windows = eng.stats.windows - w0
            walls[depth] = wall
            lats = list(eng.stats.latencies_s)
            if best[depth] is None or wall / max(windows, 1) < \
                    best[depth][1] / max(best[depth][0], 1):
                best[depth] = (windows, wall,
                               float(np.percentile(lats, 99)) if lats
                               else 0.0)
        ratios.append(walls[0] / walls[1])   # = wps_async / wps_sync
    # Both flavors run the fused single-model step: one logical dispatch
    # per verdict step, asserted so the row can't silently degrade to the
    # per-layer path.
    for eng in engines.values():
        assert eng.stats.dispatches == eng.stats.steps, \
            (eng.stats.dispatches, eng.stats.steps)
    best["ratio"] = float(np.median(ratios))
    return best


def run_naive(model, params, readings, *, stride: int,
              reps: int = 12) -> tuple:
    """Per-stream float loop: np.roll ring + one jit apply per ready stream.

    Best of ``reps`` passes — the same sample count as ``run_engine_pair``'s
    flavors, so vs_naive ratios don't reward the engine rows with a deeper
    best-of draw than their denominator."""
    n_cycles, n_streams, n_feat = readings.shape
    window = spec.WINDOW
    apply1 = jax.jit(model.apply)
    mean = np.asarray(spec.NORM_MEAN, np.float32)
    std = np.asarray(spec.NORM_STD, np.float32)
    # warmup compile outside the timed region (same courtesy as the engine)
    jax.block_until_ready(apply1(params, jnp.zeros((window * n_feat,))))
    rings = np.zeros((n_streams, window, n_feat), np.float32)
    count = 0

    def run_pass():
        nonlocal rings, count
        windows = 0
        latencies = []
        t0 = time.perf_counter()
        for c in range(n_cycles):
            tc = time.perf_counter()
            norm = (readings[c] - mean) / std
            rings = np.roll(rings, -1, axis=1)
            rings[:, -1, :] = norm
            count += 1
            if count >= window and (count - window) % stride == 0:
                outs = []
                for i in range(n_streams):
                    outs.append(
                        apply1(params, jnp.asarray(rings[i].reshape(-1))))
                for o in outs:
                    jax.block_until_ready(o)
                windows += n_streams
                latencies.append(time.perf_counter() - tc)
        return windows, time.perf_counter() - t0, latencies

    # same steady-state best-pass discipline as run_engine_pair: throughput
    # AND p99 come from the single best pass, never pooled across reps.
    run_pass()
    windows, wall, lats = min((run_pass() for _ in range(reps)),
                              key=lambda r: r[1] / max(r[0], 1))
    p99 = float(np.percentile(lats, 99)) if lats else 0.0
    return windows, wall, p99


def mixed_group_detectors(scheme: str, calib) -> list:
    """(name, model, params, head) for the four-way heterogeneous fleet:
    classifier + autoencoder + one-class margin + next-step forecaster,
    each optionally quantized (the forecaster's calibration samples pass
    through its head's window view, like serving will)."""
    heads = {
        "mlp": None,
        "ae": ReconstructionHead(threshold=BENCH_AE_THRESHOLD),
        "margin": MarginHead(threshold=BENCH_AE_THRESHOLD,
                             center=(0.0,) * spec.MARGIN_EMBED),
        "forecast": ForecastHead(threshold=BENCH_AE_THRESHOLD),
    }
    builders = {"mlp": build_detector, "ae": build_autoencoder,
                "margin": build_margin_model, "forecast": build_forecaster}
    out = []
    for i, name in enumerate(("mlp", "ae", "margin", "forecast")):
        model = builders[name]()
        params = model.init_params(jax.random.PRNGKey(10 + i))
        if scheme != "REAL":
            head = heads[name]
            c = calib if head is None else [head.prepare(s) for s in calib]
            params = quantize.quantize_params(model, params, scheme,
                                              calibration=c)
        out.append((name, model, params, heads[name]))
    return out


def run_grouped_pair(detectors, readings, *, stride: int,
                     reps: int = 12) -> dict:
    """Grouped engine vs N independent split engines over the same mixed
    fleet, interleaved-pass discipline (run_engine_pair conventions).

    The deployment question: a fleet whose streams carry different models
    can be served by one :class:`GroupedStreamEngine` (one jitted step, one
    fused dispatch per group — pinned with ``megakernel=False`` so this row
    keeps measuring the per-group flavor now that packable fleets default
    to the megakernel) or by one :class:`StreamEngine` per model (one
    jitted step EACH, host python between them).  Returns
    {"grouped": (windows, wall_s, p99_s), "split": ..., "ratio": r} with
    ``ratio`` = median paired split-wall / grouped-wall (grouped speedup)."""
    n_cycles, n_streams, _ = readings.shape
    n_per = n_streams // len(detectors)
    groups = [ModelGroup(name, m, p, n_per, head)
              for name, m, p, head in detectors]
    ge = GroupedStreamEngine(groups, stride=stride, megakernel=False)
    ge.warmup()
    splits = [(i * n_per, StreamEngine(m, p, n_streams=n_per, stride=stride,
                                       head=head))
              for i, (name, m, p, head) in enumerate(detectors)]
    for eng in (e for _, e in splits):
        eng.warmup()
    for c in range(min(spec.WINDOW, n_cycles)):   # ring fill, uncounted
        ge.ingest(readings[c % n_cycles])
        for off, eng in splits:
            eng.ingest(readings[c % n_cycles][off:off + n_per])
    best = {"grouped": None, "split": None}
    ratios = []
    for rep in range(reps):
        order = (("grouped", "split") if rep % 2 == 0
                 else ("split", "grouped"))
        walls = {}
        for kind in order:
            if kind == "grouped":
                w0 = ge.stats.windows
                ge.stats.reset_latencies()   # per-pass reservoir swap
                t0 = time.perf_counter()
                for c in range(n_cycles):
                    ge.ingest(readings[c])
                wall = time.perf_counter() - t0
                windows = ge.stats.windows - w0
                lats = list(ge.stats.latencies_s)
            else:
                w0 = sum(e.stats.windows for _, e in splits)
                for _, eng in splits:
                    eng.stats.reset_latencies()
                t0 = time.perf_counter()
                for c in range(n_cycles):
                    for off, eng in splits:
                        eng.ingest(readings[c][off:off + n_per])
                wall = time.perf_counter() - t0
                windows = sum(e.stats.windows for _, e in splits) - w0
                lats = [v for _, e in splits for v in e.stats.latencies_s]
            walls[kind] = wall
            if best[kind] is None or wall / max(windows, 1) < \
                    best[kind][1] / max(best[kind][0], 1):
                best[kind] = (windows, wall,
                              float(np.percentile(lats, 99)) if lats else 0.0)
        ratios.append(walls["split"] / walls["grouped"])
    best["ratio"] = float(np.median(ratios))
    return best


def run_mega_pair(detectors, readings, *, stride: int,
                  reps: int = 12) -> dict:
    """Single-dispatch megakernel vs the per-group grouped step over the
    identical heterogeneous fleet, interleaved-pass discipline
    (run_engine_pair conventions).

    Both engines serve the same four-group fleet through ONE jitted step;
    the per-group flavor carries one fused pallas dispatch per group, the
    megakernel exactly ONE for the whole fleet (grid ``(group, M-blocks)``,
    packed weight arena, per-group quantization scales and head epilogues
    in-kernel).  Returns {"mega": (windows, wall_s, p99_s),
    "pergroup": ..., "ratio": r} with ``ratio`` = median paired
    pergroup-wall / mega-wall (megakernel speedup)."""
    n_cycles, n_streams, _ = readings.shape
    n_per = n_streams // len(detectors)
    engines = {}
    for mega in (False, True):
        groups = [ModelGroup(name, m, p, n_per, head)
                  for name, m, p, head in detectors]
        ge = GroupedStreamEngine(groups, stride=stride, shard=False,
                                 megakernel=mega)
        assert ge._mega == mega, ge._mega_reason
        ge.warmup()
        for c in range(min(spec.WINDOW, n_cycles)):   # ring fill, uncounted
            ge.ingest(readings[c % n_cycles])
        engines[mega] = ge
    best = {"mega": None, "pergroup": None}
    ratios = []
    for rep in range(reps):
        order = (False, True) if rep % 2 == 0 else (True, False)
        walls = {}
        for mega in order:
            kind = "mega" if mega else "pergroup"
            ge = engines[mega]
            w0 = ge.stats.windows
            ge.stats.reset_latencies()   # per-pass reservoir swap
            t0 = time.perf_counter()
            for c in range(n_cycles):
                ge.ingest(readings[c])
            wall = time.perf_counter() - t0
            windows = ge.stats.windows - w0
            walls[mega] = wall
            lats = list(ge.stats.latencies_s)
            if best[kind] is None or wall / max(windows, 1) < \
                    best[kind][1] / max(best[kind][0], 1):
                best[kind] = (windows, wall,
                              float(np.percentile(lats, 99)) if lats else 0.0)
        ratios.append(walls[False] / walls[True])
    # The collapsed dispatch count the rows claim, asserted: one logical
    # dispatch per megakernel step, one per group for the per-group flavor.
    for mega, ge in engines.items():
        want = ge.stats.steps * (1 if mega else len(detectors))
        assert ge.stats.dispatches == want, \
            (mega, ge.stats.dispatches, want)
    best["ratio"] = float(np.median(ratios))
    return best


def run_drift_pair(model, params, readings, *, stride: int,
                   head, reps: int = 12) -> dict:
    """Adaptive (streaming-threshold) vs frozen-threshold engines over a
    *drifting* fleet, interleaved-pass discipline (run_engine_pair
    conventions).  The rows answer two questions: what the per-step calib
    maintenance + host recalibration costs (``vs_fixed`` paired ratio, both
    engines run the same fused step otherwise) and whether the live
    threshold actually leaves the frozen calibration point on drifted
    readings (``live_thr`` in derived).  Returns {False: fixed triple,
    True: adaptive triple, "ratio": r, "live_thr": t}."""
    n_cycles, n_streams, _ = readings.shape
    engines = {}
    for adaptive in (False, True):
        eng = StreamEngine(model, params, n_streams=n_streams, stride=stride,
                           fused=True, head=head,
                           adapt=adaptive or None)
        eng.warmup()
        for c in range(min(spec.WINDOW, n_cycles)):
            eng.ingest(readings[c % n_cycles])
        engines[adaptive] = eng
    best = {False: None, True: None}
    ratios = []
    for rep in range(reps):
        order = (False, True) if rep % 2 == 0 else (True, False)
        walls = {}
        for adaptive in order:
            eng = engines[adaptive]
            w0 = eng.stats.windows
            eng.stats.reset_latencies()
            t0 = time.perf_counter()
            for c in range(n_cycles):
                eng.ingest(readings[c])
            wall = time.perf_counter() - t0
            windows = eng.stats.windows - w0
            walls[adaptive] = wall
            lats = list(eng.stats.latencies_s)
            if best[adaptive] is None or wall / max(windows, 1) < \
                    best[adaptive][1] / max(best[adaptive][0], 1):
                best[adaptive] = (windows, wall,
                                  float(np.percentile(lats, 99)) if lats
                                  else 0.0)
        ratios.append(walls[False] / walls[True])   # = wps_adapt / wps_fixed
    best["ratio"] = float(np.median(ratios))
    best["live_thr"] = engines[True].live_threshold
    return best


def synthetic_readings(n_streams: int, n_cycles: int, seed: int) -> np.ndarray:
    """Gaussian readings around the nominal operating point — engine timing
    is content-independent, and python-stepping thousands of PlantStreams
    would dwarf the serve clock at sharded fleet sizes."""
    rng = np.random.default_rng(seed)
    return (np.asarray(spec.NORM_MEAN, np.float32)
            + rng.normal(size=(n_cycles, n_streams, spec.N_FEATURES))
            .astype(np.float32) * np.asarray(spec.NORM_STD, np.float32))


def shard_worker(n_devices: int, n_streams: int, n_cycles: int,
                 workload: str = "mlp") -> None:
    """One device-scaling measurement, run in a child process whose
    XLA_FLAGS fanned out ``n_devices`` host devices.  Prints a single
    ``SHARD_ROW {json}`` line for the parent to collect.  ``workload``
    picks the classifier (``mlp``) or the reconstruction autoencoder
    (``ae`` — served through its head's on-device score reduction)."""
    from repro.launch.mesh import make_fleet_mesh

    if len(jax.devices()) < n_devices:
        raise RuntimeError(
            f"worker needs {n_devices} devices, sees {len(jax.devices())}")
    model = build_autoencoder() if workload == "ae" else build_detector()
    params = model.init_params(jax.random.PRNGKey(0))
    calib = [jnp.asarray(np.random.default_rng(1).normal(size=spec.INPUT_SIZE)
                         .astype(np.float32)) for _ in range(8)]
    params = quantize.quantize_params(model, params, "SINT",
                                      calibration=calib)
    head = (ReconstructionHead(threshold=BENCH_AE_THRESHOLD)
            if workload == "ae" else None)
    readings = synthetic_readings(n_streams, n_cycles, seed=n_devices)
    # Timed as a full serve lifecycle — cold ring, fill cycles, verdicts —
    # because that's the deployment question the mesh answers: cycles of
    # host ingest cost the same regardless of fleet size, so a d-device
    # mesh serving d shards amortizes the scan-cycle tax d ways.  Best of
    # two lifecycles (fresh engine each; shared-core CI boxes are noisy).
    best = None
    for rep in range(2):
        eng = StreamEngine(model, params, n_streams=n_streams,
                           stride=spec.STRIDE, mesh=make_fleet_mesh(n_devices),
                           head=head)
        eng.warmup()
        t0 = time.perf_counter()
        for c in range(n_cycles):
            eng.ingest(readings[c])
        wall = time.perf_counter() - t0
        if best is None or wall < best[1]:
            best = (eng.stats.windows, wall, eng.stats.latency_p(99))
    print("SHARD_ROW " + json.dumps({
        "devices": n_devices, "streams": n_streams,
        "windows": best[0], "wall_s": best[1],
        "p99_s": best[2]}), flush=True)


def run_scaling(quick: bool, workload: str = "mlp") -> list:
    """Fan out one child per device count; return the scaling Rows."""
    if workload == "ae":
        counts = (1, 2) if quick else (1, 2, 4)
    else:
        counts = (1, 2) if quick else (1, 2, 4, 8)
    # Long enough that verdict steps dominate the lifecycle (the fill is
    # 200 of these cycles); scaling rows keep it fixed across --quick so
    # records stay comparable.
    n_cycles = 1200
    prefix = "detect_ae_shard" if workload == "ae" else "detect_fleet_shard"

    def spawn(d):
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
        if d > 1:
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count={d}").strip()
        cmd = [sys.executable, os.path.abspath(__file__), "--shard-worker",
               "--devices", str(d), "--workload", workload,
               "--streams", str(spec.STREAMS_PER_DEVICE * d),
               "--cycles", str(n_cycles)]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=1800)
        if out.returncode != 0:
            sys.stderr.write(out.stderr)
            raise RuntimeError(f"shard worker (devices={d}) failed")
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("SHARD_ROW ")][-1]
        return json.loads(line[len("SHARD_ROW "):])

    # Three interleaved sweeps, median wall per device count: a transient
    # load burst on a shared CI box then taxes sweeps, not device counts,
    # and the median discards the outlier epoch in either direction.
    samples = {d: [] for d in counts}
    for _ in range(3):
        for d in counts:
            samples[d].append(spawn(d))
    results = [sorted(samples[d], key=lambda r: r["wall_s"])[1]
               for d in counts]

    rows = []
    wps_1dev = results[0]["windows"] / results[0]["wall_s"]
    for r in results:
        wps = r["windows"] / r["wall_s"]
        rows.append({
            "name": f"{prefix}_d{r['devices']}",
            "us_per_call": r["wall_s"] / max(r["windows"], 1) * 1e6,
            "derived": f"devices={r['devices']};streams={r['streams']};"
                       f"windows_s={wps:.0f};p99_ms={r['p99_s'] * 1e3:.2f};"
                       f"vs_1dev={wps / wps_1dev:.2f}x"})
        print(f"# {workload} shard d{r['devices']}: {r['streams']} plants, "
              f"{wps:.0f} windows/s ({wps / wps_1dev:.2f}x vs 1 device)")
    return rows


def main(quick: bool = False, n_streams: int = 16, n_cycles: int = 0):
    n_cycles = n_cycles or (400 if quick else 1200)
    # A run too short to complete one window emits zero verdicts and every
    # windows/s ratio degenerates — clamp to the first verdict cycle.
    n_cycles = max(n_cycles, spec.WINDOW + spec.STRIDE)
    stride = spec.STRIDE

    print(f"# fleet: {n_streams} plants, {n_cycles} cycles, "
          f"window={spec.WINDOW}, stride={stride}")
    readings = generate_readings(n_streams, n_cycles, seed=0)

    model = build_detector()
    params = model.init_params(jax.random.PRNGKey(0))
    calib = [jnp.asarray(np.random.default_rng(1).normal(size=spec.INPUT_SIZE)
                         .astype(np.float32)) for _ in range(8)]

    rows = []
    w_naive, wall_naive, p99_naive = run_naive(model, params, readings,
                                               stride=stride)
    wps_naive = w_naive / wall_naive
    rows.append({"name": "detect_naive_float",
                 "us_per_call": wall_naive / max(w_naive, 1) * 1e6,
                 "derived": f"windows_s={wps_naive:.0f};"
                            f"p99_ms={p99_naive * 1e3:.2f}"})

    variants = [("REAL", params)]
    for scheme in quantize.SCHEMES:
        variants.append((scheme, quantize.quantize_params(
            model, params, scheme, calibration=calib)))
    def emit_pair_rows(prefix, pair, *, vs_naive=False):
        """Append the perlayer+fused Row pair for one run_engine_pair result;
        the fused row's vs_perlayer is the paired-median ratio.  Returns
        (wps_perlayer, wps_fused)."""
        wps = {}
        for fused, suffix in ((False, "perlayer"), (True, "fused")):
            w, wall, p99 = pair[fused]
            wps[fused] = w / wall
            derived = f"windows_s={wps[fused]:.0f};p99_ms={p99 * 1e3:.2f}"
            if vs_naive:
                derived += f";vs_naive={wps[fused] / wps_naive:.2f}x"
            if fused:
                derived += f";vs_perlayer={pair['ratio']:.2f}x"
            rows.append({"name": f"{prefix}_{suffix}",
                         "us_per_call": wall / max(w, 1) * 1e6,
                         "derived": derived})
        return wps[False], wps[True]

    speedup_sint = 0.0
    fused_vs_perlayer_sint = 0.0
    for scheme, p in variants:
        pair = run_engine_pair(model, p, readings, stride=stride)
        _, wps_f = emit_pair_rows(f"detect_engine_{scheme.lower()}", pair,
                                  vs_naive=True)
        if scheme == "SINT":
            speedup_sint = wps_f / wps_naive
            fused_vs_perlayer_sint = pair["ratio"]
    # Sustained-throughput rows (detect_sustained_*): async double-buffered
    # vs synchronous serving of the fused SINT step under continuous
    # arrival, flush() inside each timed pass.  Kept under --quick so the
    # CI artifact always carries the async row.
    sint_params = dict(variants)["SINT"]
    pair = run_sustained_pair(model, sint_params, readings, stride=stride)
    wps_sust = {}
    for depth, suffix in ((0, "_sync"), (1, "")):
        w, wall, p99 = pair[depth]
        wps_sust[depth] = w / wall
        derived = f"windows_s={wps_sust[depth]:.0f};p99_ms={p99 * 1e3:.2f}"
        if depth:
            derived += f";vs_sync={pair['ratio']:.2f}x"
        rows.append({"name": f"detect_sustained_sint{suffix}",
                     "us_per_call": wall / max(w, 1) * 1e6,
                     "derived": derived})
    print(f"# sustained SINT: async {wps_sust[1]:.0f} vs sync "
          f"{wps_sust[0]:.0f} windows/s (paired ratio {pair['ratio']:.2f}x)")

    # Autoencoder workload (detect_ae_* rows): the 400-64-16-64-400
    # reconstruction detector through the same engine, verdicts via its
    # ReconstructionHead — the (S, 400) decode reduced to an (S, 1) score
    # on device.  fused-vs-per-layer at REAL+SINT; --quick keeps SINT so
    # the CI artifact always carries the fused autoencoder row.
    ae_model = build_autoencoder()
    ae_params = ae_model.init_params(jax.random.PRNGKey(2))
    ae_head = ReconstructionHead(threshold=BENCH_AE_THRESHOLD)
    ae_variants = [] if quick else [("REAL", ae_params)]
    ae_variants.append(("SINT", quantize.quantize_params(
        ae_model, ae_params, "SINT", calibration=calib)))
    for scheme, p in ae_variants:
        pair = run_engine_pair(ae_model, p, readings, stride=stride,
                               head=ae_head)
        wps_pl, wps_f = emit_pair_rows(f"detect_ae_{scheme.lower()}", pair)
        print(f"# ae {scheme}: fused {wps_f:.0f} vs per-layer {wps_pl:.0f} "
              f"windows/s (paired ratio {pair['ratio']:.2f}x)")

    # Heterogeneous model-group fleet (detect_grouped_* rows): the fleet
    # split four ways across classifier/autoencoder/margin/forecast groups,
    # served by ONE GroupedStreamEngine (one fused dispatch per group inside
    # one jitted step) vs one StreamEngine per model.  --quick keeps SINT so
    # the CI artifact always carries a grouped row.
    grouped_schemes = ("SINT",) if quick else ("REAL", "SINT")
    for scheme in grouped_schemes:
        detectors = mixed_group_detectors(scheme, calib)
        pair = run_grouped_pair(detectors, readings, stride=stride)
        wps = {}
        for kind, suffix in (("split", "split"), ("grouped", "")):
            w, wall, p99 = pair[kind]
            wps[kind] = w / wall
            name = f"detect_grouped_{scheme.lower()}" + \
                (f"_{suffix}" if suffix else "")
            derived = f"windows_s={wps[kind]:.0f};p99_ms={p99 * 1e3:.2f}"
            if kind == "grouped":
                derived += f";groups=4;vs_split={pair['ratio']:.2f}x"
            rows.append({"name": name,
                         "us_per_call": wall / max(w, 1) * 1e6,
                         "derived": derived})
        print(f"# grouped {scheme}: {wps['grouped']:.0f} vs split "
              f"{wps['split']:.0f} windows/s "
              f"(paired ratio {pair['ratio']:.2f}x)")
        # Megakernel row (detect_grouped_*_mega): the same fleet, ONE
        # pallas dispatch per verdict step vs one per group.
        mpair = run_mega_pair(detectors, readings, stride=stride)
        w, wall, p99 = mpair["mega"]
        wps_mega = w / wall
        p99_pg = mpair["pergroup"][2]
        rows.append({
            "name": f"detect_grouped_{scheme.lower()}_mega",
            "us_per_call": wall / max(w, 1) * 1e6,
            "derived": f"windows_s={wps_mega:.0f};p99_ms={p99 * 1e3:.2f};"
                       f"groups=4;vs_pergroup={mpair['ratio']:.2f}x;"
                       f"p99_pergroup_ms={p99_pg * 1e3:.2f}"})
        print(f"# megakernel {scheme}: {wps_mega:.0f} windows/s, "
              f"vs per-group paired ratio {mpair['ratio']:.2f}x "
              f"(p99 {p99 * 1e3:.2f}ms vs {p99_pg * 1e3:.2f}ms)")

    # Drift-adaptation rows (detect_drift_*): the autoencoder engine over a
    # *drifting* fleet (seasonal-drift scenario — benign flash-gain decay
    # plus warming seawater), streaming-threshold adaptive engine vs the
    # frozen-threshold engine in interleaved passes.  --quick keeps SINT so
    # the CI artifact always carries a drift row.
    drift_head = ReconstructionHead(threshold=BENCH_AE_THRESHOLD,
                                    target_fpr=0.05)
    drift_readings = fleet_readings(n_streams, n_cycles,
                                    names=["seasonal-drift"], seed=3)
    ae_by_scheme = dict(ae_variants)
    for scheme in grouped_schemes:
        pair = run_drift_pair(ae_model, ae_by_scheme[scheme], drift_readings,
                              stride=stride, head=drift_head)
        wps = {}
        for adaptive, suffix in ((False, "fixed"), (True, "")):
            w, wall, p99 = pair[adaptive]
            wps[adaptive] = w / wall
            name = f"detect_drift_{scheme.lower()}" + \
                (f"_{suffix}" if suffix else "")
            derived = f"windows_s={wps[adaptive]:.0f};p99_ms={p99 * 1e3:.2f}"
            if adaptive:
                derived += (f";vs_fixed={pair['ratio']:.2f}x"
                            f";live_thr={pair['live_thr']:.4g}")
            rows.append({"name": name,
                         "us_per_call": wall / max(w, 1) * 1e6,
                         "derived": derived})
        print(f"# drift {scheme}: adaptive {wps[True]:.0f} vs fixed "
              f"{wps[False]:.0f} windows/s (paired ratio "
              f"{pair['ratio']:.2f}x, live_thr={pair['live_thr']:.4g})")

    print(f"# device scaling ({spec.STREAMS_PER_DEVICE} plants/device)")
    rows.extend(run_scaling(quick))
    rows.extend(run_scaling(quick, workload="ae"))

    emit(rows)
    print(f"# fused SINT vs naive float: {speedup_sint:.2f}x windows/s; "
          f"fused vs per-layer step: {fused_vs_perlayer_sint:.2f}x")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--streams", type=int, default=16)
    ap.add_argument("--cycles", type=int, default=0)
    ap.add_argument("--shard-worker", action="store_true",
                    help="internal: one device-scaling measurement "
                         "(spawned by run_scaling with XLA_FLAGS set)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--workload", default="mlp", choices=("mlp", "ae"),
                    help="internal: shard-worker model kind")
    a = ap.parse_args()
    if a.shard_worker:
        shard_worker(a.devices, a.streams, a.cycles, a.workload)
    else:
        main(quick=a.quick, n_streams=a.streams, n_cycles=a.cycles)
