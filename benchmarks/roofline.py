"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch × shape) on the single-pod mesh:

  compute term    = HLO_FLOPs_per_chip / peak (bf16 197 TF/s; int8 394 TOP/s)
  memory term     = HLO_bytes_per_chip / 819 GB/s
  collective term = wire_bytes_per_chip / (3 links x 50 GB/s)

plus MODEL_FLOPS = 6·N(_active)·D and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs.  Notes: XLA cost_analysis reports per-device
program cost; totals come from the unroll/extrapolation pass
(``cost_totals``) when present.  Emits CSV + a markdown table to
experiments/roofline.md.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import emit
from repro.configs.base import INPUT_SHAPES, get_config

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
ICI_LINKS = 3           # per chip on a 2D torus slice (approx)
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def active_params(arch: str) -> float:
    """MODEL params N (active for MoE) from the config dims."""
    cfg = get_config(arch)
    d = cfg.d_model
    if cfg.family == "ssm":
        per_layer = d * (2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
                         + cfg.ssm_heads) + cfg.d_inner * d
        return cfg.n_layers * per_layer + cfg.vocab * d
    attn = d * cfg.n_heads * cfg.d_head + 2 * d * cfg.n_kv_heads * cfg.d_head \
        + cfg.n_heads * cfg.d_head * d
    glu = 3 if cfg.mlp_kind == "swiglu" else 2
    if cfg.family in ("moe",):
        ffn = glu * d * cfg.d_ff * cfg.top_k
    else:
        ffn = glu * d * cfg.d_ff
    per_layer = attn + ffn
    if cfg.family == "hybrid":
        period = cfg.attn_period
        mamba_pl = d * (2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
                        + cfg.ssm_heads) + cfg.d_inner * d
        moe_pl = glu * d * cfg.d_ff * cfg.top_k
        mlp_pl = glu * d * cfg.d_ff
        per_period = (period - 1) * mamba_pl + attn \
            + (period // 2) * moe_pl + (period - period // 2) * mlp_pl
        return (cfg.n_layers // period) * per_period + cfg.vocab * d
    return cfg.n_layers * per_layer + cfg.vocab * d


def model_flops(arch: str, shape: str) -> float:
    """6·N·D for train, 2·N·D for inference (per step/token batch)."""
    shp = INPUT_SHAPES[shape]
    n = active_params(arch)
    if shp["kind"] == "train":
        tokens = shp["global_batch"] * shp["seq_len"]
        return 6.0 * n * tokens
    if shp["kind"] == "prefill":
        tokens = shp["global_batch"] * shp["seq_len"]
        return 2.0 * n * tokens
    tokens = shp["global_batch"]  # one token per sequence per step
    return 2.0 * n * tokens


def load_results(mesh: str = "16x16") -> Dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        out[f"{r['arch']}__{r['shape']}"] = r
    return out


def analytic_hbm_bytes(r: dict) -> float:
    """Per-chip lower-bound HBM traffic for one step.

    XLA's 'bytes accessed' counts every HLO operand (no fusion residency), a
    loose upper bound — on CPU it labels everything memory-bound.  This model
    counts mandatory traffic only:

      train  : params fwd read + bwd read + update write (3x, bf16) +
               opt moments read+write (4x f32 sizes) + per-layer remat
               checkpoints write+read (2x) + logits write (f32)
      prefill: params read + cache write + layer activations write+read
      decode : params read + cache read + cache write (one slot)
    """
    chips = r["n_chips"]
    cfg = get_config(r["arch"])
    p_local = r["param_bytes"] / chips
    kind = r["kind"]
    tokens = r["global_batch"] * r["seq_len"]
    act_ckpt = tokens * cfg.d_model * 2 * cfg.n_layers / chips  # bf16 inputs
    logits = tokens * cfg.vocab * 4 / chips
    if kind == "train":
        opt_local = r.get("opt_bytes", 0) / chips
        return 3 * p_local + 2 * opt_local + 2 * act_ckpt + logits
    if kind == "prefill":
        cache_local = r.get("cache_bytes", 0) / chips
        return p_local + cache_local + 2 * act_ckpt + logits
    # decode: one token per sequence
    cache_local = r.get("cache_bytes", 0) / chips
    return p_local + cache_local


def roofline_row(r: dict) -> Optional[dict]:
    chips = r["n_chips"]
    tot = r.get("cost_totals")
    if tot:
        flops_pc = tot["flops"]          # per-chip (cost_analysis convention)
        bytes_pc = tot["bytes"]
        wire_pc = tot["wire_bytes"]
        method = tot["method"]
    else:
        flops_pc, bytes_pc = r["hlo_flops"], r["hlo_bytes"]
        wire_pc = r["collectives"]["wire_bytes"]
        method = "scan_body_once(LOWER-BOUND)"
    peak = PEAK_INT8 if r.get("quant") else PEAK_BF16
    t_comp = flops_pc / peak
    t_mem_hlo = bytes_pc / HBM_BW                     # upper bound (unfused)
    t_mem = analytic_hbm_bytes(r) / HBM_BW            # lower bound (mandatory)
    t_coll = wire_pc / (ICI_LINKS * ICI_BW)
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    mf = model_flops(r["arch"], r["shape"])
    useful = mf / (flops_pc * chips) if flops_pc else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "method": method,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_hlo_s": t_mem_hlo, "t_collective_s": t_coll,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": useful,
    }


def main(quick: bool = False):
    rows_csv = []
    results = load_results()
    md = ["| arch | shape | compute s | memory s (min) | memory s (HLO ub) | "
          "collective s | dominant | MODEL_FLOPS/HLO | method |",
          "|---|---|---|---|---|---|---|---|---|"]
    for key, r in sorted(results.items()):
        rl = roofline_row(r)
        if rl is None:
            continue
        rows_csv.append({
            "name": f"roofline/{rl['arch']}/{rl['shape']}",
            "us_per_call": rl["t_compute_s"] * 1e6,
            "derived": (f"mem_us={rl['t_memory_s'] * 1e6:.1f};"
                        f"mem_hlo_us={rl['t_memory_hlo_s'] * 1e6:.1f};"
                        f"coll_us={rl['t_collective_s'] * 1e6:.1f};"
                        f"dominant={rl['dominant']};"
                        f"useful={rl['useful_ratio']:.3f}")})
        md.append(
            f"| {rl['arch']} | {rl['shape']} | {rl['t_compute_s']:.3e} | "
            f"{rl['t_memory_s']:.3e} | {rl['t_memory_hlo_s']:.3e} | "
            f"{rl['t_collective_s']:.3e} | "
            f"{rl['dominant']} | {rl['useful_ratio']:.3f} | {rl['method']} |")
    if len(md) > 2:
        os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
        with open(OUT_MD, "w") as f:
            f.write("\n".join(md) + "\n")
    if not rows_csv:
        rows_csv.append({"name": "roofline/no_dryrun_artifacts",
                         "us_per_call": 0.0,
                         "derived": "run repro.launch.dryrun first"})
    return emit(rows_csv)


if __name__ == "__main__":
    main()
