"""§5.3: inference time vs layer width (neurons doubled each step, 32-feature
input, single ReLU dense layer).  Paper: near-linear scaling in neurons."""

from __future__ import annotations

import jax

from benchmarks.common import emit, linear_fit, time_fn
from repro.core import layers as L, sequential

WIDTHS = (32, 64, 128, 256, 512, 1024)


def main(quick: bool = False):
    widths = WIDTHS[:4] if quick else WIDTHS
    rows, times = [], []
    batch = 512  # amortize dispatch: see layer_stacking
    xb = jax.random.normal(jax.random.PRNGKey(1), (batch, 32))
    for w in widths:
        m = sequential([L.Input(), L.Dense(units=w, activation="relu")], (32,))
        p = m.init_params(jax.random.PRNGKey(0))
        fn = jax.jit(jax.vmap(m.apply_planned, in_axes=(None, 0)))
        t = time_fn(lambda: fn(p, xb)) / batch
        times.append(t)
        rows.append({"name": f"layer_width/icsml/W{w}", "us_per_call": t,
                     "derived": ""})
    slope, _, r2 = linear_fit(widths, times)
    rows.append({"name": "layer_width/us_per_neuron", "us_per_call": slope,
                 "derived": f"R2={r2:.4f};paper_bbb=9.326us_per_neuron"})
    return emit(rows)


if __name__ == "__main__":
    main()
