"""Serving example: batched requests against a reduced assigned architecture,
with the paper's optimizations as switches (deliverable b).

  --engine continuous   per-slot continuous batching (serving/continuous.py)
  --quant SINT          int8 weights through the qmatmul path (§6.1)
  --kv-quant            int8 KV cache (§6.1 applied to serving state)
  --cyclic N            multipart decode, N layer-segments per cycle (§6.3);
                        with --engine continuous, segments compose with slots

Run:  PYTHONPATH=src python examples/serve_llm.py --arch qwen3_8b --engine continuous
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model
from repro.serving import ContinuousEngine, CyclicDecoder, Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_8b")
    ap.add_argument("--engine", choices=("wave", "continuous"), default="wave")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", choices=("SINT", "INT", "DINT"))
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--cyclic", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.quant:
        cfg = cfg.with_(quant=args.quant)
    if args.kv_quant:
        cfg = cfg.with_(kv_quant=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} (reduced) quant={cfg.quant} kv_quant={cfg.kv_quant}")

    extras = {}
    if cfg.family == "vlm":
        extras["image_emb"] = jnp.zeros((4, cfg.num_image_tokens, 1152), cfg.dtype)
    elif cfg.family == "audio":
        extras["frames"] = jnp.zeros((4, cfg.encoder_frames, cfg.d_model), cfg.dtype)

    rng = np.random.default_rng(0)
    if args.cyclic and args.engine == "wave":
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, 8).astype(np.int32)[None]),
            **{k: v[:1] for k, v in extras.items()}}
        cache, logits = api.prefill(params, batch, 128)
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        cd = CyclicDecoder(cfg, params, n_segments=args.cyclic, batch=1,
                           cache_len=128)
        toks, _, stats = cd.decode_tokens(cache, first, 8, args.max_new,
                                          control_task=lambda: None)
        ct = np.asarray(stats.cycle_times_s) * 1e3
        print(f"multipart decode: {args.cyclic} cycles/token; "
              f"cycle p50={np.percentile(ct, 50):.1f}ms p99={np.percentile(ct, 99):.1f}ms")
        print("tokens:", toks)
        return

    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=args.max_new, temperature=args.temperature)
            for i in range(args.requests)]
    if args.engine == "continuous":
        engine = ContinuousEngine(api, params, batch_slots=4, cache_len=128,
                                  cyclic_segments=args.cyclic)
        for c in engine.serve(reqs):
            print(f"req {c.uid}: prefill {c.prefill_s * 1e3:.0f}ms "
                  f"finished {c.finished_s * 1e3:.0f}ms "
                  f"tokens={c.tokens[:10].tolist()}...")
        st = engine.last_stats
        print(f"continuous{f' x {args.cyclic}-part' if args.cyclic else ''}: "
              f"{st.steps} steps, {st.admitted} requests, "
              f"{st.wall_s:.2f}s wall")
        return

    engine = Engine(api, params, batch_slots=4, cache_len=128, extras=extras)
    for c in engine.serve(reqs):
        print(f"req {c.uid}: prefill {c.prefill_s * 1e3:.0f}ms "
              f"{c.tokens_per_s:.1f} tok/s  tokens={c.tokens[:10].tolist()}...")


if __name__ == "__main__":
    main()
