"""Quickstart: the ICSML core in five minutes.

Builds a small model the ICSML way (array of layers + static memory plan),
runs planned (arena) inference, quantizes it (§6.1), prunes it (§6.2), and
executes it multipart across simulated scan cycles (§6.3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MultipartInference, layers as L, prune, quantize, sequential


def main():
    # 1. declare the model — an array of layers, sizes static (ICSML style)
    model = sequential(
        [L.Input(),
         L.Dense(units=128, activation="relu"),
         L.Dense(units=64, activation="relu"),
         L.Dense(units=10, activation="softmax")],
        input_shape=(32,))
    params = model.init_params(jax.random.PRNGKey(0))
    print(model.summary(), "\n")

    # 2. static memory plan (the dataMem table) + planned inference
    plan = model.memory_plan()
    print(f"activation arena: {plan.arena_bytes} B "
          f"(naive layout would be {model.memory_plan(reuse=False).arena_bytes} B)")
    x = jax.random.normal(jax.random.PRNGKey(1), (32,))
    y_ref = model.apply(params, x)
    y_arena = model.apply_planned(params, x)
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_arena))
    print("planned (arena) inference == reference inference ✓\n")

    # 3. integer quantization (§6.1)
    qparams = quantize.quantize_params(model, params, "SINT", calibration=[x])
    y_q = model.apply(qparams, x)
    print(f"SINT output max|err| = {float(jnp.abs(y_q - y_ref).max()):.4g}")
    print("512x512 layer memory (Table 2):",
          {s: quantize.memory_report(512, 512, s)["total"]
           for s in ("SINT", "INT", "DINT", "REAL")}, "\n")

    # 4. pruning (§6.2)
    pparams = prune.prune_model(model, params, 0.5)
    print(f"pruned sparsity of layer 1: "
          f"{prune.sparsity_of(pparams[1]['w']):.2f}\n")

    # 5. multipart inference (§6.3): one segment per scan cycle
    mi = MultipartInference(model, params, n_segments=3)
    state = mi.start(x)
    for cycle in range(mi.n_segments):
        state = mi.step(state)      # this cycle's inference budget
        print(f"scan cycle {cycle}: segment done "
              f"({mi.segment_flops()[cycle]} FLOPs)")
    np.testing.assert_allclose(np.asarray(mi.output(state)),
                               np.asarray(y_arena), rtol=1e-6, atol=1e-7)
    print("multipart output identical to single-shot ✓")


if __name__ == "__main__":
    main()
