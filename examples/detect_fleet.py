"""Fleet-scale anomaly detection over the MSF scenario library.

Trains a detector (established-framework stage), ports it to the ICSML
core (§4.3), optionally quantizes it (§6.1), then serves a heterogeneous
fleet of simulated plants — each running a named scenario from
``repro.sim.scenarios`` — through the batched ``StreamEngine``: per-stream
ring-buffer windows, one jitted donated detector step per verdict cadence,
per-window latency/deadline accounting.

``--detector`` picks the workload: ``mlp`` is the paper's supervised
400-64-32-16-2 classifier; ``ae`` is the unsupervised 400-64-16-64-400
autoencoder — trained on benign windows only, anomaly score = per-window
reconstruction error, verdict threshold calibrated to
``spec.AE_TARGET_FPR`` false positives on held-out normal traces (and
re-calibrated on the quantized model when ``--quant`` is not REAL, so the
served scores match the served arithmetic).  Both serve through the same
fused single-dispatch detector step.

``--mixed`` serves a *heterogeneous model-group fleet* instead: the plants
are partitioned into four model groups — supervised classifier,
reconstruction autoencoder, one-class margin detector, next-step
forecaster — each group carrying its own trained model, verdict head,
calibrated threshold and quantization scales, all batched by ONE
``GroupedStreamEngine`` whose jitted step runs one fused dispatch per
group per verdict cadence.

With ``--devices N`` the engine shards the fleet's stream axis over an
N-device ``("data",)`` mesh — on a CPU host the devices are fanned out via
``XLA_FLAGS=--xla_force_host_platform_device_count`` (set here before jax
loads), on real hardware the mesh maps onto the visible accelerators.

``--async`` serves double-buffered (``async_depth=1``): each ready
boundary dispatches the detector step and returns to ingesting the next
scan cycle while the device works, harvesting the previous step's
verdicts — bit-identical to synchronous serving, one boundary later
(``flush()`` drains the last in-flight step).  After the serve it prints
a sync-vs-async sustained windows/s comparison on fresh engines.

``--drift`` overlays fleet-wide benign parameter drift (flash-gain decay +
warming seawater, the ``seasonal-drift`` physics) on every plant's scenario
and switches score-head detectors to **online threshold recalibration**
(``adapt=True``): the engine's live threshold then tracks the sliding
benign-score quantile instead of flooding with false alarms as the
operating point creeps away from the offline calibration.  The pooled
quantile assumes a mostly-benign fleet: sharp attacks overshoot the
headroom gate and stay out of the calibration pool, but serving the full
attack gauntlet under ``--drift`` puts a *sustained, slowly-ramping*
attack on nearly every stream — those ramp inside the headroom and get
absorbed into the live threshold (any self-calibrating detector's
poisoning window).  The drift demo is the mostly-benign + sharp-attack
mix below.

Run:
  PYTHONPATH=src python examples/detect_fleet.py --list
  PYTHONPATH=src python examples/detect_fleet.py --scenarios stealth-drift
  PYTHONPATH=src python examples/detect_fleet.py --plants 16 --quant SINT
  PYTHONPATH=src python examples/detect_fleet.py --plants 64 --devices 4
  PYTHONPATH=src python examples/detect_fleet.py --mixed --fast --plants 16
  PYTHONPATH=src python examples/detect_fleet.py --async --fast --plants 16
  PYTHONPATH=src python examples/detect_fleet.py --detector ae --drift \
      --scenarios baseline,seasonal-drift,tb0-spoof,wd-spoof --plants 16
"""

import argparse
import collections
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _fan_out_devices() -> int:
    """--devices must act before jax initializes: host-device fan-out only
    works through XLA_FLAGS at backend-creation time."""
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=1)
    args, _ = ap.parse_known_args()
    if args.devices > 1 and "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}").strip()
    return args.devices


_fan_out_devices()

import numpy as np

from repro.configs import msf_detector as spec
from repro.core import porting, quantize
from repro.launch.mesh import make_fleet_mesh
from repro.sim import (SCENARIOS, ParamDrift, build_dataset, build_fleet,
                       get_scenario, recalibrate_threshold, scenario_table,
                       train_autoencoder, train_detector, train_forecaster,
                       train_one_class)
from repro.sim.msf import SCAN_DT
from repro.serving import GroupedStreamEngine, ModelGroup, StreamEngine


def _budget(fast: bool, smoke: bool):
    """(normal_cycles, attack_cycles, epochs, patience) for a training run.
    ``--smoke`` is the CI-subprocess budget: just enough data/steps to prove
    the pipeline end to end in seconds, not a useful detector."""
    if smoke:
        # Floor: the score heads refuse to train/calibrate on < 768 benign
        # windows, and the mixed fleet trains an autoencoder too.
        return 5_200, 800, 2, 2
    scale = 0.2 if fast else 0.5
    return int(42_000 * scale), int(5_700 * scale), 30 if fast else 60, 8


def train_and_port(fast: bool, quant: str, detector: str, smoke: bool = False):
    normal, attack, epochs, patience = _budget(fast, smoke)
    print("== dataset + training (established-framework stage) ==")
    # jittered normal plants in training: the fleet is heterogeneous, and
    # per-plant operating-point spread must read as benign
    x, y = build_dataset(normal_cycles=normal, attack_cycles=attack,
                         stride=8, seed=0, jitter=0.015, jitter_plants=4)
    head = None
    if detector == "ae":
        model, res = train_autoencoder(x, y, epochs=epochs,
                                       patience=patience, lr=1e-3)
        head = res.head
        print(f"val mse {res.best_val_mse:.6f}  threshold {res.threshold:.6f}"
              f"  calib FPR {res.calib_fpr:.4f}"
              f"  attack-window detection {res.test_detection_rate:.4f}")
    else:
        model, res = train_detector(x, y, epochs=epochs,
                                    patience=patience, lr=1e-3)
        print(f"val acc {res.best_val_acc:.4f}  test acc {res.test_acc:.4f}")
    print("== porting to ICSML (§4.3) ==")
    with tempfile.TemporaryDirectory() as tmp:
        model, params = porting.port_mlp(model, res.params, tmp)
    if quant != "REAL":
        print(f"== quantizing to {quant} (§6.1) ==")
        # Activation scales from benign-trace ranges (quantize.py docstring:
        # weight absmax alone leaves the AE decoder's scales wildly off).
        calib = quantize.calibration_samples(x, y)
        params = quantize.quantize_params(model, params, quant,
                                          calibration=calib)
        if head is not None:
            # Re-calibrate the verdict threshold against the *quantized*
            # model's scores — on the same held-out normal windows the REAL
            # threshold came from (recalibrate_threshold owns that invariant).
            head, _ = recalibrate_threshold(model, params, res.calib_windows)
            print(f"re-calibrated {quant} threshold {head.threshold:.6f}")
    return model, params, head


def _port_and_quantize(model, res, head, quant, x, y):
    """Shared §4.3 port + §6.1 quantize + (score heads) threshold
    re-calibration against the quantized arithmetic."""
    with tempfile.TemporaryDirectory() as tmp:
        model, params = porting.port_mlp(model, res.params, tmp)
    if quant != "REAL":
        calib = quantize.calibration_samples(x, y)
        if head is not None:
            # Heads with non-identity window geometry (the forecaster) eat a
            # slice of the window; quantization scales must see the same view.
            calib = [head.prepare(c) for c in calib]
        params = quantize.quantize_params(model, params, quant,
                                          calibration=calib)
        if head is not None:
            head, _ = recalibrate_threshold(model, params, res.calib_windows,
                                            head=head)
    return model, params, head


def train_mixed(fast: bool, quant: str, smoke: bool = False):
    """Train/port/quantize all four detector types for the grouped fleet."""
    normal, attack, epochs, patience = _budget(fast, smoke)
    print("== dataset + training x4 (mixed model-group fleet) ==")
    x, y = build_dataset(normal_cycles=normal, attack_cycles=attack,
                         stride=8, seed=0, jitter=0.015, jitter_plants=4)
    trained = []
    model, res = train_detector(x, y, epochs=epochs, patience=patience,
                                lr=1e-3)
    print(f"  mlp:      val acc {res.best_val_acc:.4f}  "
          f"test acc {res.test_acc:.4f}")
    trained.append(("mlp", model, res, None))
    for name, trainer in (("ae", train_autoencoder),
                          ("margin", train_one_class),
                          ("forecast", train_forecaster)):
        model, res = trainer(x, y, epochs=epochs, patience=patience, lr=1e-3)
        print(f"  {name + ':':<9} threshold {res.threshold:.6f}  "
              f"calib FPR {res.calib_fpr:.4f}  "
              f"attack-window detection {res.test_detection_rate:.4f}")
        trained.append((name, model, res, res.head))
    print("== porting to ICSML (§4.3)"
          + (f" + quantizing to {quant} (§6.1)" if quant != "REAL" else "")
          + " ==")
    out = []
    for name, model, res, head in trained:
        model, params, head = _port_and_quantize(model, res, head, quant, x, y)
        if head is not None and quant != "REAL":
            print(f"  {name}: re-calibrated {quant} threshold "
                  f"{head.threshold:.6f}")
        out.append((name, model, params, head))
    return out


def sustained_side_by_side(make_engine, n_streams, n_cycles=800):
    """Sync-vs-async sustained windows/s under continuous per-cycle arrival.

    Fresh engines (built by ``make_engine(async_depth)``), synthetic normal
    readings (serving throughput is content-independent), ring fill
    untimed, ``flush()`` inside the timed region so every dispatched window
    is also harvested."""
    readings = (np.asarray(spec.NORM_MEAN, np.float32)
                + np.random.default_rng(0)
                .normal(size=(n_cycles, n_streams, spec.N_FEATURES))
                .astype(np.float32) * np.asarray(spec.NORM_STD, np.float32))
    wps = {}
    for depth in (0, 1):
        eng = make_engine(depth)
        eng.warmup()
        for c in range(min(spec.WINDOW, n_cycles)):
            eng.ingest(readings[c])
        eng.flush()
        w0 = eng.stats.windows
        t0 = time.perf_counter()
        for c in range(n_cycles):
            eng.ingest(readings[c])
        eng.flush()
        wps[depth] = (eng.stats.windows - w0) / (time.perf_counter() - t0)
    print(f"\nsustained throughput ({n_cycles} cycles, continuous arrival):")
    print(f"  sync   {wps[0]:>8.0f} windows/s")
    print(f"  async  {wps[1]:>8.0f} windows/s ({wps[1] / wps[0]:.2f}x, "
          f"double-buffered: ingest of cycle N+1 overlaps step N)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", default="all",
                    help="comma-separated scenario names, or 'all'")
    ap.add_argument("--plants", type=int, default=spec.FLEET_STREAMS)
    ap.add_argument("--cycles", type=int, default=1600)
    ap.add_argument("--quant", default="SINT",
                    choices=("REAL",) + quantize.SCHEMES)
    ap.add_argument("--detector", default="mlp", choices=("mlp", "ae"),
                    help="mlp: supervised §7 classifier; ae: unsupervised "
                         "reconstruction-error autoencoder")
    ap.add_argument("--mixed", action="store_true",
                    help="serve a heterogeneous model-group fleet "
                         "(classifier + autoencoder + margin + forecast "
                         "groups in one GroupedStreamEngine)")
    ap.add_argument("--jitter", type=float, default=None,
                    help="override per-scenario plant jitter")
    ap.add_argument("--drift", action="store_true",
                    help="overlay fleet-wide benign parameter drift and "
                         "enable streaming threshold recalibration on "
                         "score-head detectors")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true", help="small training budget")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-subprocess budget: tiny dataset, 2 epochs, and "
                         "(unless overridden) 4 plants x 240 cycles — proves "
                         "the pipeline, not the detector")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the fleet over this many devices "
                         "(host devices are fanned out automatically)")
    ap.add_argument("--async", dest="async_serve", action="store_true",
                    help="serve double-buffered (async_depth=1: verdicts "
                         "arrive one ready boundary late, bit-identical) "
                         "and print sync-vs-async sustained windows/s")
    ap.add_argument("--list", action="store_true",
                    help="print the scenario library and exit")
    args = ap.parse_args()

    if args.list:
        print(scenario_table())
        return

    if args.smoke:
        if args.plants == spec.FLEET_STREAMS:
            args.plants = 4
        if args.cycles == 1600:
            args.cycles = 240

    names = (list(SCENARIOS) if args.scenarios == "all"
             else [s.strip() for s in args.scenarios.split(",")])
    for n in names:
        get_scenario(n)   # fail fast on typos

    mesh = make_fleet_mesh(args.devices) if args.devices > 1 else None
    shard_note = (f", sharded over {args.devices} devices "
                  f"({-(-args.plants // args.devices)} streams/device)"
                  if mesh is not None else "")
    # Fleet-wide benign drift: the seasonal-drift physics overlaid on every
    # plant's scenario (attacks compose on top of the drifted base).
    drift = (ParamDrift({"k_flash": -0.08, "t_sea": 0.04},
                        start=300, ramp=1200) if args.drift else None)
    drift_note = ", drifting+adaptive" if args.drift else ""
    fleet = build_fleet(names, args.plants, seed=args.seed + 1000,
                        jitter=args.jitter, drift=drift)
    # --devices 1 pins sharding OFF even in a multi-device process, so the
    # flag always means what the serve header prints.
    shard_kw = {"mesh": mesh} if mesh is not None else {"shard": False}
    async_note = ", async double-buffered" if args.async_serve else ""
    if args.mixed:
        detectors = train_mixed(args.fast, args.quant, args.smoke)
        if args.plants < len(detectors):
            ap.error(f"--mixed needs at least {len(detectors)} plants")
        base, extra = divmod(args.plants, len(detectors))
        groups = [ModelGroup(name, model, params,
                             base + (1 if i < extra else 0), head,
                             adapt=args.drift and head is not None)
                  for i, (name, model, params, head) in enumerate(detectors)]

        def make_engine(depth):
            return GroupedStreamEngine(groups, async_depth=depth, **shard_kw)

        engine = make_engine(1 if args.async_serve else 0)
        split = " + ".join(f"{n}x{name}" for name, _, n in engine.groups)
        print(f"== serving {args.plants} plants x {args.cycles} cycles "
              f"(mixed: {split} / {args.quant}{shard_note}{drift_note}"
              f"{async_note}) ==")
    else:
        model, params, head = train_and_port(args.fast, args.quant,
                                             args.detector, args.smoke)
        if args.drift and head is None:
            print("note: --drift serves a drifting fleet, but the "
                  "classifier has no score threshold to recalibrate "
                  "(use --detector ae for adaptation)")

        def make_engine(depth):
            return StreamEngine(model, params, n_streams=args.plants,
                                head=head,
                                adapt=args.drift and head is not None or None,
                                async_depth=depth, **shard_kw)

        engine = make_engine(1 if args.async_serve else 0)
        print(f"== serving {args.plants} plants x {args.cycles} cycles "
              f"({args.detector}/{args.quant}{shard_note}{drift_note}"
              f"{async_note}) ==")
    engine.warmup()
    flagged = collections.defaultdict(list)   # stream -> attack-verdict cycles
    verdicts = engine.run(fleet, args.cycles)
    verdicts += engine.flush()   # async: drain the final in-flight step
    for v in verdicts:
        if v.pred != 0:
            flagged[v.stream].append(v.cycle)

    group_of = {}
    if args.mixed:
        for gname, off, n in engine.groups:
            for s in range(off, off + n):
                group_of[s] = gname
    gcol = f"{'group':<9} " if args.mixed else ""
    print(f"{'plant':<26} {gcol}{'onset':>6} {'first-flag':>10} "
          f"{'latency':>9} {'pre-onset FPs':>13}")
    for i, plant in enumerate(fleet):
        sc = get_scenario(plant.name.split("#")[0])
        onset = sc.onset
        cycles = flagged.get(i, [])
        g = f"{group_of[i]:<9} " if args.mixed else ""
        if onset is None:
            print(f"{plant.name:<26} {g}{'-':>6} {'-':>10} {'-':>9} "
                  f"{len(cycles):>13}")
            continue
        hits = [c for c in cycles if c >= onset]
        fps = len([c for c in cycles if c < onset])
        first = hits[0] if hits else None
        lat = f"{(first - onset) * SCAN_DT:.1f}s" if first is not None else "miss"
        print(f"{plant.name:<26} {g}{onset:>6} "
              f"{first if first is not None else 'miss':>10} {lat:>9} {fps:>13}")

    if args.mixed:
        gw = engine.group_windows()
        print("\nper-group verdicts: "
              + "  ".join(f"{k}={v}" for k, v in gw.items()))
    if args.drift:
        if args.mixed:
            moved = "  ".join(
                f"{k}={v:.6f}" for k, v in engine.live_thresholds().items()
                if v is not None)
            if moved:
                print(f"live thresholds after drift: {moved}")
        elif engine.live_threshold is not None:
            print(f"live threshold after drift: {engine.live_threshold:.6f} "
                  f"(offline calibration: {engine.head.threshold:.6f})")
    st = engine.stats
    print(f"\nserve stats: {st.steps} detector steps, {st.windows} windows, "
          f"{st.windows_per_s():.0f} windows/s | verdict latency "
          f"p50={st.latency_p(50) * 1e3:.1f}ms p99={st.latency_p(99) * 1e3:.1f}ms "
          f"| deadline({spec.DEADLINE_S * 1e3:.0f}ms) misses: "
          f"{st.deadline_misses}/{st.windows}")
    if args.async_serve:
        sustained_side_by_side(make_engine, args.plants)


if __name__ == "__main__":
    main()
