"""Export a detector to IEC 61131-3 Structured Text and prove it serves.

The paper's deployment artifact end to end: train (or, under ``--smoke``,
just initialize) a detector, port it to the ICSML core (§4.3), quantize it
(§6.1), calibrate the verdict head, emit one self-contained
``FUNCTION_BLOCK`` (``repro.codegen.st``) with the serving engines' ingest
normalization baked in — then *verify the export before anything ships*:
the in-suite ST emulator replays attack-scenario windows through the
emitted block while a ``StreamEngine`` serves the same raw readings, and
every per-window verdict is compared.

The verification contract (exit code 1 on any violation):

* SINT exports are **bit-exact against the reference semantics**: model
  outputs bit-match the eager two-op §6.1 oracle (``numpy_mlp_ref``),
  classifier ``CONF`` bit-matches the host softmax over those oracle
  logits, and score-head ``SCORE`` bit-matches the sequential-f32 MSE
  oracle.  Versus the live engine, ``PRED`` and ``THRESHOLD`` must agree
  exactly, and the f32 tails (``CONF``/``SCORE``) to 1e-4 relative — the
  engine's jitted XLA program FMA-contracts the requantize mul+add, so it
  sits an ulp off the two-op arithmetic a PLC actually executes.
* REAL exports: everything holds to epsilon (1e-4 relative), and verdicts
  may legitimately differ only when a score sits within epsilon of the
  threshold (reassociation error — reported, not failed).

Threshold calibration uses benign windows from the SAME simulated plants
over a DISJOINT later time range: the realistic held-out-trace workflow,
and what keeps the conservative-quantile cutoff (an actual calibration
score) from replaying at exactly ``score == threshold``.

Run:
  PYTHONPATH=src python examples/export_st.py --smoke --detector mlp
  PYTHONPATH=src python examples/export_st.py --smoke --detector ae --quant REAL
  PYTHONPATH=src python examples/export_st.py --detector ae --fast
"""

import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.codegen import st as stgen
from repro.codegen import verify as V
from repro.codegen.emulator import STFunctionBlock
from repro.configs import msf_detector as spec
from repro.core import porting, quantize
from repro.kernels import ops
from repro.sim import (build_dataset, get_scenario, recalibrate_threshold,
                       train_autoencoder, train_detector)
from repro.sim.detector import build_autoencoder, build_detector
from repro.sim.heads import ClassifierHead, softmax_np
from repro.sim.scenarios import fleet_readings


def calibration_windows(n_streams, replay_cycles, seed, stride):
    """Benign calibration windows from the replay's own plants (same fleet
    seed) over a disjoint later time range — held-out normal traces."""
    horizon = replay_cycles + 60 + spec.WINDOW + 8 * stride
    raw = fleet_readings(n_streams, horizon,
                         names=["baseline"] * n_streams, seed=seed)
    norm = ((np.asarray(raw, np.float32)
             - np.asarray(spec.NORM_MEAN, np.float32))
            / np.asarray(spec.NORM_STD, np.float32))
    tail = norm[replay_cycles + 60:]
    return np.concatenate([V.stream_windows(tail[:, s, :], spec.WINDOW,
                                            stride)
                           for s in range(n_streams)])


def smoke_detector(kind, quant, calib_wins):
    """Untrained (init-params) detector — the CI path: export correctness
    is a property of the arithmetic, not of detection quality."""
    model = build_detector() if kind == "mlp" else build_autoencoder()
    params = model.init_params(jax.random.PRNGKey(0 if kind == "mlp" else 1))
    if quant != "REAL":
        params = quantize.quantize_params(
            model, params, quant,
            calibration=quantize.calibration_samples(calib_wins, k=16))
    if kind == "mlp":
        return model, params, ClassifierHead()
    head, _ = recalibrate_threshold(model, params, calib_wins)
    return model, params, head


def trained_detector(kind, quant, calib_wins, fast):
    """The real workflow: train -> port -> quantize -> calibrate on the
    held-out benign scenario windows."""
    scale = 0.2 if fast else 0.5
    x, y = build_dataset(normal_cycles=int(42_000 * scale),
                         attack_cycles=int(5_700 * scale), stride=8, seed=0,
                         jitter=0.015, jitter_plants=4)
    epochs = 30 if fast else 60
    if kind == "ae":
        model, res = train_autoencoder(x, y, epochs=epochs, patience=8,
                                       lr=1e-3)
    else:
        model, res = train_detector(x, y, epochs=epochs, patience=8, lr=1e-3)
    with tempfile.TemporaryDirectory() as tmp:
        model, params = porting.port_mlp(model, res.params, tmp)
    if quant != "REAL":
        params = quantize.quantize_params(
            model, params, quant,
            calibration=quantize.calibration_samples(x, y))
    if kind == "mlp":
        return model, params, ClassifierHead()
    head, _ = recalibrate_threshold(model, params, calib_wins)
    return model, params, head


def verify_export(export, model, params, head, raw, stride):
    """Replay raw fleet readings through engine and emulator; return a
    result dict (printed by main) with a ``failures`` count."""
    n_cycles, n_streams, _ = raw.shape
    sint = export.scheme == "SINT"
    engine_verdicts = V.run_engine(model, params, raw, stride=stride,
                                   head=head)
    fb = STFunctionBlock(export.text)
    emulated = {s: V.emulate_stream(export, raw[:, s, :], stride=stride,
                                    fb=fb)
                for s in range(n_streams)}
    norm = ((np.asarray(raw, np.float32)
             - np.asarray(spec.NORM_MEAN, np.float32))
            / np.asarray(spec.NORM_STD, np.float32))
    # The bit-oracle is the eager two-op reference; the engine's jitted
    # program agrees only to an ulp (XLA contracts the requantize mul+add
    # into an FMA once biases are nonzero), so engine-side f32 tails are
    # compared to epsilon while PRED/THRESHOLD stay exact.
    stack = ops.dense_stack(model, params)
    oracle_y = {s: V.numpy_mlp_ref(
        V.stream_windows(norm[:, s, :], export.window, stride), stack)
        for s in range(n_streams)}

    failures = borderline = 0
    n = 0
    max_body = 0.0
    for v in engine_verdicts:
        em = emulated[v.stream]
        idx = int(np.searchsorted(em["cycle"], v.cycle))
        assert em["cycle"][idx] == v.cycle
        n += 1
        # Body: emulated Y vs the per-layer JAX oracle.
        ydiff = float(np.abs(np.float32(em["Y"][idx])
                             - oracle_y[v.stream][idx]).max())
        max_body = max(max_body, ydiff)
        scale_y = 1.0 + float(np.abs(oracle_y[v.stream][idx]).max())
        if (sint and ydiff != 0.0) or (not sint
                                       and ydiff > 1e-5 * scale_y):
            failures += 1
            continue
        if export.head_name == "classifier":
            logits = oracle_y[v.stream][idx]
            oracle_conf = np.float32(
                softmax_np(logits[None])[0, int(np.argmax(logits))])
            conf = np.float32(em["CONF"][idx])
            if int(em["PRED"][idx]) != v.pred:
                failures += 1
            elif sint and conf != oracle_conf:
                failures += 1          # bit contract vs the oracle logits
            elif not np.isclose(float(conf), v.prob, rtol=1e-4):
                failures += 1          # epsilon vs the engine's softmax
        else:
            sc = float(em["SCORE"][idx])
            thr_ok = float(np.float32(em["THRESHOLD"][idx])) == np.float32(
                v.threshold)
            if not thr_ok or not np.isclose(sc, v.score, rtol=1e-4):
                failures += 1
                continue
            if sint:
                seq = V.sequential_f32_mse(
                    oracle_y[v.stream][idx:idx + 1],
                    V.stream_windows(norm[:, v.stream, :], export.window,
                                     stride)[idx:idx + 1])[0]
                if np.float32(sc) != seq:
                    failures += 1
                    continue
            if int(em["PRED"][idx]) != v.pred:
                if sint or abs(sc - v.threshold) > 1e-5 * v.threshold:
                    failures += 1
                else:
                    borderline += 1
    return {"windows": n, "failures": failures, "borderline": borderline,
            "max_body_diff": max_body,
            "anomalous": sum(v.pred != 0 for v in engine_verdicts)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--detector", default="mlp", choices=("mlp", "ae"))
    ap.add_argument("--quant", default="SINT", choices=("REAL", "SINT"))
    ap.add_argument("--scenarios",
                    default="baseline,tb0-spoof,drift-then-spoof,steam-pulse",
                    help="comma-separated replay scenarios (one stream each;"
                         " includes a composed multi-attack by default)")
    ap.add_argument("--cycles", type=int, default=460,
                    help="replay length (default wraps the serving ring "
                         "more than twice)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out-dir", default="st-out",
                    help="directory the .st file is written into")
    ap.add_argument("--smoke", action="store_true",
                    help="skip training: export an init-params detector "
                         "(the arithmetic contract is training-independent)")
    ap.add_argument("--fast", action="store_true",
                    help="small training budget (ignored with --smoke)")
    args = ap.parse_args()

    names = [s.strip() for s in args.scenarios.split(",")]
    for nm in names:
        get_scenario(nm)
    stride = spec.STRIDE
    raw = fleet_readings(len(names), args.cycles, names=names,
                         seed=args.seed)

    print(f"== calibration (held-out benign windows, same plants) ==")
    calib = calibration_windows(len(names), args.cycles, args.seed, stride)
    print(f"{calib.shape[0]} windows x {calib.shape[1]}")

    if args.smoke:
        print(f"== init-params {args.detector} ({args.quant}, --smoke) ==")
        model, params, head = smoke_detector(args.detector, args.quant,
                                             calib)
    else:
        print(f"== training {args.detector} ({args.quant}) ==")
        model, params, head = trained_detector(args.detector, args.quant,
                                               calib, args.fast)
    if getattr(head, "threshold", None) is not None:
        print(f"calibrated threshold {head.threshold:.6g}")

    fb_name = f"{args.detector}_{args.quant}".upper()
    export = stgen.export_st(model, params, head=head, name=fb_name,
                             normalize=(spec.NORM_MEAN, spec.NORM_STD))
    os.makedirs(args.out_dir, exist_ok=True)
    path = os.path.join(args.out_dir, f"{fb_name.lower()}.st")
    with open(path, "w") as f:
        f.write(export.text)
    print(f"== emitted {path} ==")
    print(f"{export.scheme} scheme, {len(export.text.splitlines())} lines, "
          f"window {export.window} readings, verdict outputs "
          f"{export.verdict_outputs}")

    print(f"== replaying {len(names)} streams x {args.cycles} cycles "
          f"through engine + ST emulator ==")
    t0 = time.time()
    res = verify_export(export, model, params, head, raw, stride)
    contract = ("bit-exact (SINT)" if export.scheme == "SINT"
                else "epsilon (REAL, 1e-4 rel)")
    print(f"windows compared : {res['windows']} "
          f"({res['anomalous']} anomalous verdicts)")
    print(f"max body |diff|  : {res['max_body_diff']:.3g}")
    print(f"borderline       : {res['borderline']} "
          f"(REAL-only: score within epsilon of threshold)")
    print(f"verdict parity   : {res['windows'] - res['failures']}"
          f"/{res['windows']} under the {contract} contract "
          f"[{time.time() - t0:.1f}s]")
    if res["failures"]:
        print(f"FAILED: {res['failures']} windows violate the contract")
        sys.exit(1)
    print("OK: exported ST serves identically to the fleet engine")


if __name__ == "__main__":
    main()
