"""§7 case study, end to end: ML-based anomaly detection for an MSF
desalination plant, running *on the controller* via the ICSML runtime.

Pipeline (paper §4.3 + §7):
  1. HITL data collection: simulate the plant + cascading PID, record the
     PLC's ADC readings (ARRBIN binary files).
  2. Train the 400-64-32-16-2 ReLU classifier in the 'established framework'.
  3. Extract weights -> binary files -> statically reconstruct in ICSML ->
     BINARR load (port_mlp), optionally with SINT quantization (§6.1).
  4. Deploy in the scan-cycle runtime as a sliding-window detector with
     multipart inference (§6.3) and inject an unseen attack: measure
     detection latency (paper: injected cycle 436, detected 486).
  5. Non-intrusiveness (§7.2): compare Wd statistics with/without defense.

Run:  PYTHONPATH=src python examples/casestudy_msf.py [--fast]
"""

import argparse
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import ScanCycleRuntime, SlidingWindowDetector, porting, quantize
from repro.core.runtime import MultipartInference
from repro.sim import build_dataset, simulate, train_detector
from repro.sim.msf import SCAN_DT, CascadePID, adc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller dataset")
    ap.add_argument("--quant", choices=("SINT", "INT", "DINT"))
    ap.add_argument("--segments", type=int, default=4,
                    help="multipart inference segments per window")
    args = ap.parse_args()

    # ---- 1+2. dataset + training ------------------------------------------
    scale = 0.25 if args.fast else 1.0
    print("== building dataset (HITL simulation) ==")
    x, y = build_dataset(normal_cycles=int(42_000 * scale),
                         attack_cycles=int(5_700 * scale),
                         stride=8, seed=0)
    print(f"dataset: {x.shape[0]} windows of {x.shape[1]} features, "
          f"{y.mean():.1%} attack")

    print("== training detector (established-framework stage) ==")
    model, res = train_detector(x, y, epochs=40 if args.fast else 120,
                                patience=10 if args.fast else 15, lr=1e-3)
    print(f"val acc {res.best_val_acc:.4f}  test acc {res.test_acc:.4f} "
          f"(paper: ~0.9368)")

    # ---- 3. port to ICSML ---------------------------------------------------
    print("== porting to ICSML (extract -> binary -> reconstruct -> load) ==")
    with tempfile.TemporaryDirectory() as tmp:
        ported_model, ported_params = porting.port_mlp(model, res.params, tmp)
    xq = jnp.asarray(x[:8])
    import jax
    ref_out = jax.vmap(model.apply, (None, 0))(res.params, xq)
    port_out = jax.vmap(ported_model.apply, (None, 0))(ported_params, xq)
    assert np.allclose(np.asarray(ref_out), np.asarray(port_out)), "port mismatch"
    print("ported model output bit-identical to trained model ✓")

    if args.quant:
        print(f"== quantizing ported model to {args.quant} (§6.1) ==")
        calib = [jnp.asarray(x[i]) for i in range(0, 256, 8)]
        ported_params = quantize.quantize_params(
            ported_model, ported_params, args.quant, calibration=calib)
        qacc = np.mean(
            np.argmax(np.asarray(jax.vmap(ported_model.apply, (None, 0))(
                ported_params, jnp.asarray(x[-512:]))), -1) == y[-512:])
        print(f"quantized accuracy on tail split: {qacc:.4f}")

    # ---- 4. on-PLC deployment: attack detection -----------------------------
    print("== scan-cycle deployment: attack injection + detection ==")
    detector = SlidingWindowDetector(ported_model, ported_params,
                                     window=200, n_features=2,
                                     n_segments=args.segments)
    attack_start = 800
    detections = []

    def hook(cycle, reading):
        # normalize like build_dataset
        r = np.array([(reading[0] - 89.6) / 2.0,
                      (reading[1] - 19.18) / 0.5], np.float32)
        detector.push(r)
        result = detector.tick(cycle)
        if result is not None:
            done_cycle, pred, latency = result
            if pred != 0:
                detections.append((done_cycle, latency))

    # unseen attack parameters: seed never used during dataset generation
    simulate(1600, attack_id=2, attack_start=attack_start, seed=777,
             defense_hook=hook)
    if detections:
        first = detections[0][0]
        print(f"attack injected at cycle {attack_start}, first detection at "
              f"cycle {first} -> latency {(first - attack_start) * SCAN_DT:.1f}s "
              f"(paper: 5.0s)")
    else:
        print("attack NOT detected (unexpected)")

    # ---- 5. non-intrusiveness (§7.2) ----------------------------------------
    print("== non-intrusiveness: Wd stats with / without defense ==")
    tr_off = simulate(3000, seed=123)
    det2 = SlidingWindowDetector(ported_model, ported_params, window=200,
                                 n_features=2, n_segments=args.segments)

    def hook2(cycle, reading):
        det2.push(np.array([(reading[0] - 89.6) / 2.0,
                            (reading[1] - 19.18) / 0.5], np.float32))
        det2.tick(cycle)

    tr_on = simulate(3000, seed=123, defense_hook=hook2)
    seg = slice(1500, None)
    print(f"  defense OFF: Wd mean {tr_off.wd_meas[seg].mean():.4f} "
          f"std {tr_off.wd_meas[seg].std():.2e}")
    print(f"  defense ON : Wd mean {tr_on.wd_meas[seg].mean():.4f} "
          f"std {tr_on.wd_meas[seg].std():.2e}")
    same = np.allclose(tr_off.wd_meas, tr_on.wd_meas)
    print(f"  process output identical: {same} (defense never touches control)")

    # multipart cost profile
    mi = MultipartInference(ported_model, ported_params, args.segments)
    print(f"multipart segments: {args.segments}, per-segment FLOPs "
          f"{mi.segment_flops()}")


if __name__ == "__main__":
    main()
