"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on the synthetic LM stream (deliverable b).

The full assigned configs are exercised via the dry-run; this driver proves
the training stack end to end at a size the CPU container can actually run.
Defaults: 12 layers x d_model 512 x 8 heads with the qwen3 feature set
(qk-norm, GQA, SwiGLU) and tied embeddings over a 32k vocab ≈ 55M params; use
--big for the ~110M variant.

Run:  PYTHONPATH=src python examples/train_llm.py [--steps 300] [--big]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("qwen3_8b").with_(
        n_layers=16 if args.big else 12,
        d_model=768 if args.big else 512,
        n_heads=12 if args.big else 8,
        n_kv_heads=4,
        d_ff=2048 if args.big else 1408,
        vocab=32768,
    )
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.n_layers}L d{cfg.d_model} -> {n_params/1e6:.1f}M params")

    opt_init, opt_update = make_optimizer(lr=6e-4, warmup=50, steps=args.steps)
    opt = opt_init(params)
    step = jax.jit(make_train_step(api, opt_update), donate_argnums=(0, 1))
    stream = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=0)).batches()

    t0 = time.time()
    first = None
    for i in range(args.steps):
        b = next(stream)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        loss = float(m["loss"])
        first = first or loss
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  loss {loss:.4f}  {tok_s:,.0f} tok/s")

    print(f"\nloss {first:.3f} -> {loss:.3f} over {args.steps} steps")
    if args.ckpt_dir:
        path = save(args.ckpt_dir, args.steps, {"params": params})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
