"""Verification harness: exported ST vs. the JAX serving oracle.

The deployment story only holds if the PLC-side block decides exactly what
the fleet engine decides, so this module owns the replay machinery the test
suite and ``examples/export_st.py`` share:

* :func:`window_starts` / :func:`stream_windows` — the serving ring's window
  schedule replayed in plain numpy: a window completes at cycle ``c`` (the
  0-based index of its last reading) when ``c + 1 >= window`` and
  ``(c + 1 - window) % stride == 0`` — exactly when ``ServingCore`` fires —
  and spans ``readings[c + 1 - window : c + 1]`` oldest-first with features
  interleaved per reading, the unrolled-ring layout the engine feeds the
  model.
* :func:`emulate_stream` — one stream's raw readings through the emulated
  FUNCTION_BLOCK, one batched interpreter pass over all of its windows.
* :func:`sequential_f32_mse` — the **score contract** oracle.  A PLC sums
  the squared errors sequentially in f32; XLA's row reduction reassociates,
  so the two agree only to epsilon even over bit-identical inputs.  The
  suite therefore asserts three things about a SINT score-head export: the
  emulated score bit-matches THIS oracle over the bit-exact SINT model
  outputs, the verdict (strict ``score > threshold``) matches the engine
  exactly, and the engine's own score agrees to tight relative tolerance.
* :func:`run_engine` — the `StreamEngine` side of the comparison: drive raw
  fleet readings cycle by cycle and collect the per-window `Verdict`s.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.codegen.emulator import STFunctionBlock
from repro.codegen.st import STExport


def window_starts(n_cycles: int, window: int, stride: int) -> List[int]:
    """Cycles (0-based last-reading index) at which a verdict window
    completes — `ServingCore`'s ready schedule (``Verdict.cycle`` values)."""
    return [c for c in range(n_cycles)
            if c + 1 >= window and (c + 1 - window) % stride == 0]


def stream_windows(readings: np.ndarray, window: int,
                   stride: int) -> np.ndarray:
    """All completed windows of one stream's ``(n_cycles, F)`` readings as a
    ``(n_windows, window * F)`` batch — oldest reading first, features
    interleaved per reading (the engine's unrolled-ring model input)."""
    readings = np.asarray(readings, np.float32)
    n_cycles, n_features = readings.shape
    rows = [readings[c + 1 - window:c + 1].reshape(-1)
            for c in window_starts(n_cycles, window, stride)]
    return (np.stack(rows) if rows
            else np.zeros((0, window * n_features), np.float32))


def normalize_windows(windows: np.ndarray, mean, std) -> np.ndarray:
    """The engines' host-side ingest normalization, replayed per reading:
    ``(x - mean) / std`` elementwise in f32 (two IEEE ops, the same two the
    exported block applies when normalization is baked in)."""
    windows = np.asarray(windows, np.float32)
    f = len(mean)
    shaped = windows.reshape(windows.shape[0], -1, f)
    out = (shaped - np.asarray(mean, np.float32)) / np.asarray(std,
                                                               np.float32)
    return out.reshape(windows.shape).astype(np.float32)


def sequential_f32_mse(y: np.ndarray, target: np.ndarray) -> np.ndarray:
    """Per-row mean squared error accumulated SEQUENTIALLY in f32 — the
    arithmetic a scan-cycle FOR loop performs, and the score oracle SINT
    score-head exports are bit-checked against."""
    y = np.asarray(y, np.float32)
    target = np.asarray(target, np.float32)
    acc = np.zeros(y.shape[0], np.float32)
    for i in range(y.shape[1]):
        t = (y[:, i] - target[:, i]).astype(np.float32)
        acc = (acc + t * t).astype(np.float32)
    return (acc / np.float32(y.shape[1])).astype(np.float32)


def _np_act(act: str, y: np.ndarray) -> np.ndarray:
    if act == "relu":
        return np.maximum(y, np.float32(0.0))
    if act == "linear":
        return y
    if act == "sigmoid":
        return (np.float32(1.0)
                / (np.float32(1.0) + np.exp(-y))).astype(np.float32)
    if act == "tanh":
        return np.tanh(y).astype(np.float32)
    raise ValueError(f"activation {act!r} has no numpy reference here")


def numpy_mlp_ref(x: np.ndarray, stack) -> np.ndarray:
    """The per-layer §6.1 reference in pure numpy — the **bit-oracle** for
    SINT exports.

    Semantics are ``ref.dense_layer_ref`` run eagerly: requantize is two
    separately-rounded f32 ops (``f32(acc) * f32(x_scale * w_scale)`` then
    ``+ b``).  The eager JAX reference bit-matches this; a *jitted* reference
    does NOT once biases are nonzero — XLA contracts the mul+add into an
    FMA, shifting last bits — and neither does the padded fused kernel.  A
    PLC executes the two-op form, so this is the arithmetic the emitted ST
    is held bit-exact to; XLA-side programs agree to an ulp.
    """
    out = np.asarray(x, np.float32)
    for p, act in stack:
        p = {k: (None if v is None else np.asarray(v))
             for k, v in p.items()}
        if "qw" in p:
            qw = p["qw"]
            if qw.dtype != np.int8:
                raise ValueError(
                    "numpy_mlp_ref covers REAL and SINT stacks only (INT/"
                    "DINT accumulate in f32 on the JAX side)")
            xs = np.float32(p["x_scale"])
            t = (out / xs).astype(np.float32)
            xq = np.clip(np.rint(t), -127, 127).astype(np.int32)
            acc = xq @ qw.astype(np.int32)
            s = (xs * p["w_scale"].astype(np.float32)).astype(np.float32)
            y = (acc.astype(np.float32) * s).astype(np.float32)
        else:
            y = (out @ p["w"].astype(np.float32)).astype(np.float32)
        if p.get("b") is not None:
            y = (y + p["b"].astype(np.float32)).astype(np.float32)
        out = _np_act(act, y)
    return out


def emulate_stream(export: STExport, readings: np.ndarray, *, stride: int,
                   fb: Optional[STFunctionBlock] = None,
                   ) -> Dict[str, np.ndarray]:
    """Replay one stream's raw ``(n_cycles, F)`` readings through the
    emulated block: every completed window in one batched FB pass.

    Returns the block's VAR_OUTPUTs batched over windows plus ``"cycle"``
    (the engine cycle each window completed at — `Verdict.cycle`).  The
    export must have ingest normalization baked in if the engine the result
    is compared against normalizes (it does) — pass raw readings either way.
    """
    wins = stream_windows(readings, export.window, stride)
    cycles = window_starts(len(readings), export.window, stride)
    if fb is None:
        fb = STFunctionBlock(export.text)
    out = fb.call({"X": wins}) if len(wins) else {
        d.name: np.zeros((0,) if d.lo is None else (0, d.size))
        for d in STFunctionBlock(export.text).outputs}
    out["cycle"] = np.asarray(cycles, np.int64)
    return out


def run_engine(model, params, readings: np.ndarray, *, stride: int,
               head=None, backend: str = "auto") -> list:
    """Drive a `StreamEngine` over ``(n_cycles, S, F)`` raw fleet readings
    cycle by cycle (unsharded, synchronous — the bit-reference serving
    configuration) and return every `Verdict` in emission order."""
    from repro.serving.streams import StreamEngine

    readings = np.asarray(readings, np.float32)
    n_cycles, n_streams, n_features = readings.shape
    engine = StreamEngine(model, params, n_streams=n_streams,
                          n_features=n_features, stride=stride, head=head,
                          backend=backend, shard=False)
    verdicts = []
    for t in range(n_cycles):
        verdicts.extend(engine.ingest(readings[t]))
    return verdicts
