"""A batched IEC 61131-3 Structured Text interpreter for the emitted subset.

This is the *verification half* of the ST export backend: every
``FUNCTION_BLOCK`` that ``repro.codegen.st`` emits is parsed and executed
here, in-suite, against the JAX oracle — the emulator is the test harness
that turns "the exporter looks right" into "the exported arithmetic IS the
served arithmetic" (bit-exact for SINT, epsilon for REAL).  It therefore
implements the PLC-relevant semantics precisely rather than conveniently:

* **Strong typing.**  REAL is IEEE-754 binary32 with one rounding per
  operation; SINT/INT/DINT are int8/int16/int32.  There are NO implicit
  conversions: ``REAL + DINT`` is a compile-time :class:`STTypeError`, and
  mixed-width integer arithmetic must go through the explicit
  ``<SRC>_TO_<DST>`` conversion functions, exactly as a strict 61131-3
  compiler enforces.  Untyped integer literals adapt to the concrete type
  they meet (``ACC := 0`` is a DINT zero when ``ACC`` is DINT), with
  compile-time range checks.
* **Integer semantics.**  Arithmetic wraps two's-complement at the declared
  width; division truncates toward zero and traps on a zero divisor; ``MOD``
  takes the dividend's sign (so ``a = (a / b) * b + (a MOD b)`` holds).
* **Conversions.**  ``REAL_TO_SINT/INT/DINT`` round half-to-even (the
  61131-3 / IEC 60559 convention — identical to ``numpy.rint``), and trap on
  non-finite or out-of-range values; narrowing integer conversions trap out
  of range; ``TRUNC`` truncates toward zero to DINT.
* **FB state.**  ``VAR`` (and ``VAR_OUTPUT``) values persist across
  :meth:`STFunctionBlock.call` invocations, like a real function block
  instance; :meth:`STFunctionBlock.reset` re-runs the declaration
  initializers.  ``VAR CONSTANT`` is write-protected at compile time.

**Batched execution.**  Replaying a full scenario run means evaluating the
same block over hundreds of windows, so the interpreter is *vectorized over
a window batch*: every runtime scalar is either a numpy scalar or a ``(B,)``
lane vector, ``IF``/``ELSIF``/``ELSE`` with batch-varying conditions run
both branches under complementary lane masks (assignments are
``np.where``-predicated), and one interpreted pass serves the whole batch.
Two restrictions follow (both hold for all emitted code, and both trap with
a clear error rather than silently mis-executing): array indices and ``FOR``
bounds must be batch-uniform, and a ``FOR`` counter is shared across lanes
(IEC leaves the counter undefined after the loop, so masking it is not
observable in conforming code).

Supported subset (everything ``codegen/st.py`` emits, plus enough slack for
hand-written test programs): one ``FUNCTION_BLOCK`` per source;
``VAR_INPUT`` / ``VAR_OUTPUT`` / ``VAR`` / ``VAR CONSTANT`` declarations of
REAL/SINT/INT/DINT/BOOL scalars and 1-D arrays with literal initializers;
assignment, ``IF/ELSIF/ELSE``, ``FOR .. TO .. BY``; arithmetic, comparison
and boolean operators; ``MAX/MIN/ABS/EXP/SQRT/LN/TRUNC`` and the
``<SRC>_TO_<DST>`` conversion family; ``(* ... *)`` comments.
"""

from __future__ import annotations

import re
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np


class STError(Exception):
    """Base for everything the emulator raises about an ST program."""


class STSyntaxError(STError):
    pass


class STTypeError(STError):
    pass


class STRuntimeError(STError):
    pass


SCALAR_TYPES = ("REAL", "SINT", "INT", "DINT", "BOOL")
INT_TYPES = ("SINT", "INT", "DINT")
DTYPES = {
    "REAL": np.float32,
    "SINT": np.int8,
    "INT": np.int16,
    "DINT": np.int32,
    "BOOL": np.bool_,
}
INT_RANGES = {
    t: (int(np.iinfo(DTYPES[t]).min), int(np.iinfo(DTYPES[t]).max))
    for t in INT_TYPES
}
_ANYINT = "ANYINT"          # untyped integer literal, adapts to context
_INT_WIDTH = {"SINT": 8, "INT": 16, "DINT": 32}

KEYWORDS = {
    "FUNCTION_BLOCK", "END_FUNCTION_BLOCK", "VAR_INPUT", "VAR_OUTPUT",
    "VAR", "CONSTANT", "END_VAR", "ARRAY", "OF", "IF", "THEN", "ELSIF",
    "ELSE", "END_IF", "FOR", "TO", "BY", "DO", "END_FOR", "AND", "OR",
    "XOR", "NOT", "MOD", "TRUE", "FALSE",
} | set(SCALAR_TYPES)


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(
    r"""(?P<ws>\s+)
      | (?P<comment>\(\*.*?\*\))
      | (?P<real>\d+\.\d+(?:[eE][+-]?\d+)?|\d+[eE][+-]?\d+)
      | (?P<int>\d+)
      | (?P<id>[A-Za-z_][A-Za-z0-9_]*)
      | (?P<op>:=|\.\.|<=|>=|<>|[][(),;:+\-*/<>=])
    """,
    re.VERBOSE | re.DOTALL,
)


def tokenize(text: str) -> List[Tuple[str, object, int]]:
    """``(kind, value, line)`` tokens; kinds: id / int / real / op / eof.
    Identifiers are case-normalized to upper (IEC identifiers are
    case-insensitive); ``(* ... *)`` comments and whitespace are dropped."""
    toks: List[Tuple[str, object, int]] = []
    pos, line = 0, 1
    n = len(text)
    while pos < n:
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise STSyntaxError(
                f"line {line}: unexpected character {text[pos]!r}")
        kind = m.lastgroup
        tok = m.group()
        if kind == "id":
            toks.append(("id", tok.upper(), line))
        elif kind == "int":
            toks.append(("int", int(tok), line))
        elif kind == "real":
            toks.append(("real", float(tok), line))
        elif kind == "op":
            toks.append(("op", tok, line))
        # ws / comment: dropped (but still advance the line counter)
        line += tok.count("\n")
        pos = m.end()
    toks.append(("eof", None, line))
    return toks


# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


class _Node:
    __slots__ = ("line",)

    def __init__(self, line):
        self.line = line


class _Lit(_Node):
    __slots__ = ("value", "kind")        # kind: ANYINT / REAL / BOOL

    def __init__(self, line, value, kind):
        super().__init__(line)
        self.value = value
        self.kind = kind


class _Var(_Node):
    __slots__ = ("name",)

    def __init__(self, line, name):
        super().__init__(line)
        self.name = name


class _Index(_Node):
    __slots__ = ("name", "idx")

    def __init__(self, line, name, idx):
        super().__init__(line)
        self.name = name
        self.idx = idx


class _Unary(_Node):
    __slots__ = ("op", "e")

    def __init__(self, line, op, e):
        super().__init__(line)
        self.op = op
        self.e = e


class _Bin(_Node):
    __slots__ = ("op", "a", "b")

    def __init__(self, line, op, a, b):
        super().__init__(line)
        self.op = op
        self.a = a
        self.b = b


class _Call(_Node):
    __slots__ = ("fn", "args")

    def __init__(self, line, fn, args):
        super().__init__(line)
        self.fn = fn
        self.args = args


class _Assign(_Node):
    __slots__ = ("target", "expr")

    def __init__(self, line, target, expr):
        super().__init__(line)
        self.target = target
        self.expr = expr


class _If(_Node):
    __slots__ = ("arms", "orelse")       # arms: [(cond, [stmt])]

    def __init__(self, line, arms, orelse):
        super().__init__(line)
        self.arms = arms
        self.orelse = orelse


class _For(_Node):
    __slots__ = ("var", "start", "stop", "step", "body")

    def __init__(self, line, var, start, stop, step, body):
        super().__init__(line)
        self.var = var
        self.start = start
        self.stop = stop
        self.step = step
        self.body = body


class _Decl:
    __slots__ = ("name", "base", "lo", "hi", "section", "const", "init",
                 "line")

    def __init__(self, name, base, lo, hi, section, const, init, line):
        self.name = name
        self.base = base          # scalar type name
        self.lo = lo              # None for scalars
        self.hi = hi
        self.section = section    # VAR_INPUT / VAR_OUTPUT / VAR
        self.const = const
        self.init = init          # scalar literal | list | None
        self.line = line

    @property
    def is_array(self) -> bool:
        return self.lo is not None

    @property
    def size(self) -> int:
        return self.hi - self.lo + 1


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------


class _Parser:
    def __init__(self, toks):
        self.toks = toks
        self.i = 0

    def peek(self):
        return self.toks[self.i]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind, value=None):
        k, v, line = self.next()
        if k != kind or (value is not None and v != value):
            want = value if value is not None else kind
            raise STSyntaxError(f"line {line}: expected {want!r}, got {v!r}")
        return v, line

    def at(self, kind, value=None):
        k, v, _ = self.peek()
        return k == kind and (value is None or v == value)

    # -- program ------------------------------------------------------------

    def parse_function_block(self):
        self.expect("id", "FUNCTION_BLOCK")
        name, _ = self.expect("id")
        if name in KEYWORDS:
            raise STSyntaxError(f"FUNCTION_BLOCK name {name!r} is a keyword")
        decls: Dict[str, _Decl] = {}
        order: List[str] = []
        while self.at("id", "VAR_INPUT") or self.at("id", "VAR_OUTPUT") or \
                self.at("id", "VAR"):
            section, line = self.expect("id")
            const = False
            if section == "VAR" and self.at("id", "CONSTANT"):
                self.next()
                const = True
            while not self.at("id", "END_VAR"):
                d = self.parse_decl(section, const)
                if d.name in decls:
                    raise STSyntaxError(
                        f"line {d.line}: duplicate declaration of {d.name}")
                decls[d.name] = d
                order.append(d.name)
            self.expect("id", "END_VAR")
        stmts = self.parse_statements(("END_FUNCTION_BLOCK",))
        self.expect("id", "END_FUNCTION_BLOCK")
        if not self.at("eof"):
            _, v, line = self.peek()
            raise STSyntaxError(
                f"line {line}: trailing content after END_FUNCTION_BLOCK")
        return name, decls, order, stmts

    def parse_decl(self, section, const):
        name, line = self.expect("id")
        if name in KEYWORDS:
            raise STSyntaxError(f"line {line}: {name!r} is a keyword")
        self.expect("op", ":")
        lo = hi = None
        if self.at("id", "ARRAY"):
            self.next()
            self.expect("op", "[")
            lo = self.parse_int_bound()
            self.expect("op", "..")
            hi = self.parse_int_bound()
            self.expect("op", "]")
            self.expect("id", "OF")
            if hi < lo:
                raise STSyntaxError(
                    f"line {line}: array bounds [{lo}..{hi}] are empty")
        base, _ = self.expect("id")
        if base not in SCALAR_TYPES:
            raise STSyntaxError(f"line {line}: unsupported type {base!r}")
        init = None
        if self.at("op", ":="):
            self.next()
            if lo is not None:
                self.expect("op", "[")
                init = []
                while True:
                    init.append(self.parse_literal())
                    if self.at("op", ","):
                        self.next()
                        continue
                    break
                self.expect("op", "]")
                if len(init) != hi - lo + 1:
                    raise STSyntaxError(
                        f"line {line}: {name} initializer has {len(init)} "
                        f"elements for ARRAY[{lo}..{hi}]")
            else:
                init = self.parse_literal()
        self.expect("op", ";")
        return _Decl(name, base, lo, hi, section, const, init, line)

    def parse_int_bound(self):
        neg = False
        if self.at("op", "-"):
            self.next()
            neg = True
        v, _ = self.expect("int")
        return -v if neg else v

    def parse_literal(self):
        """A (possibly signed) numeric or boolean literal — initializers
        only, parsed to raw python values for speed (weight arrays are
        tens of thousands of elements)."""
        neg = False
        if self.at("op", "-"):
            self.next()
            neg = True
        k, v, line = self.next()
        if k == "int" or k == "real":
            return -v if neg else v
        if k == "id" and v in ("TRUE", "FALSE") and not neg:
            return v == "TRUE"
        raise STSyntaxError(f"line {line}: expected a literal, got {v!r}")

    # -- statements ---------------------------------------------------------

    def parse_statements(self, stop_keywords):
        out = []
        while True:
            k, v, _ = self.peek()
            if k == "eof" or (k == "id" and v in stop_keywords):
                return out
            out.append(self.parse_statement())

    def parse_statement(self):
        k, v, line = self.peek()
        if k == "id" and v == "IF":
            return self.parse_if()
        if k == "id" and v == "FOR":
            return self.parse_for()
        # assignment
        target = self.parse_primary()
        if not isinstance(target, (_Var, _Index)):
            raise STSyntaxError(
                f"line {line}: statement must be an assignment")
        self.expect("op", ":=")
        expr = self.parse_expr()
        self.expect("op", ";")
        return _Assign(line, target, expr)

    def parse_if(self):
        _, line = self.expect("id", "IF")
        arms = []
        cond = self.parse_expr()
        self.expect("id", "THEN")
        arms.append((cond, self.parse_statements(
            ("ELSIF", "ELSE", "END_IF"))))
        while self.at("id", "ELSIF"):
            self.next()
            cond = self.parse_expr()
            self.expect("id", "THEN")
            arms.append((cond, self.parse_statements(
                ("ELSIF", "ELSE", "END_IF"))))
        orelse = []
        if self.at("id", "ELSE"):
            self.next()
            orelse = self.parse_statements(("END_IF",))
        self.expect("id", "END_IF")
        self.expect("op", ";")
        return _If(line, arms, orelse)

    def parse_for(self):
        _, line = self.expect("id", "FOR")
        var, _ = self.expect("id")
        self.expect("op", ":=")
        start = self.parse_expr()
        self.expect("id", "TO")
        stop = self.parse_expr()
        step = None
        if self.at("id", "BY"):
            self.next()
            step = self.parse_expr()
        self.expect("id", "DO")
        body = self.parse_statements(("END_FOR",))
        self.expect("id", "END_FOR")
        self.expect("op", ";")
        return _For(line, var, start, stop, step, body)

    # -- expressions (precedence climbing) ----------------------------------

    def parse_expr(self):
        return self.parse_or()

    def parse_or(self):
        e = self.parse_xor()
        while self.at("id", "OR"):
            _, _, line = self.next()
            e = _Bin(line, "OR", e, self.parse_xor())
        return e

    def parse_xor(self):
        e = self.parse_and()
        while self.at("id", "XOR"):
            _, _, line = self.next()
            e = _Bin(line, "XOR", e, self.parse_and())
        return e

    def parse_and(self):
        e = self.parse_cmp()
        while self.at("id", "AND"):
            _, _, line = self.next()
            e = _Bin(line, "AND", e, self.parse_cmp())
        return e

    def parse_cmp(self):
        e = self.parse_add()
        k, v, line = self.peek()
        if k == "op" and v in ("=", "<>", "<", ">", "<=", ">="):
            self.next()
            return _Bin(line, v, e, self.parse_add())
        return e

    def parse_add(self):
        e = self.parse_mul()
        while True:
            k, v, line = self.peek()
            if k == "op" and v in ("+", "-"):
                self.next()
                e = _Bin(line, v, e, self.parse_mul())
            else:
                return e

    def parse_mul(self):
        e = self.parse_unary()
        while True:
            k, v, line = self.peek()
            if (k == "op" and v in ("*", "/")) or (k == "id" and v == "MOD"):
                self.next()
                e = _Bin(line, "MOD" if v == "MOD" else v, e,
                         self.parse_unary())
            else:
                return e

    def parse_unary(self):
        k, v, line = self.peek()
        if k == "op" and v in ("-", "+"):
            self.next()
            e = self.parse_unary()
            return e if v == "+" else _Unary(line, "-", e)
        if k == "id" and v == "NOT":
            self.next()
            return _Unary(line, "NOT", self.parse_unary())
        return self.parse_primary()

    def parse_primary(self):
        k, v, line = self.next()
        if k == "int":
            return _Lit(line, v, _ANYINT)
        if k == "real":
            return _Lit(line, v, "REAL")
        if k == "op" and v == "(":
            e = self.parse_expr()
            self.expect("op", ")")
            return e
        if k == "id":
            if v == "TRUE":
                return _Lit(line, True, "BOOL")
            if v == "FALSE":
                return _Lit(line, False, "BOOL")
            if v in KEYWORDS:
                raise STSyntaxError(
                    f"line {line}: unexpected keyword {v!r} in expression")
            if self.at("op", "("):
                self.next()
                args = []
                if not self.at("op", ")"):
                    while True:
                        args.append(self.parse_expr())
                        if self.at("op", ","):
                            self.next()
                            continue
                        break
                self.expect("op", ")")
                return _Call(line, v, args)
            if self.at("op", "["):
                self.next()
                idx = self.parse_expr()
                self.expect("op", "]")
                return _Index(line, v, idx)
            return _Var(line, v)
        raise STSyntaxError(f"line {line}: unexpected token {v!r}")


# ---------------------------------------------------------------------------
# Runtime helpers
# ---------------------------------------------------------------------------


class _Frame:
    __slots__ = ("vars", "mask", "batch")

    def __init__(self, vars, batch):
        self.vars = vars
        self.mask = None          # None = all lanes active
        self.batch = batch


def _uniform_int(v, line, what):
    """Array indices / loop bounds must be one value across the batch."""
    if isinstance(v, np.ndarray) and v.ndim:
        first = v.flat[0]
        if not (v == first).all():
            raise STRuntimeError(
                f"line {line}: batch-varying {what} is outside the emulated "
                "subset (all lanes must agree)")
        return int(first)
    return int(v)


def _check_active(bad, mask, line, msg):
    """Trap only if a *live* lane violates; masked-off lanes may hold
    garbage (their results are discarded by the predication)."""
    if mask is not None:
        bad = np.logical_and(bad, mask)
    if np.any(bad):
        raise STRuntimeError(f"line {line}: {msg}")


def _wrap_int(v, base):
    """Two's-complement wrap of a python int into an ST integer type."""
    width = _INT_WIDTH[base]
    v &= (1 << width) - 1
    if v >= 1 << (width - 1):
        v -= 1 << width
    return DTYPES[base](v)


def _store(frame, old, new):
    if frame.mask is None:
        return new
    return np.where(frame.mask, new, old)


# ---------------------------------------------------------------------------
# Compiler: typed AST -> closures over a _Frame
# ---------------------------------------------------------------------------


class _Compiler:
    def __init__(self, decls: Dict[str, _Decl]):
        self.decls = decls

    # -- type plumbing ------------------------------------------------------

    def _decl(self, name, line):
        d = self.decls.get(name)
        if d is None:
            raise STTypeError(f"line {line}: undeclared variable {name}")
        return d

    def _unify(self, ta, tb, line, what):
        """The common type of two operand types under strict IEC typing:
        identical types unify; an untyped integer literal adapts to any
        integer type; everything else is a compile-time error."""
        if ta == tb:
            return ta
        if ta == _ANYINT and tb in INT_TYPES:
            return tb
        if tb == _ANYINT and ta in INT_TYPES:
            return ta
        raise STTypeError(
            f"line {line}: {what} needs matching types, got {ta} and {tb} "
            "(IEC 61131-3 has no implicit conversions; use "
            "<SRC>_TO_<DST>)")

    def _coerce(self, t_from, fn, t_to, line):
        """Adapt an ANYINT closure to a concrete integer type (range-checked
        at runtime; literals are constant so this fires at first use)."""
        if t_from == t_to:
            return fn
        assert t_from == _ANYINT and t_to in INT_TYPES
        lo, hi = INT_RANGES[t_to]
        dtype = DTYPES[t_to]

        def run(fr):
            v = fn(fr)
            if not lo <= v <= hi:
                raise STRuntimeError(
                    f"line {line}: literal {v} out of {t_to} range "
                    f"[{lo}, {hi}]")
            return dtype(v)

        return run

    # -- expressions --------------------------------------------------------

    def expr(self, node):
        """Compile an expression to ``(type, fn)``; ``fn(frame)`` returns a
        numpy scalar / (B,) vector (or a python int for ANYINT)."""
        if isinstance(node, _Lit):
            if node.kind == "REAL":
                v = np.float32(node.value)
                return "REAL", lambda fr: v
            if node.kind == "BOOL":
                v = np.bool_(node.value)
                return "BOOL", lambda fr: v
            v = node.value
            return _ANYINT, lambda fr: v
        if isinstance(node, _Var):
            d = self._decl(node.name, node.line)
            if d.is_array:
                raise STTypeError(
                    f"line {node.line}: {node.name} is an array; index it")
            name = node.name
            return d.base, lambda fr: fr.vars[name]
        if isinstance(node, _Index):
            return self._index_read(node)
        if isinstance(node, _Unary):
            return self._unary(node)
        if isinstance(node, _Bin):
            return self._binary(node)
        if isinstance(node, _Call):
            return self._call(node)
        raise STSyntaxError(f"line {node.line}: unsupported expression")

    def _index_read(self, node):
        d = self._decl(node.name, node.line)
        if not d.is_array:
            raise STTypeError(f"line {node.line}: {node.name} is not an array")
        ti, fi = self.expr(node.idx)
        if ti not in INT_TYPES and ti != _ANYINT:
            raise STTypeError(
                f"line {node.line}: array index must be an integer, got {ti}")
        name, lo, size, line = node.name, d.lo, d.size, node.line

        def run(fr):
            i = _uniform_int(fi(fr), line, "array index") - lo
            if not 0 <= i < size:
                raise STRuntimeError(
                    f"line {line}: index {i + lo} out of bounds for "
                    f"{name}[{lo}..{lo + size - 1}]")
            return fr.vars[name][i]

        return d.base, run

    def _unary(self, node):
        t, f = self.expr(node.e)
        if node.op == "NOT":
            if t != "BOOL":
                raise STTypeError(
                    f"line {node.line}: NOT needs BOOL, got {t}")
            return "BOOL", lambda fr: np.logical_not(f(fr))
        # negation
        if t == _ANYINT:
            return _ANYINT, lambda fr: -f(fr)
        if t == "REAL":
            return "REAL", lambda fr: -f(fr)
        if t in INT_TYPES:
            base = t
            return t, lambda fr: -f(fr) if isinstance(f(fr), np.ndarray) \
                else _neg_scalar(f(fr), base)
        raise STTypeError(f"line {node.line}: cannot negate {t}")

    def _binary(self, node):
        op = node.op
        ta, fa = self.expr(node.a)
        tb, fb = self.expr(node.b)
        line = node.line
        if op in ("AND", "OR", "XOR"):
            if ta != "BOOL" or tb != "BOOL":
                raise STTypeError(
                    f"line {line}: {op} needs BOOL operands, got "
                    f"{ta} and {tb}")
            npf = {"AND": np.logical_and, "OR": np.logical_or,
                   "XOR": np.logical_xor}[op]
            return "BOOL", lambda fr: npf(fa(fr), fb(fr))
        if op in ("=", "<>", "<", ">", "<=", ">="):
            t = self._unify(ta, tb, line, f"comparison {op!r}")
            if t == "BOOL" and op not in ("=", "<>"):
                raise STTypeError(
                    f"line {line}: BOOL only supports = and <>")
            fa = self._coerce(ta, fa, t, line) if ta != t else fa
            fb = self._coerce(tb, fb, t, line) if tb != t else fb
            npf = {"=": np.equal, "<>": np.not_equal, "<": np.less,
                   ">": np.greater, "<=": np.less_equal,
                   ">=": np.greater_equal}[op]
            return "BOOL", lambda fr: npf(fa(fr), fb(fr))
        # arithmetic
        t = self._unify(ta, tb, line, f"operator {op!r}")
        if t == "BOOL":
            raise STTypeError(f"line {line}: no arithmetic on BOOL")
        if t == _ANYINT:
            return _ANYINT, self._anyint_arith(op, fa, fb, line)
        fa = self._coerce(ta, fa, t, line) if ta != t else fa
        fb = self._coerce(tb, fb, t, line) if tb != t else fb
        if op == "+":
            return t, lambda fr: fa(fr) + fb(fr)
        if op == "-":
            return t, lambda fr: fa(fr) - fb(fr)
        if op == "*":
            return t, lambda fr: fa(fr) * fb(fr)
        if op == "MOD":
            if t == "REAL":
                raise STTypeError(
                    f"line {line}: MOD is integer-only in IEC 61131-3")
            return t, _int_divmod(fa, fb, line, want_mod=True)
        if op == "/":
            if t == "REAL":
                return t, lambda fr: fa(fr) / fb(fr)
            return t, _int_divmod(fa, fb, line, want_mod=False)
        raise STSyntaxError(f"line {line}: unknown operator {op!r}")

    @staticmethod
    def _anyint_arith(op, fa, fb, line):
        def run(fr):
            a, b = fa(fr), fb(fr)
            if op == "+":
                return a + b
            if op == "-":
                return a - b
            if op == "*":
                return a * b
            if b == 0:
                raise STRuntimeError(f"line {line}: division by zero")
            q = abs(a) // abs(b) * (1 if (a < 0) == (b < 0) else -1)
            return q if op == "/" else a - q * b

        return run

    # -- calls --------------------------------------------------------------

    _CONV_RE = re.compile(r"^(REAL|SINT|INT|DINT)_TO_(REAL|SINT|INT|DINT)$")

    def _call(self, node):
        name, line = node.fn, node.line
        m = self._CONV_RE.match(name)
        if m:
            if len(node.args) != 1:
                raise STTypeError(f"line {line}: {name} takes one argument")
            return self._conversion(m.group(1), m.group(2), node.args[0],
                                    line)
        if name in ("MAX", "MIN"):
            if len(node.args) != 2:
                raise STTypeError(f"line {line}: {name} takes two arguments")
            ta, fa = self.expr(node.args[0])
            tb, fb = self.expr(node.args[1])
            t = self._unify(ta, tb, line, name)
            if t == "BOOL":
                raise STTypeError(f"line {line}: {name} is numeric")
            if t == _ANYINT:
                t = "DINT"
            fa = self._coerce(ta, fa, t, line) if ta != t else fa
            fb = self._coerce(tb, fb, t, line) if tb != t else fb
            npf = np.maximum if name == "MAX" else np.minimum
            return t, lambda fr: npf(fa(fr), fb(fr))
        if name == "ABS":
            (t, f), = [self.expr(a) for a in node.args[:1]]
            if len(node.args) != 1 or t == "BOOL":
                raise STTypeError(f"line {line}: ABS takes one numeric arg")
            if t == _ANYINT:
                return _ANYINT, lambda fr: abs(f(fr))
            return t, lambda fr: np.abs(f(fr))
        if name in ("EXP", "SQRT", "LN"):
            if len(node.args) != 1:
                raise STTypeError(f"line {line}: {name} takes one argument")
            t, f = self.expr(node.args[0])
            if t != "REAL":
                raise STTypeError(f"line {line}: {name} needs REAL, got {t}")
            npf = {"EXP": np.exp, "SQRT": np.sqrt, "LN": np.log}[name]
            return "REAL", lambda fr: npf(f(fr))
        if name == "TRUNC":
            if len(node.args) != 1:
                raise STTypeError(f"line {line}: TRUNC takes one argument")
            t, f = self.expr(node.args[0])
            if t != "REAL":
                raise STTypeError(f"line {line}: TRUNC needs REAL, got {t}")
            return "DINT", _real_to_int(f, "DINT", line, rounder=np.trunc)
        raise STTypeError(f"line {line}: unknown function {name}")

    def _conversion(self, src, dst, arg, line):
        t, f = self.expr(arg)
        if t == _ANYINT and src in INT_TYPES:
            f = self._coerce(t, f, src, line)
        elif t != src:
            raise STTypeError(
                f"line {line}: {src}_TO_{dst} applied to {t} value")
        if src == dst:
            return dst, f
        if dst == "REAL":                       # int -> REAL: exactness up
            return "REAL", lambda fr: _cast(f(fr), np.float32)
        if src == "REAL":                       # REAL -> int: round half-even
            return dst, _real_to_int(f, dst, line, rounder=np.rint)
        # int -> int
        lo_d, hi_d = INT_RANGES[dst]
        lo_s, hi_s = INT_RANGES[src]
        dtype = DTYPES[dst]
        if lo_d <= lo_s and hi_s <= hi_d:       # widening: always exact
            return dst, lambda fr: _cast(f(fr), dtype)

        def run(fr):                            # narrowing: trap out of range
            v = f(fr)
            _check_active((v < lo_d) | (v > hi_d), fr.mask, line,
                          f"{src}_TO_{dst} value out of {dst} range")
            return _cast(np.clip(v, lo_d, hi_d), dtype)

        return dst, run

    # -- statements ---------------------------------------------------------

    def statements(self, stmts):
        return [self.statement(s) for s in stmts]

    def statement(self, node):
        if isinstance(node, _Assign):
            return self._assign(node)
        if isinstance(node, _If):
            return self._if(node)
        if isinstance(node, _For):
            return self._for(node)
        raise STSyntaxError(f"line {node.line}: unsupported statement")

    def _check_writable(self, d, line):
        if d.const:
            raise STTypeError(
                f"line {line}: {d.name} is VAR CONSTANT and cannot be "
                "assigned")

    def _value_for(self, d, expr, line):
        t, f = self.expr(expr)
        if t == d.base:
            return f
        if t == _ANYINT and d.base in INT_TYPES:
            return self._coerce(t, f, d.base, line)
        raise STTypeError(
            f"line {line}: cannot assign {t} to {d.name} ({d.base})")

    def _assign(self, node):
        line = node.line
        if isinstance(node.target, _Var):
            d = self._decl(node.target.name, line)
            if d.is_array:
                raise STTypeError(
                    f"line {line}: whole-array assignment is outside the "
                    "emulated subset")
            self._check_writable(d, line)
            f = self._value_for(d, node.expr, line)
            name = d.name

            def run(fr):
                fr.vars[name] = _store(fr, fr.vars[name], f(fr))

            return run
        d = self._decl(node.target.name, line)
        if not d.is_array:
            raise STTypeError(f"line {line}: {d.name} is not an array")
        self._check_writable(d, line)
        ti, fi = self.expr(node.target.idx)
        if ti not in INT_TYPES and ti != _ANYINT:
            raise STTypeError(
                f"line {line}: array index must be an integer, got {ti}")
        f = self._value_for(d, node.expr, line)
        name, lo, size = d.name, d.lo, d.size

        def run(fr):
            i = _uniform_int(fi(fr), line, "array index") - lo
            if not 0 <= i < size:
                raise STRuntimeError(
                    f"line {line}: index {i + lo} out of bounds for "
                    f"{name}[{lo}..{lo + size - 1}]")
            arr = fr.vars[name]
            arr[i] = _store(fr, arr[i], f(fr))

        return run

    def _if(self, node):
        arms = [(self._bool_cond(c, node.line), self.statements(b))
                for c, b in node.arms]
        orelse = self.statements(node.orelse)

        def run(fr):
            outer = fr.mask
            rem = outer                   # lanes still looking for a branch
            try:
                for cond, body in arms:
                    fr.mask = rem
                    c = cond(fr)
                    if not (isinstance(c, np.ndarray) and c.ndim):
                        if bool(c):       # batch-uniform condition: fast path
                            fr.mask = rem
                            for s in body:
                                s(fr)
                            return
                        continue
                    take = c if rem is None else np.logical_and(rem, c)
                    if take.any():
                        fr.mask = take
                        for s in body:
                            s(fr)
                    rem = np.logical_and(rem, np.logical_not(c)) \
                        if rem is not None else np.logical_not(c)
                    if not rem.any():
                        return
                if orelse and (rem is None or not isinstance(rem, np.ndarray)
                               or rem.any()):
                    fr.mask = rem
                    for s in orelse:
                        s(fr)
            finally:
                fr.mask = outer

        return run

    def _bool_cond(self, cond, line):
        t, f = self.expr(cond)
        if t != "BOOL":
            raise STTypeError(
                f"line {line}: IF condition must be BOOL, got {t}")
        return f

    def _for(self, node):
        d = self._decl(node.var, node.line)
        if d.is_array or d.base not in INT_TYPES:
            raise STTypeError(
                f"line {node.line}: FOR counter {node.var} must be an "
                "integer scalar")
        self._check_writable(d, node.line)
        bounds = []
        for what, e in (("start", node.start), ("stop", node.stop),
                        ("step", node.step)):
            if e is None:
                bounds.append(None)
                continue
            t, f = self.expr(e)
            if t not in INT_TYPES and t != _ANYINT:
                raise STTypeError(
                    f"line {node.line}: FOR {what} must be an integer, "
                    f"got {t}")
            bounds.append(f)
        fs, fe, fstep = bounds
        body = self.statements(node.body)
        name, base, line = d.name, d.base, node.line
        dtype = DTYPES[base]

        def run(fr):
            i = _uniform_int(fs(fr), line, "FOR bound")
            stop = _uniform_int(fe(fr), line, "FOR bound")
            step = 1 if fstep is None else _uniform_int(fstep(fr), line,
                                                        "FOR step")
            if step == 0:
                raise STRuntimeError(f"line {line}: FOR step of zero")
            while (i <= stop) if step > 0 else (i >= stop):
                fr.vars[name] = dtype(i)
                for s in body:
                    s(fr)
                i += step
            # IEC leaves the counter undefined after the loop; pin it to the
            # first non-taken value for determinism.
            fr.vars[name] = _wrap_int(i, base)

        return run


def _neg_scalar(v, base):
    return _wrap_int(-int(v), base)


def _cast(v, dtype):
    if isinstance(v, np.ndarray):
        return v.astype(dtype)
    return dtype(v)


def _real_to_int(f, dst, line, *, rounder):
    lo, hi = INT_RANGES[dst]
    dtype = DTYPES[dst]

    def run(fr):
        r = rounder(f(fr))
        _check_active(~np.isfinite(r) | (r < lo) | (r > hi), fr.mask, line,
                      f"REAL value does not fit {dst}")
        return _cast(np.clip(r, lo, hi), dtype)

    return run


def _int_divmod(fa, fb, line, *, want_mod):
    def run(fr):
        a, b = fa(fr), fb(fr)
        bz = b == 0
        _check_active(bz, fr.mask, line, "division by zero")
        if np.any(bz):                # masked-off zero lanes: dummy divisor
            b = np.where(bz, np.asarray(1, dtype=np.asarray(b).dtype), b)
        q = np.floor_divide(a, b)
        r = a - q * b
        adj = np.logical_and(r != 0, (a < 0) != (b < 0))
        q = (q + adj).astype(np.asarray(q).dtype)   # floor -> trunc
        if want_mod:
            return (a - q * b) if isinstance(a, np.ndarray) or \
                isinstance(q, np.ndarray) else a - q * b
        return q

    return run


# ---------------------------------------------------------------------------
# Function block instances
# ---------------------------------------------------------------------------


def _init_scalar(d: _Decl):
    dtype = DTYPES[d.base]
    if d.init is None:
        return dtype(0) if d.base != "BOOL" else np.bool_(False)
    return _coerce_init(d, d.init)


def _coerce_init(d: _Decl, v):
    if d.base == "REAL":
        if isinstance(v, bool):
            raise STTypeError(f"{d.name}: BOOL initializer for REAL")
        return np.float32(v)
    if d.base == "BOOL":
        if not isinstance(v, bool):
            raise STTypeError(f"{d.name}: BOOL initializer must be "
                              "TRUE/FALSE")
        return np.bool_(v)
    if isinstance(v, float) or isinstance(v, bool):
        raise STTypeError(f"{d.name}: {d.base} initializer must be an "
                          "integer literal")
    lo, hi = INT_RANGES[d.base]
    if not lo <= v <= hi:
        raise STTypeError(
            f"{d.name}: initializer {v} out of {d.base} range [{lo}, {hi}]")
    return DTYPES[d.base](v)


class STFunctionBlock:
    """A parsed, compiled, *stateful* FUNCTION_BLOCK instance.

    :meth:`call` runs one invocation over a window batch and returns the
    ``VAR_OUTPUT`` values as ``(B,)`` / ``(B, size)`` arrays.  ``VAR`` and
    ``VAR_OUTPUT`` state persists across calls (FB instance semantics);
    :meth:`reset` re-runs the declaration initializers.
    """

    def __init__(self, text: str):
        parser = _Parser(tokenize(text))
        self.name, self._decls, self._order, stmts = \
            parser.parse_function_block()
        self._stmts = _Compiler(self._decls).statements(stmts)
        self._state: Dict[str, object] = {}
        self.reset()

    # -- declaration surface -----------------------------------------------

    def _section(self, section) -> List[_Decl]:
        return [self._decls[n] for n in self._order
                if self._decls[n].section == section]

    @property
    def inputs(self) -> List[_Decl]:
        return self._section("VAR_INPUT")

    @property
    def outputs(self) -> List[_Decl]:
        return self._section("VAR_OUTPUT")

    def reset(self) -> None:
        for name in self._order:
            d = self._decls[name]
            if d.is_array:
                if d.init is None:
                    z = _init_scalar(_Decl(name, d.base, None, None,
                                           d.section, False, None, d.line))
                    self._state[name] = [z] * d.size
                else:
                    self._state[name] = [_coerce_init(d, v) for v in d.init]
            else:
                self._state[name] = _init_scalar(d)

    # -- execution ----------------------------------------------------------

    def call(self, inputs: Dict[str, np.ndarray],
             batch: Optional[int] = None) -> Dict[str, np.ndarray]:
        """One FB invocation over a batch of lanes.

        ``inputs`` maps every VAR_INPUT name to ``(B, size)`` (arrays; a 1-D
        ``(size,)`` is taken as ``B=1``) or ``(B,)`` / scalar (scalars).
        Returns each VAR_OUTPUT as ``(B,)`` or ``(B, size)`` float/int
        arrays of the declared dtype.
        """
        decls_in = self.inputs
        names = {d.name for d in decls_in}
        for k in inputs:
            if k.upper() not in names:
                raise STRuntimeError(f"{k} is not a VAR_INPUT of {self.name}")
        staged = {}
        b = batch
        for d in decls_in:
            given = None
            for k, v in inputs.items():
                if k.upper() == d.name:
                    given = np.asarray(v)
            if given is None:
                raise STRuntimeError(f"missing input {d.name}")
            if d.is_array:
                if given.ndim == 1:
                    given = given[None, :]
                if given.ndim != 2 or given.shape[1] != d.size:
                    raise STRuntimeError(
                        f"input {d.name} wants (B, {d.size}), got "
                        f"{given.shape}")
            else:
                if given.ndim == 0:
                    given = given[None]
                if given.ndim != 1:
                    raise STRuntimeError(
                        f"input {d.name} wants (B,) or scalar, got "
                        f"{given.shape}")
            if given.shape[0] != 1:
                if b is None:
                    b = given.shape[0]
                elif given.shape[0] != b:
                    raise STRuntimeError(
                        f"inconsistent batch sizes: {b} vs "
                        f"{given.shape[0]} ({d.name})")
            staged[d.name] = given
        b = b or 1
        for d in decls_in:
            given = staged[d.name]
            if given.shape[0] == 1 and b > 1:
                given = np.broadcast_to(given, (b,) + given.shape[1:])
            dtype = DTYPES[d.base]
            if d.base in INT_TYPES:
                lo, hi = INT_RANGES[d.base]
                if np.any((given < lo) | (given > hi)):
                    raise STRuntimeError(
                        f"input {d.name} out of {d.base} range")
            given = given.astype(dtype)
            if d.is_array:
                self._state[d.name] = [
                    np.ascontiguousarray(given[:, j]) for j in range(d.size)]
            else:
                self._state[d.name] = np.ascontiguousarray(given)

        frame = _Frame(self._state, b)
        with np.errstate(all="ignore"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                for s in self._stmts:
                    s(frame)

        out = {}
        for d in self.outputs:
            v = self._state[d.name]
            if d.is_array:
                out[d.name] = np.stack(
                    [np.broadcast_to(np.asarray(c), (b,)) for c in v],
                    axis=1).copy()
            else:
                out[d.name] = np.broadcast_to(np.asarray(v), (b,)).copy()
        return out


def parse_function_block(text: str) -> STFunctionBlock:
    """Parse + compile one FUNCTION_BLOCK source into a callable instance."""
    return STFunctionBlock(text)
