"""IEC 61131-3 Structured Text export backend (the paper's PLC target).

``st`` emits a trained, quantized detector as one self-contained
FUNCTION_BLOCK; ``emulator`` executes the emitted subset with PLC-faithful
semantics; ``verify`` replays scenario windows through both the block and
the serving engine and holds them to the bit-exact (SINT) / epsilon (REAL)
contract.
"""

from repro.codegen.emulator import (STError, STFunctionBlock,
                                    STRuntimeError, STSyntaxError,
                                    STTypeError, parse_function_block)
from repro.codegen.st import STContext, STExport, STExportError, STWriter, \
    export_st, format_real
from repro.codegen.verify import (emulate_stream, normalize_windows,
                                  numpy_mlp_ref, run_engine,
                                  sequential_f32_mse, stream_windows,
                                  window_starts)

__all__ = [
    "STError", "STFunctionBlock", "STRuntimeError", "STSyntaxError",
    "STTypeError", "parse_function_block",
    "STContext", "STExport", "STExportError", "STWriter", "export_st",
    "format_real",
    "emulate_stream", "normalize_windows", "numpy_mlp_ref",
    "run_engine",
    "sequential_f32_mse", "stream_windows", "window_starts",
]
