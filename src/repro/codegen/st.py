"""IEC 61131-3 Structured Text emitter: trained detector -> FUNCTION_BLOCK.

The paper's headline artifact is *native inference on the PLC*: the trained,
quantized model compiled to IEC 61131-3 code a controller executes in its
scan cycle.  :func:`export_st` is that porting step for any all-Dense stack
served by the fleet engines — it emits one self-contained ``FUNCTION_BLOCK``
(no external libraries, weights as ``VAR CONSTANT`` arrays) in one of two
schemes, inferred from the params:

* **REAL** — float params (``w``/``b``): f32 matvec with sequential
  accumulation.  A PLC's REAL is IEEE binary32, so the exported arithmetic
  matches the JAX forward to reassociation error only (the oracle reduces in
  a different order); exports verify to an epsilon, not bit-exactly.
* **SINT** — §6.1-quantized params (``qw`` int8 / ``w_scale`` / ``x_scale``):
  activation quantization with the oracle's exact clip rails (round
  half-to-even, clip to ±127 — the rail guard fires at ``|t| >= 127.0``,
  which decides identically to round-then-clip), int8 weights in
  ``ARRAY OF SINT``, DINT (int32) accumulation, then the per-layer
  f32 requantize ``DINT_TO_REAL(acc) * scale[i] + bias[i]`` with the
  combined per-channel scale precomputed in f32 exactly as
  ``kernels/ops`` stages it.  Integer products and f32 rescale are
  order-independent, so SINT exports are **bit-exact** against
  ``kernels/ref.fused_mlp_ref`` — the property ``codegen.verify`` and the
  test suite enforce on every export.

INT/DINT schemes are rejected: the JAX oracle emulates their accumulation
in f32 (int32 has no native MXU path), which a PLC's genuine integer
arithmetic would *not* reproduce — exporting them would emit code that is
faithful to neither side.

The verdict epilogue is the head's business: ``export_st`` hands a
:class:`STWriter` + :class:`STContext` to ``head.st_epilogue`` (see
``sim.heads``), which declares the verdict ``VAR_OUTPUT``s (classifier:
``PRED``/``CONF``; score heads: ``PRED``/``SCORE``/``THRESHOLD`` with the
calibrated cutoff baked in as a constant).  ``head=None`` exports the bare
body (``Y`` only) — the differential-fuzz harness uses that form.

Ingest normalization can be baked into the block (``normalize=(mean, std)``
per feature): the block then consumes the *raw* ring window exactly as the
serving engines do, applying the same two f32 ops per element the engines'
host-side ingest applies.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.layers import Dense, Input
from repro.kernels.ops import dense_stack


class STExportError(ValueError):
    """The model/params/head combination cannot be exported to ST."""


# Activations expressible in the emitted subset.  SINT layers additionally
# require the activation to be exact in one f32 op (MAX / identity) so the
# bit-exactness contract survives; sigmoid/tanh ride the REAL path only and
# verify to epsilon like the rest of it.
_SINT_ACTS = ("relu", "linear")
_REAL_ACTS = ("relu", "linear", "sigmoid", "tanh")

_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


def format_real(v: float) -> str:
    """An ST REAL literal that parses back to exactly the f32 ``v``: the
    shortest float64 repr of the f32 (exact, since f32 -> f64 is exact and
    the emulator/compiler parses to f64 then rounds to f32)."""
    f = float(np.float32(v))
    if not np.isfinite(f):
        raise STExportError(f"non-finite REAL constant {v!r}")
    s = repr(f)
    if "e" in s or "E" in s:
        mant, _, ex = s.replace("E", "e").partition("e")
        if "." not in mant:
            mant += ".0"
        return f"{mant}E{int(ex):+d}"
    if "." not in s:
        s += ".0"
    return s


@dataclasses.dataclass(frozen=True)
class STContext:
    """What a head's ST epilogue may reference in the surrounding block."""

    y: str                  # model-output REAL array (size n_outputs)
    x: str                  # model-input view: normalized window when
    #                         normalization is baked, else the raw input
    n_outputs: int
    in_width: int           # model input width the body consumed
    window_width: int       # full FB input width (>= in_width for forecast)
    n_features: int


class STWriter:
    """Accumulates declarations + body statements, renders one block.

    Declarations are keyed by (upper-cased) name: ``var`` deduplicates
    (emitter and head share scratch like ``I``/``T``), everything else
    rejects collisions.  Body lines are plain pre-indented statements.
    """

    def __init__(self, name: str):
        if not _NAME_RE.match(name):
            raise STExportError(f"invalid ST identifier {name!r}")
        self.name = name.upper()
        self._sections: Dict[str, List[Tuple[str, str, Optional[int],
                                             Optional[object]]]] = {
            "VAR_INPUT": [], "VAR_OUTPUT": [], "VAR": [], "CONST": []}
        self._names: Dict[str, str] = {}
        self.body: List[str] = []

    def _declare(self, section, name, base, size, init=None):
        name = name.upper()
        if not _NAME_RE.match(name):
            raise STExportError(f"invalid ST identifier {name!r}")
        prior = self._names.get(name)
        if prior is not None:
            if section == "VAR" and prior == ("VAR", base, size):
                return name                       # shared scratch
            raise STExportError(f"duplicate ST declaration {name}")
        self._names[name] = (section, base, size) if section == "VAR" \
            else section
        self._sections[section].append((name, base, size, init))
        return name

    def input(self, name, base, size=None):
        return self._declare("VAR_INPUT", name, base, size)

    def output(self, name, base, size=None):
        return self._declare("VAR_OUTPUT", name, base, size)

    def var(self, name, base, size=None):
        return self._declare("VAR", name, base, size)

    def const(self, name, base, value):
        size = len(value) if isinstance(value, (list, tuple)) else None
        return self._declare("CONST", name, base, size, value)

    def line(self, stmt: str) -> None:
        self.body.append(stmt)

    def comment(self, text: str) -> None:
        self.body.append(f"(* {text} *)")

    @staticmethod
    def real(v: float) -> str:
        return format_real(v)

    # -- rendering ----------------------------------------------------------

    @staticmethod
    def _literal(base: str, v) -> str:
        return format_real(v) if base == "REAL" else str(int(v))

    def _render_decl(self, name, base, size, init) -> List[str]:
        if size is None:
            head = f"    {name} : {base}"
            if init is not None:
                head += f" := {self._literal(base, init)}"
            return [head + ";"]
        head = f"    {name} : ARRAY[0..{size - 1}] OF {base}"
        if init is None:
            return [head + ";"]
        toks = [self._literal(base, v) for v in init]
        lines = [head + " := ["]
        cur = "       "
        for i, t in enumerate(toks):
            piece = t + ("," if i < len(toks) - 1 else "")
            if len(cur) + len(piece) + 1 > 78:
                lines.append(cur)
                cur = "       "
            cur += " " + piece
        lines.append(cur)
        lines.append("    ];")
        return lines

    def render(self) -> str:
        out = [f"FUNCTION_BLOCK {self.name}"]
        for section, keyword in (("VAR_INPUT", "VAR_INPUT"),
                                 ("VAR_OUTPUT", "VAR_OUTPUT"),
                                 ("VAR", "VAR"),
                                 ("CONST", "VAR CONSTANT")):
            decls = self._sections[section]
            if not decls:
                continue
            out.append(keyword)
            for d in decls:
                out.extend(self._render_decl(*d))
            out.append("END_VAR")
        out.append("")
        out.extend(f"    {s}" if s else "" for s in self.body)
        out.append("END_FUNCTION_BLOCK")
        return "\n".join(out) + "\n"


@dataclasses.dataclass(frozen=True)
class STExport:
    """One exported block plus the contract needed to verify/serve it."""

    text: str
    name: str
    scheme: str                       # "REAL" | "SINT"
    head_name: Optional[str]          # None for a bare-body export
    verdict_outputs: Tuple[str, ...]  # head VAR_OUTPUTs ("Y" always exists)
    window: int                       # ring readings per verdict window
    window_width: int                 # FB input width (window * n_features)
    in_width: int                     # model input width
    n_outputs: int
    n_features: int
    threshold: Optional[float]        # f32-snapped baked cutoff (score heads)
    normalize: Optional[Tuple[Tuple[float, ...], Tuple[float, ...]]]


def _stack_scheme(stack) -> str:
    schemes = []
    for i, (p, _) in enumerate(stack):
        if "qw" in p:
            qw = np.asarray(p["qw"])
            if qw.dtype != np.int8:
                raise STExportError(
                    f"layer {i} is {qw.dtype.name}-quantized: the JAX "
                    "oracle emulates INT/DINT accumulation in f32, which "
                    "genuine PLC integer arithmetic would not reproduce — "
                    "export SINT or REAL")
            if "w_scale" not in p or "x_scale" not in p:
                raise STExportError(
                    f"layer {i} quantized params lack w_scale/x_scale")
            schemes.append("SINT")
        elif "w" in p:
            schemes.append("REAL")
        else:
            raise STExportError(f"layer {i} has neither 'w' nor 'qw'")
    if len(set(schemes)) != 1:
        raise STExportError(
            f"mixed-scheme stacks are not exportable (got {schemes}); "
            "quantize every layer or none")
    return schemes[0]


def _emit_activation(w: STWriter, out: str, i: str, act: str,
                     value: str) -> None:
    """``out[i] := act(value)`` where ``value`` is a REAL scratch var."""
    if act == "relu":
        w.line(f"{out}[{i}] := MAX({value}, 0.0);")
    elif act == "linear":
        w.line(f"{out}[{i}] := {value};")
    elif act == "sigmoid":
        # Overflow-stable split: never exponentiates a positive argument.
        w.var("E", "REAL")
        w.line(f"IF {value} >= 0.0 THEN")
        w.line(f"    {out}[{i}] := 1.0 / (1.0 + EXP(-{value}));")
        w.line("ELSE")
        w.line(f"    E := EXP({value});")
        w.line(f"    {out}[{i}] := E / (1.0 + E);")
        w.line("END_IF;")
    elif act == "tanh":
        # tanh(t) = 1 - 2/(exp(2t) + 1), reflected to keep EXP's argument
        # non-positive.
        w.var("E", "REAL")
        w.line(f"IF {value} >= 0.0 THEN")
        w.line(f"    E := EXP(-2.0 * {value});")
        w.line(f"    {out}[{i}] := 1.0 - 2.0 * E / (1.0 + E);")
        w.line("ELSE")
        w.line(f"    E := EXP(2.0 * {value});")
        w.line(f"    {out}[{i}] := 2.0 * E / (1.0 + E) - 1.0;")
        w.line("END_IF;")
    else:  # pragma: no cover - guarded by the scheme/activation check
        raise STExportError(f"activation {act!r} is not exportable")


def export_st(model, params, head=None, *, name: str = "DETECTOR",
              normalize: Optional[Tuple[Sequence[float],
                                        Sequence[float]]] = None,
              n_features: int = 2) -> STExport:
    """Emit one self-contained IEC 61131-3 FUNCTION_BLOCK for a trained
    (optionally §6.1-quantized) all-Dense detector.

    ``head`` contributes the verdict epilogue (``sim.heads`` —
    ``st_epilogue``); ``None`` exports the bare body with only the raw
    model-output array ``Y``.  ``normalize=(mean, std)`` (per-feature) bakes
    the engines' ingest normalization into the block so it consumes raw
    sensor windows.  The emitted text is deterministic: same model, params
    and head -> identical bytes (the golden-file suite pins it).
    """
    if not all(isinstance(n.layer, (Input, Dense))
               for n in model.graph.nodes):
        raise STExportError(
            "only all-Dense chain models are exportable to ST (found a "
            "non-Dense layer in the graph)")
    stack = dense_stack(model, params)
    if not stack:
        raise STExportError("model has no Dense layers")
    scheme = _stack_scheme(stack)
    acts_ok = _SINT_ACTS if scheme == "SINT" else _REAL_ACTS
    for i, (_, act) in enumerate(stack):
        if act not in acts_ok:
            raise STExportError(
                f"layer {i} activation {act!r} is not exportable under "
                f"{scheme} (supported: {acts_ok})")

    weights = [np.asarray(p["qw" if scheme == "SINT" else "w"])
               for p, _ in stack]
    for i, wt in enumerate(weights):
        if wt.ndim != 2:
            raise STExportError(f"layer {i} weight is not 2-D")
    in_width = weights[0].shape[0]
    n_outputs = weights[-1].shape[1]
    for i in range(1, len(weights)):
        if weights[i].shape[0] != weights[i - 1].shape[1]:
            raise STExportError(
                f"layer {i} input width {weights[i].shape[0]} does not "
                f"chain from layer {i - 1} output {weights[i - 1].shape[1]}")

    if head is not None:
        head.validate(in_width, n_outputs)
        window = head.ring_window(in_width, n_features)
    else:
        if in_width % n_features:
            raise STExportError(
                f"model input {in_width} is not a whole number of "
                f"{n_features}-feature readings")
        window = in_width // n_features
    window_width = window * n_features

    w = STWriter(name)
    w.comment(f"auto-generated by repro.codegen.st - scheme {scheme}, "
              f"head {head.name if head is not None else 'none'}")
    w.comment(f"window: {window} readings x {n_features} features "
              f"(oldest first, features interleaved per reading)")
    w.input("X", "REAL", window_width)
    w.output("Y", "REAL", n_outputs)
    w.var("I", "DINT")
    w.var("J", "DINT")
    w.var("T", "REAL")

    # -- ingest normalization (optional, baked) -----------------------------
    if normalize is not None:
        mean, std = normalize
        if len(mean) != n_features or len(std) != n_features:
            raise STExportError(
                f"normalize needs {n_features} per-feature means/stds")
        model_x = w.var("NX", "REAL", window_width)
        w.const("NMEAN", "REAL", [float(np.float32(v)) for v in mean])
        w.const("NSTD", "REAL", [float(np.float32(v)) for v in std])
        w.comment("ingest normalization: (x - mean) / std per feature")
        w.line(f"FOR I := 0 TO {window - 1} DO")
        w.line(f"    FOR J := 0 TO {n_features - 1} DO")
        w.line(f"        NX[I * {n_features} + J] := "
               f"(X[I * {n_features} + J] - NMEAN[J]) / NSTD[J];")
        w.line("    END_FOR;")
        w.line("END_FOR;")
        norm_tuple = (tuple(float(np.float32(v)) for v in mean),
                      tuple(float(np.float32(v)) for v in std))
    else:
        model_x = "X"
        norm_tuple = None

    # -- dense body ---------------------------------------------------------
    if scheme == "SINT":
        w.var("XQ", "SINT", max(wt.shape[0] for wt in weights))
        w.var("ACC", "DINT")
    cur = model_x
    for k, ((p, act), wt) in enumerate(zip(stack, weights)):
        kk, nn = wt.shape
        out = "Y" if k == len(stack) - 1 else w.var(f"A{k + 1}", "REAL", nn)
        wname = w.const(f"W{k}", "SINT" if scheme == "SINT" else "REAL",
                        [v for v in wt.flatten().tolist()])
        b = p.get("b")
        bias = np.zeros(nn, np.float32) if b is None else np.asarray(b)
        bname = w.const(f"B{k}", "REAL",
                        [float(np.float32(v)) for v in bias])
        w.comment(f"layer {k}: {kk} -> {nn}, {act}")
        if scheme == "SINT":
            xs = np.float32(np.asarray(p["x_scale"]))
            combined = (xs * np.asarray(p["w_scale"], np.float32)
                        ).astype(np.float32)
            combined = np.broadcast_to(combined, (nn,))
            sname = w.const(f"S{k}", "REAL",
                            [float(v) for v in combined.tolist()])
            qname = w.const(f"Q{k}", "REAL", float(xs))
            w.line(f"FOR J := 0 TO {kk - 1} DO")
            w.line(f"    T := {cur}[J] / {qname};")
            w.line("    IF T >= 127.0 THEN")
            w.line("        XQ[J] := 127;")
            w.line("    ELSIF T <= -127.0 THEN")
            w.line("        XQ[J] := -127;")
            w.line("    ELSE")
            w.line("        XQ[J] := REAL_TO_SINT(T);")
            w.line("    END_IF;")
            w.line("END_FOR;")
            w.line(f"FOR I := 0 TO {nn - 1} DO")
            w.line("    ACC := 0;")
            w.line(f"    FOR J := 0 TO {kk - 1} DO")
            w.line(f"        ACC := ACC + SINT_TO_DINT(XQ[J]) * "
                   f"SINT_TO_DINT({wname}[J * {nn} + I]);")
            w.line("    END_FOR;")
            w.line(f"    T := DINT_TO_REAL(ACC) * {sname}[I] + {bname}[I];")
            _emit_activation(w, out, "I", act, "T")
            w.line("END_FOR;")
        else:
            w.line(f"FOR I := 0 TO {nn - 1} DO")
            w.line("    T := 0.0;")
            w.line(f"    FOR J := 0 TO {kk - 1} DO")
            w.line(f"        T := T + {cur}[J] * {wname}[J * {nn} + I];")
            w.line("    END_FOR;")
            w.line(f"    T := T + {bname}[I];")
            _emit_activation(w, out, "I", act, "T")
            w.line("END_FOR;")
        cur = out

    # -- verdict epilogue ---------------------------------------------------
    threshold = None
    verdict_outputs: Tuple[str, ...] = ()
    head_name = None
    if head is not None:
        ctx = STContext(y="Y", x=model_x, n_outputs=n_outputs,
                        in_width=in_width, window_width=window_width,
                        n_features=n_features)
        head.st_epilogue(w, ctx)
        verdict_outputs = tuple(head.st_verdict_outputs())
        head_name = head.name
        thr = getattr(head, "threshold", None)
        if thr is not None:
            threshold = float(np.float32(thr))

    return STExport(
        text=w.render(), name=w.name, scheme=scheme, head_name=head_name,
        verdict_outputs=verdict_outputs, window=window,
        window_width=window_width, in_width=in_width, n_outputs=n_outputs,
        n_features=n_features, threshold=threshold, normalize=norm_tuple)
