"""Jamba-style hybrid: Mamba + attention 1:7 interleave with MoE
[arXiv:2403.19887].

The 72 layers are 9 homogeneous *super-blocks* of ``attn_period`` (8)
sublayers — attention at position 3, Mamba elsewhere; the FFN after each
mixer alternates dense MLP (even positions) / MoE 16e top-2 (odd positions).
The outer ``lax.scan`` runs over super-blocks (homogeneous params), the inner
8 sublayers are unrolled — HLO stays compact while matching the published
interleave.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models import moe as moelib
from repro.models.transformer import _attn_cfg, _mlp_cfg, stacked_specs

Params = Dict[str, Any]


def _layout(cfg: ArchConfig):
    period = cfg.attn_period
    attn_pos = period // 2 - 1          # position 3 of 8 (jamba layout)
    n_super = cfg.n_layers // period
    n_mamba = period - 1
    n_moe = period // 2                 # odd positions
    n_mlp = period - n_moe
    return period, attn_pos, n_super, n_mamba, n_moe, n_mlp


def _take(tree: Params, i: int) -> Params:
    return jax.tree.map(lambda a: a[i], tree)


def super_block_spec(cfg: ArchConfig) -> Params:
    period, attn_pos, n_super, n_mamba, n_moe, n_mlp = _layout(cfg)
    return {
        "mamba": stacked_specs(
            {"ln": cm.rmsnorm_spec(cfg.d_model), "mixer": mb.mamba_spec(cfg)},
            n_mamba),
        "attn": {"ln": cm.rmsnorm_spec(cfg.d_model),
                 "attn": cm.attn_spec(_attn_cfg(cfg), cfg.quant, cfg.dtype)},
        "mlp": stacked_specs(
            {"ln": cm.rmsnorm_spec(cfg.d_model),
             "mlp": cm.mlp_spec(_mlp_cfg(cfg), cfg.quant, cfg.dtype)},
            n_mlp),
        "moe": stacked_specs(
            {"ln": cm.rmsnorm_spec(cfg.d_model), "moe": moelib.moe_spec(cfg)},
            n_moe),
    }


def super_block_init(key: jax.Array, cfg: ArchConfig) -> Params:
    period, attn_pos, n_super, n_mamba, n_moe, n_mlp = _layout(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def mamba_one(k):
        return {"ln": cm.rmsnorm_init(cfg.d_model), "mixer": mb.mamba_init(k, cfg)}

    def mlp_one(k):
        return {"ln": cm.rmsnorm_init(cfg.d_model),
                "mlp": cm.mlp_init(k, _mlp_cfg(cfg), cfg.quant, cfg.dtype)}

    def moe_one(k):
        return {"ln": cm.rmsnorm_init(cfg.d_model), "moe": moelib.moe_init(k, cfg)}

    return {
        "mamba": jax.vmap(mamba_one)(jax.random.split(k1, n_mamba)),
        "attn": {"ln": cm.rmsnorm_init(cfg.d_model),
                 "attn": cm.attn_init(k2, _attn_cfg(cfg), cfg.quant, cfg.dtype)},
        "mlp": jax.vmap(mlp_one)(jax.random.split(k3, n_mlp)),
        "moe": jax.vmap(moe_one)(jax.random.split(k4, n_moe)),
    }


def model_spec(cfg: ArchConfig) -> Params:
    _, _, n_super, *_ = _layout(cfg)
    return {
        "embed": cm.embed_spec(cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": stacked_specs(super_block_spec(cfg), n_super),
        "final_norm": cm.rmsnorm_spec(cfg.d_model),
    }


def model_init(key: jax.Array, cfg: ArchConfig) -> Params:
    _, _, n_super, *_ = _layout(cfg)
    k_emb, k_blocks = jax.random.split(key)
    return {
        "embed": cm.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": jax.vmap(lambda k: super_block_init(k, cfg))(
            jax.random.split(k_blocks, n_super)),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
    }


def _ffn(sb: Params, cfg: ArchConfig, i: int, h: jax.Array) -> jax.Array:
    if i % 2 == 1:
        p = _take(sb["moe"], i // 2)
        return moelib.moe_forward(p["moe"], cfg, cm.rmsnorm(p["ln"], h))
    p = _take(sb["mlp"], i // 2)
    return cm.mlp_forward(p["mlp"], _mlp_cfg(cfg), cm.rmsnorm(p["ln"], h))


def super_block_forward(sb: Params, cfg: ArchConfig, x: jax.Array,
                        positions: jax.Array) -> jax.Array:
    period, attn_pos, *_ = _layout(cfg)
    mamba_j = 0
    for i in range(period):
        if i == attn_pos:
            h = cm.rmsnorm(sb["attn"]["ln"], x)
            x = x + cm.attn_forward(sb["attn"]["attn"], _attn_cfg(cfg), h, positions)
        else:
            p = _take(sb["mamba"], mamba_j)
            x = x + mb.mamba_forward(p["mixer"], cfg, cm.rmsnorm(p["ln"], x))
            mamba_j += 1
        x = x + _ffn(sb, cfg, i, x)
        x = cm.constrain(x, "btd")
    return x


def forward_logits(params: Params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, sb):
        return super_block_forward(sb, cfg, h, positions), None

    body_fn = jax.checkpoint(body) if cfg.remat == "layer" else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"], unroll=cfg.scan_unroll)
    return cm.unembed(params["embed"], cm.rmsnorm(params["final_norm"], x))


def loss_fn(params, cfg, batch):
    return cm.cross_entropy(forward_logits(params, cfg, batch["tokens"]),
                            batch["labels"])


# -- serving ---------------------------------------------------------------


def cache_spec(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    period, attn_pos, n_super, n_mamba, *_ = _layout(cfg)
    kv = (n_super, batch, cache_len, cfg.n_kv_heads, cfg.d_head)
    mamba_one = mb.mamba_cache_spec(cfg, batch)
    return {
        "k": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "v": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "mamba": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_super, n_mamba) + s.shape, s.dtype),
            mamba_one),
    }


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len))


def super_block_decode(sb: Params, cfg: ArchConfig, x: jax.Array,
                       pos: jax.Array, kv, mamba_cache, *, multi: bool = False
                       ) -> Tuple[jax.Array, Any, Any]:
    period, attn_pos, *_ = _layout(cfg)
    attn_step = cm.attn_decode_multi if multi else cm.attn_decode
    mamba_j = 0
    new_conv, new_ssm = [], []
    for i in range(period):
        if i == attn_pos:
            h = cm.rmsnorm(sb["attn"]["ln"], x)
            a, kv = attn_step(sb["attn"]["attn"], _attn_cfg(cfg), h, pos, kv)
            x = x + a
        else:
            p = _take(sb["mamba"], mamba_j)
            c = jax.tree.map(lambda a: a[mamba_j], mamba_cache)
            out, c2 = mb.mamba_decode(p["mixer"], cfg, cm.rmsnorm(p["ln"], x), c)
            new_conv.append(c2["conv"])
            new_ssm.append(c2["ssm"])
            x = x + out
            mamba_j += 1
        x = x + _ffn(sb, cfg, i, x)
    new_mamba = {"conv": jnp.stack(new_conv), "ssm": jnp.stack(new_ssm)}
    return x, kv, new_mamba


def _decode_step_impl(params, cfg, cache, tokens, pos, multi):
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)

    def body(h, inputs):
        sb, kc, vc, mc = inputs
        h, (kc, vc), mc = super_block_decode(sb, cfg, h, pos, (kc, vc), mc,
                                             multi=multi)
        return h, (kc, vc, mc)

    x, (k, v, mamba) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], cache["mamba"]),
        unroll=cfg.scan_unroll,
    )
    x = cm.rmsnorm(params["final_norm"], x)
    return {"k": k, "v": v, "mamba": mamba}, cm.unembed(params["embed"], x)


def decode_step(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[Dict[str, Any], jax.Array]:
    return _decode_step_impl(params, cfg, cache, tokens, pos, multi=False)


def decode_step_multi(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                      tokens: jax.Array, pos: jax.Array
                      ) -> Tuple[Dict[str, Any], jax.Array]:
    """Per-slot-position decode (pos (B,)): attention layers write/mask per
    row; the mamba layers are position-free recurrent state."""
    return _decode_step_impl(params, cfg, cache, tokens, pos, multi=True)


def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array, cache_len: int
            ) -> Tuple[Dict[str, Any], jax.Array]:
    """Prefill: full forward collecting attention KV + final mamba states."""
    period, attn_pos, *_ = _layout(cfg)
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, sb):
        mamba_j = 0
        convs, ssms = [], []
        kv = None
        for i in range(period):
            if i == attn_pos:
                hn = cm.rmsnorm(sb["attn"]["ln"], h)
                a, kv = cm.attn_prefill(sb["attn"]["attn"], _attn_cfg(cfg),
                                        hn, positions, cache_len)
                h = h + a
            else:
                p = _take(sb["mamba"], mamba_j)
                out, st = mb._mamba_forward_state(p["mixer"], cfg,
                                                  cm.rmsnorm(p["ln"], h))
                convs.append(st["conv"].astype(cfg.dtype))
                ssms.append(st["ssm"])
                h = h + out
                mamba_j += 1
            h = h + _ffn(sb, cfg, i, h)
        mamba = {"conv": jnp.stack(convs), "ssm": jnp.stack(ssms)}
        return h, (kv[0], kv[1], mamba)

    x, (k, v, mamba) = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    x = cm.rmsnorm(params["final_norm"], x)
    logits = cm.unembed(params["embed"], x[:, -1:])
    return {"k": k, "v": v, "mamba": mamba}, logits
