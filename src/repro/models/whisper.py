"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, frames, d_model).
We implement the transformer backbone: 6 bidirectional encoder layers over
the frames and 6 causal decoder layers with cross-attention.

Divergences (recorded in DESIGN.md): positions are sinusoidal for both
stacks (whisper's decoder uses learned embeddings capped at 448 positions —
meaningless at the assigned 32k/500k decode shapes); norms follow the repo's
RMSNorm-with-bias-free convention, with biased linears per whisper.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models.transformer import _attn_cfg, _mlp_cfg, stacked_specs

Params = Dict[str, Any]


def sinusoids(positions: jax.Array, d: int) -> jax.Array:
    """Whisper's sinusoidal position encoding, computed on the fly."""
    half = d // 2
    log_timescale = np.log(10000.0) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- cross attention ---------------------------------------------------------


def cross_attn_spec(cfg: ArchConfig) -> Params:
    d, dh = cfg.d_model, cfg.d_head
    q = cfg.quant
    return {
        "wq": cm.linear_spec(d, cfg.n_heads * dh, bias=cfg.bias, quant=q, dtype=cfg.dtype),
        "wk": cm.linear_spec(d, cfg.n_kv_heads * dh, bias=False, quant=q, dtype=cfg.dtype),
        "wv": cm.linear_spec(d, cfg.n_kv_heads * dh, bias=cfg.bias, quant=q, dtype=cfg.dtype),
        "wo": cm.linear_spec(cfg.n_heads * dh, d, bias=cfg.bias, quant=q, dtype=cfg.dtype),
    }


def cross_attn_init(key: jax.Array, cfg: ArchConfig) -> Params:
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 4)
    q = cfg.quant
    return {
        "wq": cm.linear_init(ks[0], d, cfg.n_heads * dh, bias=cfg.bias, quant=q, dtype=cfg.dtype),
        "wk": cm.linear_init(ks[1], d, cfg.n_kv_heads * dh, bias=False, quant=q, dtype=cfg.dtype),
        "wv": cm.linear_init(ks[2], d, cfg.n_kv_heads * dh, bias=cfg.bias, quant=q, dtype=cfg.dtype),
        "wo": cm.linear_init(ks[3], cfg.n_heads * dh, d, bias=cfg.bias, quant=q, dtype=cfg.dtype),
    }


def cross_kv(p: Params, cfg: ArchConfig, enc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    b, f, _ = enc.shape
    k = cm.linear(p["wk"], enc).reshape(b, f, cfg.n_kv_heads, cfg.d_head)
    v = cm.linear(p["wv"], enc).reshape(b, f, cfg.n_kv_heads, cfg.d_head)
    return k, v


def cross_attn_apply(p: Params, cfg: ArchConfig, x: jax.Array,
                     k: jax.Array, v: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    q = cm.linear(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    mask = jnp.ones((s, k.shape[1]), bool)
    out = cm.gqa_attention(q, k, v, mask)
    return cm.linear(p["wo"], out.reshape(b, s, -1))


# -- encoder ------------------------------------------------------------------


def enc_block_spec(cfg: ArchConfig) -> Params:
    return {
        "ln1": cm.rmsnorm_spec(cfg.d_model),
        "attn": cm.attn_spec(_attn_cfg(cfg), cfg.quant, cfg.dtype),
        "ln2": cm.rmsnorm_spec(cfg.d_model),
        "mlp": cm.mlp_spec(_mlp_cfg(cfg), cfg.quant, cfg.dtype),
    }


def enc_block_init(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": cm.rmsnorm_init(cfg.d_model),
        "attn": cm.attn_init(k1, _attn_cfg(cfg), cfg.quant, cfg.dtype),
        "ln2": cm.rmsnorm_init(cfg.d_model),
        "mlp": cm.mlp_init(k2, _mlp_cfg(cfg), cfg.quant, cfg.dtype),
    }


def encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) stub embeddings -> encoder output."""
    f = frames.shape[1]
    x = frames.astype(cfg.dtype) + sinusoids(
        jnp.arange(f, dtype=jnp.int32), cfg.d_model
    ).astype(cfg.dtype)
    positions = jnp.arange(f, dtype=jnp.int32)

    def body(h, blk):
        hn = cm.rmsnorm(blk["ln1"], h)
        # bidirectional: no causal mask
        acfg = _attn_cfg(cfg)
        q, k, v = cm.attn_qkv(blk["attn"], acfg, hn, positions)
        mask = jnp.ones((f, f), bool)
        a = cm.linear(blk["attn"]["wo"],
                      cm.gqa_attention(q, k, v, mask).reshape(h.shape[0], f, -1))
        h = h + a
        h = h + cm.mlp_forward(blk["mlp"], _mlp_cfg(cfg), cm.rmsnorm(blk["ln2"], h))
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=cfg.scan_unroll)
    return cm.rmsnorm(params["enc_norm"], x)


# -- decoder ------------------------------------------------------------------


def dec_block_spec(cfg: ArchConfig) -> Params:
    return {
        "ln1": cm.rmsnorm_spec(cfg.d_model),
        "self_attn": cm.attn_spec(_attn_cfg(cfg), cfg.quant, cfg.dtype),
        "ln_x": cm.rmsnorm_spec(cfg.d_model),
        "cross": cross_attn_spec(cfg),
        "ln2": cm.rmsnorm_spec(cfg.d_model),
        "mlp": cm.mlp_spec(_mlp_cfg(cfg), cfg.quant, cfg.dtype),
    }


def dec_block_init(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": cm.rmsnorm_init(cfg.d_model),
        "self_attn": cm.attn_init(k1, _attn_cfg(cfg), cfg.quant, cfg.dtype),
        "ln_x": cm.rmsnorm_init(cfg.d_model),
        "cross": cross_attn_init(k2, cfg),
        "ln2": cm.rmsnorm_init(cfg.d_model),
        "mlp": cm.mlp_init(k3, _mlp_cfg(cfg), cfg.quant, cfg.dtype),
    }


def model_spec(cfg: ArchConfig) -> Params:
    return {
        "embed": cm.embed_spec(cfg.vocab, cfg.d_model, cfg.dtype),
        "enc_blocks": stacked_specs(enc_block_spec(cfg), cfg.n_layers),
        "enc_norm": cm.rmsnorm_spec(cfg.d_model),
        "dec_blocks": stacked_specs(dec_block_spec(cfg), cfg.n_layers),
        "final_norm": cm.rmsnorm_spec(cfg.d_model),
    }


def model_init(key: jax.Array, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "embed": cm.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg))(
            jax.random.split(ks[1], cfg.n_layers)),
        "enc_norm": cm.rmsnorm_init(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg))(
            jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
    }


def _dec_block(blk, cfg, x, positions, kcross, vcross):
    acfg = _attn_cfg(cfg)
    h = cm.rmsnorm(blk["ln1"], x)
    x = x + cm.attn_forward(blk["self_attn"], acfg, h, positions)
    x = x + cross_attn_apply(blk["cross"], cfg, cm.rmsnorm(blk["ln_x"], x),
                             kcross, vcross)
    x = x + cm.mlp_forward(blk["mlp"], _mlp_cfg(cfg), cm.rmsnorm(blk["ln2"], x))
    return x


def forward_logits(params: Params, cfg: ArchConfig, frames: jax.Array,
                   tokens: jax.Array) -> jax.Array:
    enc = encode(params, cfg, frames)
    s = tokens.shape[1]
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    x = x + sinusoids(jnp.arange(s, dtype=jnp.int32), cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(h, blk):
        kc, vc = cross_kv(blk["cross"], cfg, enc)
        return _dec_block(blk, cfg, h, positions, kc, vc), None

    body_fn = jax.checkpoint(body) if cfg.remat == "layer" else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"], unroll=cfg.scan_unroll)
    return cm.unembed(params["embed"], cm.rmsnorm(params["final_norm"], x))


def loss_fn(params, cfg, batch):
    logits = forward_logits(params, cfg, batch["frames"], batch["tokens"])
    return cm.cross_entropy(logits, batch["labels"])


# -- serving -----------------------------------------------------------------


def cache_spec(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    kv = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head)
    xkv = (cfg.n_layers, batch, cfg.encoder_frames, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "v": jax.ShapeDtypeStruct(kv, cfg.dtype),
        "xk": jax.ShapeDtypeStruct(xkv, cfg.dtype),
        "xv": jax.ShapeDtypeStruct(xkv, cfg.dtype),
    }


def init_cache(cfg, batch, cache_len):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len))


def prefill(params: Params, cfg: ArchConfig, frames: jax.Array,
            tokens: jax.Array, cache_len: int) -> Tuple[Dict[str, Any], jax.Array]:
    enc = encode(params, cfg, frames)
    s = tokens.shape[1]
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    x = x + sinusoids(jnp.arange(s, dtype=jnp.int32), cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)
    acfg = _attn_cfg(cfg)

    def body(h, blk):
        kc, vc = cross_kv(blk["cross"], cfg, enc)
        hn = cm.rmsnorm(blk["ln1"], h)
        a, kv = cm.attn_prefill(blk["self_attn"], acfg, hn, positions, cache_len)
        h = h + a
        h = h + cross_attn_apply(blk["cross"], cfg, cm.rmsnorm(blk["ln_x"], h), kc, vc)
        h = h + cm.mlp_forward(blk["mlp"], _mlp_cfg(cfg), cm.rmsnorm(blk["ln2"], h))
        return h, (kv[0], kv[1], kc, vc)

    x, (k, v, xk, xv) = jax.lax.scan(body, x, params["dec_blocks"], unroll=cfg.scan_unroll)
    x = cm.rmsnorm(params["final_norm"], x)
    return ({"k": k, "v": v, "xk": xk, "xv": xv},
            cm.unembed(params["embed"], x[:, -1:]))


def _decode_step_impl(params, cfg, cache, tokens, pos, multi):
    acfg = _attn_cfg(cfg)
    attn_step = cm.attn_decode_multi if multi else cm.attn_decode
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    if multi:
        x = x + sinusoids(pos, cfg.d_model).astype(cfg.dtype)[:, None, :]
    else:
        x = x + sinusoids(pos[None] if pos.ndim == 0 else pos,
                          cfg.d_model).astype(cfg.dtype)

    def body(h, inputs):
        blk, kc, vc, xk, xv = inputs
        hn = cm.rmsnorm(blk["ln1"], h)
        a, (kc, vc) = attn_step(blk["self_attn"], acfg, hn, pos, (kc, vc))
        h = h + a
        h = h + cross_attn_apply(blk["cross"], cfg, cm.rmsnorm(blk["ln_x"], h), xk, xv)
        h = h + cm.mlp_forward(blk["mlp"], _mlp_cfg(cfg), cm.rmsnorm(blk["ln2"], h))
        return h, (kc, vc)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]),
        unroll=cfg.scan_unroll,
    )
    x = cm.rmsnorm(params["final_norm"], x)
    return ({"k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"]},
            cm.unembed(params["embed"], x))


def decode_step(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[Dict[str, Any], jax.Array]:
    return _decode_step_impl(params, cfg, cache, tokens, pos, multi=False)


def decode_step_multi(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                      tokens: jax.Array, pos: jax.Array
                      ) -> Tuple[Dict[str, Any], jax.Array]:
    """Per-slot-position decode (pos (B,)): self-attention writes/masks per
    row; cross-attention reads the per-slot encoder KV, position-free."""
    return _decode_step_impl(params, cfg, cache, tokens, pos, multi=True)
