"""Shared transformer primitives for the assigned architectures.

Everything here is pure JAX (pjit-compatible; distribution is applied by
`repro.launch.shardings` via NamedSharding on the inputs/params and
`with_sharding_constraint` on activations).  Conventions:

* params are dicts of arrays; per-layer params are **stacked** on a leading
  layer axis and consumed with ``jax.lax.scan`` so the HLO stays compact for
  the 512-device dry-runs (96-layer models compile as one block).
* activations compute in ``cfg.dtype`` (bf16), reductions/softmax in f32.
* KV caches are statically preallocated at the serving shape and threaded
  functionally — the ICSML static-memory discipline (DESIGN.md §2).
* all linear layers route through :func:`linear`, which dispatches to the
  paper's int8 quantized path (``repro.kernels``) when the params carry
  quantized weights — this is how §6.1 quantization becomes a first-class
  serving feature for every architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Activation-sharding hook.  `repro.launch.shardings` installs a function
# mapping (array, logical_name) -> with_sharding_constraint(array, ...);
# outside a mesh context this is the identity.  Models stay mesh-agnostic.
# ---------------------------------------------------------------------------

_CONSTRAIN_HOOK = None


def set_constrain_hook(fn) -> None:
    global _CONSTRAIN_HOOK
    _CONSTRAIN_HOOK = fn


def constrain(x: jax.Array, name: str) -> jax.Array:
    if _CONSTRAIN_HOOK is None:
        return x
    return _CONSTRAIN_HOOK(x, name)


# ---------------------------------------------------------------------------
# Linear / norm / embedding
# ---------------------------------------------------------------------------


def linear_spec(d_in: int, d_out: int, *, bias: bool, quant: Optional[str],
                dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct tree for one linear layer (dry-run, no allocation)."""
    if quant is None:
        p = {"w": jax.ShapeDtypeStruct((d_in, d_out), dtype)}
    else:
        from repro.core.layers import IEC_INT_TYPES
        p = {
            "qw": jax.ShapeDtypeStruct((d_in, d_out), jnp.dtype(IEC_INT_TYPES[quant])),
            "w_scale": jax.ShapeDtypeStruct((d_out,), jnp.float32),
            "x_scale": jax.ShapeDtypeStruct((), jnp.float32),
        }
    if bias:
        p["b"] = jax.ShapeDtypeStruct((d_out,), jnp.float32)
    return p


def linear_init(key: jax.Array, d_in: int, d_out: int, *, bias: bool,
                quant: Optional[str], dtype=jnp.bfloat16, scale: float = 1.0) -> Params:
    std = scale / np.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)
    if quant is None:
        p = {"w": w}
    else:
        from repro.core.quantize import quantize_tensor
        qt = quantize_tensor(w.astype(jnp.float32), quant)
        p = {"qw": qt.q, "w_scale": qt.scale,
             "x_scale": jnp.asarray(1.0 / 127.0, jnp.float32)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    """Apply a (possibly int-quantized) linear layer to (..., d_in)."""
    if "qw" in p:
        qw = p["qw"]
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        # Symmetric clip, matching quantize.quantize_tensor's weight range
        # (the extra negative code would decode outside [-absmax, absmax]).
        qmax = jnp.iinfo(qw.dtype).max
        xq = jnp.clip(jnp.round(x2 / p["x_scale"]), -qmax, qmax)
        scale = p["x_scale"] * p["w_scale"]
        if qw.dtype == jnp.int8:
            # SINT: native int8 dot with int32 accumulation (qmatmul path).
            y = kops.quantized_matmul(xq.astype(qw.dtype), qw, scale,
                                      p.get("b"))
        else:
            # INT/DINT: int16/int32 products overflow int32 accumulation,
            # and int32's qmax is not f32-representable (the int round-trip
            # would overflow at the clip rail) — emulate in f32, exactly
            # like layers._quantized_matvec / streams._dense_batched.
            y = xq @ qw.astype(jnp.float32) * scale
            if p.get("b") is not None:
                y = y + p["b"]
        return y.reshape(*lead, qw.shape[-1]).astype(x.dtype)
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm_spec(d: int) -> Params:
    return {"g": jax.ShapeDtypeStruct((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * p["g"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (B, S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Grouped-query attention (full / causal / sliding-window; qk-norm option)
# ---------------------------------------------------------------------------


def gqa_scores_mask(
    q_pos: jax.Array,        # (Sq,) query positions
    k_pos: jax.Array,        # (Sk,) key positions
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """Boolean (Sq, Sk) attention mask."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return ok


def gqa_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, K, D)
    v: jax.Array,            # (B, Sk, K, D)
    mask: jax.Array,         # (Sq, Sk) bool, or (B, Sq, Sk) per-row
) -> jax.Array:
    """Grouped-query attention; softmax in f32. Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, sq, kheads, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(d)
    if mask.ndim == 3:
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    else:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, d)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    qk_norm: bool = False
    bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding window (tokens), None = full
    d_head: Optional[int] = None     # defaults to d_model // n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)


def attn_spec(a: AttnConfig, quant: Optional[str], dtype=jnp.bfloat16) -> Params:
    d_head = a.head_dim
    p = {
        "wq": linear_spec(a.d_model, a.n_heads * d_head, bias=a.bias, quant=quant, dtype=dtype),
        "wk": linear_spec(a.d_model, a.n_kv_heads * d_head, bias=a.bias, quant=quant, dtype=dtype),
        "wv": linear_spec(a.d_model, a.n_kv_heads * d_head, bias=a.bias, quant=quant, dtype=dtype),
        "wo": linear_spec(a.n_heads * d_head, a.d_model, bias=a.bias, quant=quant, dtype=dtype),
    }
    if a.qk_norm:
        p["q_norm"] = rmsnorm_spec(d_head)
        p["k_norm"] = rmsnorm_spec(d_head)
    return p


def attn_init(key: jax.Array, a: AttnConfig, quant: Optional[str],
              dtype=jnp.bfloat16) -> Params:
    d_head = a.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], a.d_model, a.n_heads * d_head, bias=a.bias, quant=quant, dtype=dtype),
        "wk": linear_init(ks[1], a.d_model, a.n_kv_heads * d_head, bias=a.bias, quant=quant, dtype=dtype),
        "wv": linear_init(ks[2], a.d_model, a.n_kv_heads * d_head, bias=a.bias, quant=quant, dtype=dtype),
        "wo": linear_init(ks[3], a.n_heads * d_head, a.d_model, bias=a.bias, quant=quant, dtype=dtype),
    }
    if a.qk_norm:
        p["q_norm"] = rmsnorm_init(d_head)
        p["k_norm"] = rmsnorm_init(d_head)
    return p


def attn_qkv(p: Params, a: AttnConfig, x: jax.Array, positions: jax.Array
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    d_head = a.head_dim
    q = linear(p["wq"], x).reshape(b, s, a.n_heads, d_head)
    k = linear(p["wk"], x).reshape(b, s, a.n_kv_heads, d_head)
    v = linear(p["wv"], x).reshape(b, s, a.n_kv_heads, d_head)
    if a.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


def attn_forward(
    p: Params, a: AttnConfig, x: jax.Array, positions: jax.Array,
    *, window_override: Optional[int] = None,
) -> jax.Array:
    """Full-sequence (train/prefill) attention."""
    window = window_override if window_override is not None else a.window
    q, k, v = attn_qkv(p, a, x, positions)
    mask = gqa_scores_mask(positions, positions, causal=True, window=window)
    out = gqa_attention(q, k, v, mask)
    return linear(p["wo"], out.reshape(*x.shape[:2], -1))


def attn_prefill(
    p: Params, a: AttnConfig, x: jax.Array, positions: jax.Array,
    cache_len: int, *, window_override: Optional[int] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Prefill: returns output and (k_cache, v_cache) padded to cache_len."""
    window = window_override if window_override is not None else a.window
    q, k, v = attn_qkv(p, a, x, positions)
    mask = gqa_scores_mask(positions, positions, causal=True, window=window)
    out = gqa_attention(q, k, v, mask)
    s = x.shape[1]
    pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
    return (
        linear(p["wo"], out.reshape(*x.shape[:2], -1)),
        (jnp.pad(k, pad), jnp.pad(v, pad)),
    )


def attn_decode(
    p: Params, a: AttnConfig, x: jax.Array, pos: jax.Array,
    cache: Tuple[jax.Array, ...],
    *, window_override: Optional[int] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """One-token decode against a static cache.

    x: (B, 1, d_model); pos: () current position; cache either
    ``(k, v)`` with k/v (B, Smax, K, D) in compute dtype, or the int8
    variant ``(k_q, v_q, k_scale, v_scale)`` with per-(token, head) REAL
    scales — §6.1 quantization applied to serving state (kv_quant).
    The cache is updated functionally (donated by the caller's jit).
    """
    window = window_override if window_override is not None else a.window
    b = x.shape[0]
    q, k, v = attn_qkv(p, a, x, jnp.full((1,), pos, jnp.int32))
    quantized = len(cache) == 4

    if quantized:
        k_cache, v_cache, ks_cache, vs_cache = cache
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(k_cache, kq, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vq, (0, pos, 0, 0))
        ks_cache = jax.lax.dynamic_update_slice(ks_cache, ks, (0, pos, 0))
        vs_cache = jax.lax.dynamic_update_slice(vs_cache, vs, (0, pos, 0))
        k_full = k_cache.astype(q.dtype) * ks_cache[..., None].astype(q.dtype)
        v_full = v_cache.astype(q.dtype) * vs_cache[..., None].astype(q.dtype)
        new_cache: Tuple[jax.Array, ...] = (k_cache, v_cache, ks_cache, vs_cache)
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
        k_full, v_full = k_cache, v_cache
        new_cache = (k_cache, v_cache)

    s_max = k_full.shape[1]
    k_pos = jnp.arange(s_max, dtype=jnp.int32)
    mask = (k_pos <= pos)
    if window is not None:
        mask &= k_pos > pos - window
    mask2d = mask[None, :]  # (1, Smax)
    out = gqa_attention(q, k_full, v_full, mask2d)
    return linear(p["wo"], out.reshape(b, 1, -1)), new_cache


def attn_decode_multi(
    p: Params, a: AttnConfig, x: jax.Array, pos: jax.Array,
    cache: Tuple[jax.Array, ...],
    *, window_override: Optional[int] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """One-token decode with **per-row** positions (continuous batching).

    x: (B, 1, d_model); pos: (B,) — each batch slot sits at its own position
    in the shared cache, so slots admitted at different times decode in one
    fixed-shape step.  Cache layouts as in :func:`attn_decode`; each row's
    new K/V lands at its own ``pos[b]`` and each row gets its own causal
    (and optional sliding-window) mask.
    """
    window = window_override if window_override is not None else a.window
    b = x.shape[0]
    q, k, v = attn_qkv(p, a, x, pos[:, None])
    quantized = len(cache) == 4

    def upd_kv(full, new):      # full (B, Smax, K, D), new (B, 1, K, D)
        return jax.vmap(
            lambda c, n, pp: jax.lax.dynamic_update_slice(c, n, (pp, 0, 0))
        )(full, new, pos)

    def upd_scale(full, new):   # full (B, Smax, K), new (B, 1, K)
        return jax.vmap(
            lambda c, n, pp: jax.lax.dynamic_update_slice(c, n, (pp, 0))
        )(full, new, pos)

    if quantized:
        k_cache, v_cache, ks_cache, vs_cache = cache
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_cache = upd_kv(k_cache, kq)
        v_cache = upd_kv(v_cache, vq)
        ks_cache = upd_scale(ks_cache, ks)
        vs_cache = upd_scale(vs_cache, vs)
        k_full = k_cache.astype(q.dtype) * ks_cache[..., None].astype(q.dtype)
        v_full = v_cache.astype(q.dtype) * vs_cache[..., None].astype(q.dtype)
        new_cache: Tuple[jax.Array, ...] = (k_cache, v_cache, ks_cache, vs_cache)
    else:
        k_cache, v_cache = cache
        k_cache = upd_kv(k_cache, k)
        v_cache = upd_kv(v_cache, v)
        k_full, v_full = k_cache, v_cache
        new_cache = (k_cache, v_cache)

    s_max = k_full.shape[1]
    k_pos = jnp.arange(s_max, dtype=jnp.int32)
    mask = k_pos[None, :] <= pos[:, None]              # (B, Smax)
    if window is not None:
        mask &= k_pos[None, :] > pos[:, None] - window
    out = gqa_attention(q, k_full, v_full, mask[:, None, :])
    return linear(p["wo"], out.reshape(b, 1, -1)), new_cache


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 per-(token, head) quantization of K/V (B, S, K, D)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-6) / 127.0               # (B, S, K)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -128, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    kind: str = "swiglu"      # 'swiglu' | 'gelu' | 'squared_relu'
    bias: bool = False


def mlp_spec(m: MlpConfig, quant: Optional[str], dtype=jnp.bfloat16) -> Params:
    p = {}
    if m.kind == "swiglu":
        p["w_gate"] = linear_spec(m.d_model, m.d_ff, bias=m.bias, quant=quant, dtype=dtype)
    p["w_up"] = linear_spec(m.d_model, m.d_ff, bias=m.bias, quant=quant, dtype=dtype)
    p["w_down"] = linear_spec(m.d_ff, m.d_model, bias=m.bias, quant=quant, dtype=dtype)
    return p


def mlp_init(key: jax.Array, m: MlpConfig, quant: Optional[str],
             dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    p = {}
    if m.kind == "swiglu":
        p["w_gate"] = linear_init(ks[2], m.d_model, m.d_ff, bias=m.bias, quant=quant, dtype=dtype)
    p["w_up"] = linear_init(ks[0], m.d_model, m.d_ff, bias=m.bias, quant=quant, dtype=dtype)
    p["w_down"] = linear_init(ks[1], m.d_ff, m.d_model, bias=m.bias, quant=quant, dtype=dtype)
    return p


def mlp_forward(p: Params, m: MlpConfig, x: jax.Array) -> jax.Array:
    if m.kind == "swiglu":
        h = jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x)
    elif m.kind == "gelu":
        h = jax.nn.gelu(linear(p["w_up"], x))
    elif m.kind == "squared_relu":   # nemotron-4 [arXiv:2402.16819]
        h = jnp.square(jax.nn.relu(linear(p["w_up"], x)))
    else:
        raise ValueError(m.kind)
    return linear(p["w_down"], h)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"emb": jax.ShapeDtypeStruct((vocab, d), dtype)}


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["emb"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits in f32 for a stable softmax/loss."""
    return jnp.einsum("bsd,vd->bsv", x, p["emb"]).astype(jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits (B, S, V) f32, labels (B, S) int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
