"""Mamba-2 (SSD) blocks and the attention-free mamba2-370m model
[arXiv:2405.21060].

Block: in_proj → causal depthwise conv (xBC) → SSD scan → gated RMSNorm →
out_proj.  Train/prefill use the chunk-parallel SSD (Pallas kernel on TPU,
chunked oracle on CPU); decode carries (conv_state, ssm_state) — constant
memory per sequence, which is why this family runs long_500k natively
(DESIGN.md §4).

ICSML applicability: in/out projections are quantizable (§6.1) via
``cm.linear``; the scan stays f32 (state accumulation precision, mirroring the
paper keeping scales/biases REAL).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import common as cm

Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    d_inner = cfg.d_inner
    h = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    proj_out = 2 * d_inner + 2 * g * n + h   # z, xBC, dt
    return d_inner, h, g, n, conv_dim, proj_out


def mamba_spec(cfg: ArchConfig) -> Params:
    d_inner, h, g, n, conv_dim, proj_out = _dims(cfg)
    dt = cfg.dtype
    return {
        "in_proj": cm.linear_spec(cfg.d_model, proj_out, bias=False,
                                  quant=cfg.quant, dtype=dt),
        "conv_w": jax.ShapeDtypeStruct((cfg.conv_kernel, conv_dim), dt),
        "conv_b": jax.ShapeDtypeStruct((conv_dim,), jnp.float32),
        "dt_bias": jax.ShapeDtypeStruct((h,), jnp.float32),
        "a_log": jax.ShapeDtypeStruct((h,), jnp.float32),
        "d_skip": jax.ShapeDtypeStruct((h,), jnp.float32),
        "norm": cm.rmsnorm_spec(d_inner),
        "out_proj": cm.linear_spec(d_inner, cfg.d_model, bias=False,
                                   quant=cfg.quant, dtype=dt),
    }


def mamba_init(key: jax.Array, cfg: ArchConfig) -> Params:
    d_inner, h, g, n, conv_dim, proj_out = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": cm.linear_init(ks[0], cfg.d_model, proj_out, bias=False,
                                  quant=cfg.quant, dtype=cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim), jnp.float32)
                   / np.sqrt(cfg.conv_kernel)).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32,
                                       np.log(1e-3), np.log(1e-1))))),
        "a_log": jnp.log(jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32,
                                                    0.0, np.log(16.0)))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": cm.rmsnorm_init(d_inner),
        "out_proj": cm.linear_init(ks[0], d_inner, cfg.d_model, bias=False,
                                   quant=cfg.quant, dtype=cfg.dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    d_inner, h, g, n, conv_dim, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def _causal_conv(p: Params, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C)."""
    k = p["conv_w"].shape[0]
    c = xbc.shape[-1]
    w = p["conv_w"].astype(xbc.dtype)[:, None, :]        # (K, 1, C)
    y = jax.lax.conv_general_dilated(
        xbc, w,
        window_strides=(1,),
        padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    return jax.nn.silu(y + p["conv_b"].astype(xbc.dtype))


def mamba_forward(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence mixer: x (B, S, d_model) -> (B, S, d_model)."""
    d_inner, h, g, n, conv_dim, _ = _dims(cfg)
    b, s, _ = x.shape
    zxbcdt = cm.linear(p["in_proj"], x)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(p, xbc)
    xs = xbc[..., :d_inner].reshape(b, s, h, cfg.ssm_headdim)
    bmat = xbc[..., d_inner:d_inner + g * n].reshape(b, s, g, n)
    cmat = xbc[..., d_inner + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    y = kops.ssd(xs.astype(jnp.float32), dt, a,
                 bmat.astype(jnp.float32), cmat.astype(jnp.float32))
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(cfg.dtype)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return cm.linear(p["out_proj"], y)


# -- decode -----------------------------------------------------------------


def mamba_cache_spec(cfg: ArchConfig, batch: int) -> Dict[str, Any]:
    d_inner, h, g, n, conv_dim, _ = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_kernel - 1, conv_dim), cfg.dtype),
        "ssm": jax.ShapeDtypeStruct((batch, h, cfg.ssm_headdim, n), jnp.float32),
    }


def mamba_decode(p: Params, cfg: ArchConfig, x: jax.Array,
                 cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step: x (B, 1, d_model); cache carries conv + ssm state."""
    d_inner, h, g, n, conv_dim, _ = _dims(cfg)
    b = x.shape[0]
    zxbcdt = cm.linear(p["in_proj"], x)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)            # (B,1,·)

    window = jnp.concatenate([cache["conv"], xbc], axis=1)   # (B, K, C)
    conv_state = window[:, 1:]
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
    xbc1 = jax.nn.silu(y + p["conv_b"])                  # (B, C) f32

    xs = xbc1[:, :d_inner].reshape(b, h, cfg.ssm_headdim)
    bmat = xbc1[:, d_inner:d_inner + g * n].reshape(b, g, n)
    cmat = xbc1[:, d_inner + g * n:].reshape(b, g, n)
    reps = h // g
    bmat = jnp.repeat(bmat, reps, axis=1)
    cmat = jnp.repeat(cmat, reps, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    new_state, yt = jax.vmap(kref.ssd_update_ref, in_axes=(0, 0, 0, None, 0, 0))(
        cache["ssm"], xs, dt, a, bmat, cmat
    )
    yt = yt + p["d_skip"][None, :, None] * xs
    yt = yt.reshape(b, 1, d_inner).astype(cfg.dtype)
    yt = cm.rmsnorm(p["norm"], yt * jax.nn.silu(z))
    out = cm.linear(p["out_proj"], yt)
    return out, {"conv": conv_state, "ssm": new_state}


# ---------------------------------------------------------------------------
# Full mamba2 model (norm → mixer → residual, no separate FFN)
# ---------------------------------------------------------------------------


def model_spec(cfg: ArchConfig) -> Params:
    blk = {"ln": cm.rmsnorm_spec(cfg.d_model), "mixer": mamba_spec(cfg)}
    from repro.models.transformer import stacked_specs
    return {
        "embed": cm.embed_spec(cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": stacked_specs(blk, cfg.n_layers),
        "final_norm": cm.rmsnorm_spec(cfg.d_model),
    }


def model_init(key: jax.Array, cfg: ArchConfig) -> Params:
    k_emb, k_blocks = jax.random.split(key)
    keys = jax.random.split(k_blocks, cfg.n_layers)

    def one(k):
        return {"ln": cm.rmsnorm_init(cfg.d_model), "mixer": mamba_init(k, cfg)}

    return {
        "embed": cm.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": jax.vmap(one)(keys),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
    }


def forward_logits(params: Params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)

    def body(h, blk):
        h = h + mamba_forward(blk["mixer"], cfg, cm.rmsnorm(blk["ln"], h))
        return cm.constrain(h, "btd"), None

    body_fn = jax.checkpoint(body) if cfg.remat == "layer" else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"], unroll=cfg.scan_unroll)
    x = cm.rmsnorm(params["final_norm"], x)
    return cm.unembed(params["embed"], x)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    return cm.cross_entropy(forward_logits(params, cfg, batch["tokens"]),
                            batch["labels"])


def cache_spec(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    one = mamba_cache_spec(cfg, batch)
    from repro.models.transformer import stacked_specs
    return stacked_specs(one, cfg.n_layers)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len))


def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array, cache_len: int
            ) -> Tuple[Dict[str, Any], jax.Array]:
    """Prefill = full forward; final states distilled by a short scan tail.

    The SSM has O(1) state, so 'prefill' just runs the sequence and keeps the
    final (conv, ssm) states.  We recompute states from the last K tokens for
    conv and run the SSD with state output for ssm; for simplicity (and since
    decode correctness is covered by stepwise tests) we rebuild the state by
    stepping the final token window."""
    b, s = tokens.shape
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)

    caches = []
    h = x

    # Python loop over layers here would unroll; instead run scan keeping
    # final-state outputs per layer via mamba_forward_with_state.
    def body(hh, blk):
        normed = cm.rmsnorm(blk["ln"], hh)
        out, state = _mamba_forward_state(blk["mixer"], cfg, normed)
        return hh + out, state

    h, states = jax.lax.scan(body, h, params["blocks"], unroll=cfg.scan_unroll)
    h = cm.rmsnorm(params["final_norm"], h)
    logits = cm.unembed(params["embed"], h[:, -1:])
    return states, logits


def _mamba_forward_state(p: Params, cfg: ArchConfig, x: jax.Array
                         ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """mamba_forward that also returns the final (conv, ssm) state."""
    d_inner, h, g, n, conv_dim, _ = _dims(cfg)
    b, s, _ = x.shape
    zxbcdt = cm.linear(p["in_proj"], x)
    z, xbc_pre, dt_raw = _split_proj(cfg, zxbcdt)
    # conv state is the last (K-1) inputs, front-padded with zeros for
    # prompts shorter than the kernel (the stepwise decode's initial state).
    k1 = cfg.conv_kernel - 1
    pad = max(k1 - s, 0)
    conv_state = jnp.pad(xbc_pre, ((0, 0), (pad, 0), (0, 0)))[:, -k1:, :]
    xbc = _causal_conv(p, xbc_pre)
    xs = xbc[..., :d_inner].reshape(b, s, h, cfg.ssm_headdim).astype(jnp.float32)
    bmat = xbc[..., d_inner:d_inner + g * n].reshape(b, s, g, n).astype(jnp.float32)
    cmat = xbc[..., d_inner + g * n:].reshape(b, s, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    y = kops.ssd(xs, dt, a, bmat, cmat)

    # Final SSM state: run the recurrence contribution sum (exact, O(S)).
    reps = h // g
    bf = jnp.repeat(bmat, reps, axis=2)
    alpha = dt * a                                        # (B,S,H)
    srev = jnp.cumsum(alpha[:, ::-1], axis=1)[:, ::-1]    # decay from τ to end
    w = jnp.exp(srev - alpha) * dt                        # exp(sum_{σ>τ}α)·dtτ
    ssm_state = jnp.einsum("bsh,bshp,bshn->bhpn", w, xs, bf)

    y = y + p["d_skip"][None, None, :, None] * xs
    y = y.reshape(b, s, d_inner).astype(cfg.dtype)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return cm.linear(p["out_proj"], y), {"conv": conv_state, "ssm": ssm_state}


def decode_step(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[Dict[str, Any], jax.Array]:
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)

    def body(h, inputs):
        blk, conv_c, ssm_c = inputs
        out, new_cache = mamba_decode(blk["mixer"], cfg,
                                      cm.rmsnorm(blk["ln"], h),
                                      {"conv": conv_c, "ssm": ssm_c})
        return h + out, (new_cache["conv"], new_cache["ssm"])

    x, (conv, ssm) = jax.lax.scan(
        body, x, (params["blocks"], cache["conv"], cache["ssm"]),
        unroll=cfg.scan_unroll,
    )
    x = cm.rmsnorm(params["final_norm"], x)
    return {"conv": conv, "ssm": ssm}, cm.unembed(params["embed"], x)


def decode_step_multi(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                      tokens: jax.Array, pos: jax.Array
                      ) -> Tuple[Dict[str, Any], jax.Array]:
    """Per-slot-position decode (pos (B,)).

    The SSM state is recurrent per batch row — positions never index the
    cache — so the plain step already decodes every slot independently."""
    return decode_step(params, cfg, cache, tokens, pos)
