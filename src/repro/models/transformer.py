"""Dense GQA decoder — command-r(-plus), nemotron-4, qwen3, and the backbone
for llava-next (vlm.py) and the MoE models (moe.py swaps the FFN).

Layers are stacked on a leading axis and executed with ``jax.lax.scan`` so the
dry-run HLO stays compact at 96 layers; training remat is per-layer
(``jax.checkpoint`` around the scan body) when ``cfg.remat == 'layer'``.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Per-layer block (attention + FFN) — ffn_* hooks let moe.py substitute MoE.
# ---------------------------------------------------------------------------


def _attn_cfg(cfg: ArchConfig) -> cm.AttnConfig:
    return cm.AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        qk_norm=cfg.qk_norm,
        bias=cfg.bias,
        rope_theta=cfg.rope_theta,
        window=cfg.sliding_window,
        d_head=cfg.d_head,
    )


def _mlp_cfg(cfg: ArchConfig) -> cm.MlpConfig:
    return cm.MlpConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                        kind=cfg.mlp_kind, bias=cfg.bias)


def block_spec(cfg: ArchConfig, ffn_spec: Callable[[], Params]) -> Params:
    p = {
        "ln1": cm.rmsnorm_spec(cfg.d_model),
        "attn": cm.attn_spec(_attn_cfg(cfg), cfg.quant, cfg.dtype),
        "ffn": ffn_spec(),
    }
    if not cfg.parallel_block:
        p["ln2"] = cm.rmsnorm_spec(cfg.d_model)
    return p


def block_init(key: jax.Array, cfg: ArchConfig,
               ffn_init: Callable[[jax.Array], Params]) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": cm.rmsnorm_init(cfg.d_model),
        "attn": cm.attn_init(k1, _attn_cfg(cfg), cfg.quant, cfg.dtype),
        "ffn": ffn_init(k2),
    }
    if not cfg.parallel_block:
        p["ln2"] = cm.rmsnorm_init(cfg.d_model)
    return p


def block_forward(
    blk: Params, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
    ffn_apply: Callable[[Params, jax.Array], jax.Array],
) -> jax.Array:
    acfg = _attn_cfg(cfg)
    h = cm.rmsnorm(blk["ln1"], x)
    a = cm.attn_forward(blk["attn"], acfg, h, positions)
    if cfg.parallel_block:
        # command-r: attention and FFN read the same normed input (one LN).
        m = ffn_apply(blk["ffn"], h)
        x = x + a + m
    else:
        x = x + a
        h2 = cm.rmsnorm(blk["ln2"], x)
        x = x + ffn_apply(blk["ffn"], h2)
    return cm.constrain(x, "btd")


def block_prefill(blk, cfg, x, positions, cache_len, ffn_apply):
    acfg = _attn_cfg(cfg)
    h = cm.rmsnorm(blk["ln1"], x)
    a, kv = cm.attn_prefill(blk["attn"], acfg, h, positions, cache_len)
    if cfg.parallel_block:
        x = x + a + ffn_apply(blk["ffn"], h)
    else:
        x = x + a
        x = x + ffn_apply(blk["ffn"], cm.rmsnorm(blk["ln2"], x))
    return cm.constrain(x, "btd"), kv


def block_decode(blk, cfg, x, pos, kv, ffn_apply):
    acfg = _attn_cfg(cfg)
    h = cm.rmsnorm(blk["ln1"], x)
    a, kv = cm.attn_decode(blk["attn"], acfg, h, pos, kv)
    if cfg.parallel_block:
        x = x + a + ffn_apply(blk["ffn"], h)
    else:
        x = x + a
        x = x + ffn_apply(blk["ffn"], cm.rmsnorm(blk["ln2"], x))
    return x, kv


def block_decode_multi(blk, cfg, x, pos, kv, ffn_apply):
    """block_decode with per-row positions pos (B,) (continuous batching)."""
    acfg = _attn_cfg(cfg)
    h = cm.rmsnorm(blk["ln1"], x)
    a, kv = cm.attn_decode_multi(blk["attn"], acfg, h, pos, kv)
    if cfg.parallel_block:
        x = x + a + ffn_apply(blk["ffn"], h)
    else:
        x = x + a
        x = x + ffn_apply(blk["ffn"], cm.rmsnorm(blk["ln2"], x))
    return x, kv


# ---------------------------------------------------------------------------
# Full decoder
# ---------------------------------------------------------------------------


def stacked_specs(one: Params, n: int) -> Params:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one
    )


def decoder_spec(cfg: ArchConfig, ffn_spec=None) -> Params:
    ffn_spec = ffn_spec or (lambda: cm.mlp_spec(_mlp_cfg(cfg), cfg.quant, cfg.dtype))
    return {
        "embed": cm.embed_spec(cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": stacked_specs(block_spec(cfg, ffn_spec), cfg.n_layers),
        "final_norm": cm.rmsnorm_spec(cfg.d_model),
    }


def decoder_init(key: jax.Array, cfg: ArchConfig, ffn_init=None) -> Params:
    ffn_init = ffn_init or (
        lambda k: cm.mlp_init(k, _mlp_cfg(cfg), cfg.quant, cfg.dtype)
    )
    k_emb, k_blocks = jax.random.split(key)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg, ffn_init))(block_keys)
    return {
        "embed": cm.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": blocks,
        "final_norm": cm.rmsnorm_init(cfg.d_model),
    }


def _scan_blocks(body, x, blocks, remat: str, unroll: int = 1):
    if remat == "layer":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, blocks, unroll=unroll)
    return x


def decoder_hidden(
    params: Params, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
    ffn_apply=None,
) -> jax.Array:
    """Run the block stack over embedded inputs x (B, S, D)."""
    ffn_apply = ffn_apply or (lambda p, h: cm.mlp_forward(p, _mlp_cfg(cfg), h))

    def body(h, blk):
        return block_forward(blk, cfg, h, positions, ffn_apply), None

    x = _scan_blocks(body, x, params["blocks"], cfg.remat, cfg.scan_unroll)
    return cm.rmsnorm(params["final_norm"], x)


def forward_logits(params: Params, cfg: ArchConfig, tokens: jax.Array,
                   ffn_apply=None, prefix_embed: Optional[jax.Array] = None
                   ) -> jax.Array:
    """Teacher-forced logits. prefix_embed (B, P, D) is prepended (VLM)."""
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(cfg.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    h = decoder_hidden(params, cfg, x, positions, ffn_apply)
    return cm.unembed(params["embed"], h)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            ffn_apply=None) -> jax.Array:
    logits = forward_logits(params, cfg, batch["tokens"], ffn_apply,
                            prefix_embed=batch.get("prefix_embed"))
    if "prefix_embed" in batch:
        logits = logits[:, batch["prefix_embed"].shape[1]:]
    return cm.cross_entropy(logits, batch["labels"])


# -- serving ---------------------------------------------------------------


def cache_spec(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    kv_shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.d_head)
    if cfg.kv_quant:
        # §6.1 quantization applied to serving state: int8 K/V + REAL scales
        sc_shape = kv_shape[:-1]
        return {
            "k": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
            "v": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
            "k_scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32),
            "v_scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32),
        }
    return {
        "k": jax.ShapeDtypeStruct(kv_shape, cfg.dtype),
        "v": jax.ShapeDtypeStruct(kv_shape, cfg.dtype),
    }


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, cache_len)
    )


def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array,
            cache_len: int, ffn_apply=None,
            prefix_embed: Optional[jax.Array] = None
            ) -> Tuple[Dict[str, Any], jax.Array]:
    ffn_apply = ffn_apply or (lambda p, h: cm.mlp_forward(p, _mlp_cfg(cfg), h))
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(cfg.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, blk):
        h, kv = block_prefill(blk, cfg, h, positions, cache_len, ffn_apply)
        if cfg.kv_quant:
            kq, ks = cm._quantize_kv(kv[0])
            vq, vs = cm._quantize_kv(kv[1])
            kv = (kq, vq, ks, vs)
        return h, kv

    x, kv = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    h = cm.rmsnorm(params["final_norm"], x)
    logits = cm.unembed(params["embed"], h[:, -1:])
    if cfg.kv_quant:
        return {"k": kv[0], "v": kv[1], "k_scale": kv[2], "v_scale": kv[3]}, logits
    return {"k": kv[0], "v": kv[1]}, logits


def _decode_step_impl(params, cfg, cache, tokens, pos, ffn_apply, block_step):
    ffn_apply = ffn_apply or (lambda p, h: cm.mlp_forward(p, _mlp_cfg(cfg), h))
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)

    if cfg.kv_quant:
        def body(h, inputs):
            blk, kc, vc, ksc, vsc = inputs
            h, kv = block_step(blk, cfg, h, pos, (kc, vc, ksc, vsc), ffn_apply)
            return h, kv

        x, kv = jax.lax.scan(
            body, x,
            (params["blocks"], cache["k"], cache["v"],
             cache["k_scale"], cache["v_scale"]),
            unroll=cfg.scan_unroll)
        h = cm.rmsnorm(params["final_norm"], x)
        return ({"k": kv[0], "v": kv[1], "k_scale": kv[2], "v_scale": kv[3]},
                cm.unembed(params["embed"], h))

    def body(h, inputs):
        blk, kc, vc = inputs
        h, kv = block_step(blk, cfg, h, pos, (kc, vc), ffn_apply)
        return h, kv

    x, (k, v) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]),
                             unroll=cfg.scan_unroll)
    h = cm.rmsnorm(params["final_norm"], x)
    logits = cm.unembed(params["embed"], h)
    return {"k": k, "v": v}, logits


def decode_step(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                tokens: jax.Array, pos: jax.Array, ffn_apply=None
                ) -> Tuple[Dict[str, Any], jax.Array]:
    """One decode step: tokens (B, 1), pos scalar int32; cache donated."""
    return _decode_step_impl(params, cfg, cache, tokens, pos, ffn_apply,
                             block_decode)


def decode_step_multi(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                      tokens: jax.Array, pos: jax.Array, ffn_apply=None
                      ) -> Tuple[Dict[str, Any], jax.Array]:
    """One decode step with per-slot positions: tokens (B, 1), pos (B,) int32.

    Each batch slot advances at its own position in the shared cache — the
    decode signature continuous batching needs (serving/continuous.py)."""
    return _decode_step_impl(params, cfg, cache, tokens, pos, ffn_apply,
                             block_decode_multi)
