"""Uniform model API over all architecture families.

``get_model(cfg)`` returns a :class:`ModelAPI` whose five functions have the
same signatures for every family, so the launcher, dry-run, serving engine and
smoke tests are architecture-agnostic:

  init(key) -> params
  loss(params, batch) -> scalar                       (train)
  prefill(params, batch, cache_len) -> (cache, logits)
  decode(params, cache, batch, pos) -> (cache, logits)       pos: () scalar
  decode_multi(params, cache, batch, pos) -> (cache, logits) pos: (B,) per-slot
  cache_specs(batch, cache_len) -> pytree of ShapeDtypeStruct

``decode`` advances every batch row at one shared position (wave batching);
``decode_multi`` advances each row at its own position — the signature the
continuous-batching engine (serving/continuous.py) schedules slots with.

Batch layouts per family (``batch_specs`` builds ShapeDtypeStruct stand-ins;
the data pipeline builds real ones):

  dense/moe/ssm/hybrid: {tokens (B,S), labels (B,S)}
  vlm:  {tokens (B,S-I), labels (B,S-I), image_emb (B,I,VISION_D)}  (stub)
  audio:{tokens (B,S), labels (B,S), frames (B,F,d_model)}          (stub)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import hybrid, mamba2, moe, transformer, vlm, whisper

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable[[jax.Array], Params]
    param_specs: Callable[[], Any]
    loss: Callable[[Params, Batch], jax.Array]
    prefill: Callable[[Params, Batch, int], Tuple[Any, jax.Array]]
    decode: Callable[[Params, Any, Batch, jax.Array], Tuple[Any, jax.Array]]
    decode_multi: Callable[[Params, Any, Batch, jax.Array], Tuple[Any, jax.Array]]
    cache_specs: Callable[[int, int], Any]
    init_cache: Callable[[int, int], Any]
    batch_specs: Callable[[str, int, int], Batch]

    def init_batch(self, kind: str, batch: int, seq: int, key: jax.Array) -> Batch:
        """Random concrete batch matching batch_specs (smoke tests/examples)."""
        specs = self.batch_specs(kind, batch, seq)
        out = {}
        for name, s in specs.items():
            key, k = jax.random.split(key)
            if jnp.issubdtype(s.dtype, jnp.integer):
                out[name] = jax.random.randint(k, s.shape, 0, self.cfg.vocab, s.dtype)
            else:
                out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
        return out


def _token_batch_specs(cfg: ArchConfig):
    def specs(kind: str, batch: int, seq: int) -> Batch:
        if kind == "train":
            return {
                "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            }
        if kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    return specs


def _vlm_batch_specs(cfg: ArchConfig):
    def specs(kind: str, batch: int, seq: int) -> Batch:
        img = jax.ShapeDtypeStruct((batch, cfg.num_image_tokens, vlm.VISION_D),
                                   cfg.dtype)
        text = max(seq - cfg.num_image_tokens, 1)
        if kind == "train":
            return {
                "tokens": jax.ShapeDtypeStruct((batch, text), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, text), jnp.int32),
                "image_emb": img,
            }
        if kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((batch, text), jnp.int32),
                    "image_emb": img}
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    return specs


def _audio_batch_specs(cfg: ArchConfig):
    def specs(kind: str, batch: int, seq: int) -> Batch:
        frames = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        if kind == "train":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            }
        if kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    return specs


def get_model(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family

    if fam in ("dense",):
        mod = transformer
        init = lambda k: transformer.decoder_init(k, cfg)
        spec = lambda: transformer.decoder_spec(cfg)
        loss = lambda p, b: transformer.loss_fn(p, cfg, b)
        pre = lambda p, b, cl: transformer.prefill(p, cfg, b["tokens"], cl)
        dec = lambda p, c, b, pos: transformer.decode_step(p, cfg, c, b["tokens"], pos)
        dec_multi = lambda p, c, b, pos: transformer.decode_step_multi(p, cfg, c, b["tokens"], pos)
        cspec = lambda bsz, cl: transformer.cache_spec(cfg, bsz, cl)
        icache = lambda bsz, cl: transformer.init_cache(cfg, bsz, cl)
        bspec = _token_batch_specs(cfg)
    elif fam == "moe":
        init = lambda k: moe.model_init(k, cfg)
        spec = lambda: moe.model_spec(cfg)
        loss = lambda p, b: moe.loss_fn(p, cfg, b)
        pre = lambda p, b, cl: moe.prefill(p, cfg, b["tokens"], cl)
        dec = lambda p, c, b, pos: moe.decode_step(p, cfg, c, b["tokens"], pos)
        dec_multi = lambda p, c, b, pos: moe.decode_step_multi(p, cfg, c, b["tokens"], pos)
        cspec = lambda bsz, cl: moe.cache_spec(cfg, bsz, cl)
        icache = lambda bsz, cl: moe.init_cache(cfg, bsz, cl)
        bspec = _token_batch_specs(cfg)
    elif fam == "ssm":
        init = lambda k: mamba2.model_init(k, cfg)
        spec = lambda: mamba2.model_spec(cfg)
        loss = lambda p, b: mamba2.loss_fn(p, cfg, b)
        pre = lambda p, b, cl: mamba2.prefill(p, cfg, b["tokens"], cl)
        dec = lambda p, c, b, pos: mamba2.decode_step(p, cfg, c, b["tokens"], pos)
        dec_multi = lambda p, c, b, pos: mamba2.decode_step_multi(p, cfg, c, b["tokens"], pos)
        cspec = lambda bsz, cl: mamba2.cache_spec(cfg, bsz, cl)
        icache = lambda bsz, cl: mamba2.init_cache(cfg, bsz, cl)
        bspec = _token_batch_specs(cfg)
    elif fam == "hybrid":
        init = lambda k: hybrid.model_init(k, cfg)
        spec = lambda: hybrid.model_spec(cfg)
        loss = lambda p, b: hybrid.loss_fn(p, cfg, b)
        pre = lambda p, b, cl: hybrid.prefill(p, cfg, b["tokens"], cl)
        dec = lambda p, c, b, pos: hybrid.decode_step(p, cfg, c, b["tokens"], pos)
        dec_multi = lambda p, c, b, pos: hybrid.decode_step_multi(p, cfg, c, b["tokens"], pos)
        cspec = lambda bsz, cl: hybrid.cache_spec(cfg, bsz, cl)
        icache = lambda bsz, cl: hybrid.init_cache(cfg, bsz, cl)
        bspec = _token_batch_specs(cfg)
    elif fam == "vlm":
        init = lambda k: vlm.model_init(k, cfg)
        spec = lambda: vlm.model_spec(cfg)
        loss = lambda p, b: vlm.loss_fn(p, cfg, b)
        pre = lambda p, b, cl: vlm.prefill(p, cfg, b, cl)
        dec = lambda p, c, b, pos: vlm.decode_step(p, cfg, c, b["tokens"], pos)
        dec_multi = lambda p, c, b, pos: vlm.decode_step_multi(p, cfg, c, b["tokens"], pos)
        cspec = lambda bsz, cl: vlm.cache_spec(cfg, bsz, cl)
        icache = lambda bsz, cl: vlm.init_cache(cfg, bsz, cl)
        bspec = _vlm_batch_specs(cfg)
    elif fam == "audio":
        init = lambda k: whisper.model_init(k, cfg)
        spec = lambda: whisper.model_spec(cfg)
        loss = lambda p, b: whisper.loss_fn(p, cfg, b)
        pre = lambda p, b, cl: whisper.prefill(p, cfg, b["frames"], b["tokens"], cl)
        dec = lambda p, c, b, pos: whisper.decode_step(p, cfg, c, b["tokens"], pos)
        dec_multi = lambda p, c, b, pos: whisper.decode_step_multi(p, cfg, c, b["tokens"], pos)
        cspec = lambda bsz, cl: whisper.cache_spec(cfg, bsz, cl)
        icache = lambda bsz, cl: whisper.init_cache(cfg, bsz, cl)
        bspec = _audio_batch_specs(cfg)
    else:
        raise ValueError(f"unknown family {fam!r}")

    def param_specs():
        return jax.eval_shape(init, jax.ShapeDtypeStruct((2,), jnp.uint32))

    return ModelAPI(
        cfg=cfg, init=init, param_specs=param_specs, loss=loss,
        prefill=pre, decode=dec, decode_multi=dec_multi, cache_specs=cspec,
        init_cache=icache, batch_specs=bspec,
    )
