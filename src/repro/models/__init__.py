"""Architecture backbones for the assigned model pool (DESIGN.md §4)."""

from repro.models.api import ModelAPI, get_model

__all__ = ["ModelAPI", "get_model"]
