"""LLaVA-NeXT-style VLM: vision encoder + projector stubbed; the language
backbone is the dense GQA decoder with an image-token prefix.

AnyRes tiling [hf:llava-hf/llava-v1.6-*]: the (stubbed) vision tower encodes a
base view plus 4 tiles → ``cfg.num_image_tokens`` patch embeddings; the 2-layer
GELU projector maps them into the language model's embedding space, and they
are prepended to the text tokens (the standard llava interleave for a single
leading image).  ``input_specs`` provides the patch embeddings directly.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import transformer as tf

Params = Dict[str, Any]

VISION_D = 1152  # SigLIP-style vision feature width (stub)


def model_spec(cfg: ArchConfig) -> Params:
    p = tf.decoder_spec(cfg)
    p["projector"] = {
        "fc1": cm.linear_spec(VISION_D, cfg.d_model, bias=True, quant=None, dtype=cfg.dtype),
        "fc2": cm.linear_spec(cfg.d_model, cfg.d_model, bias=True, quant=None, dtype=cfg.dtype),
    }
    return p


def model_init(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = tf.decoder_init(k1, cfg)
    p["projector"] = {
        "fc1": cm.linear_init(k2, VISION_D, cfg.d_model, bias=True, quant=None, dtype=cfg.dtype),
        "fc2": cm.linear_init(k3, cfg.d_model, cfg.d_model, bias=True, quant=None, dtype=cfg.dtype),
    }
    return p


def project(p: Params, image_emb: jax.Array) -> jax.Array:
    h = jax.nn.gelu(cm.linear(p["projector"]["fc1"], image_emb))
    return cm.linear(p["projector"]["fc2"], h)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    prefix = project(params, batch["image_emb"])
    b2 = dict(batch, prefix_embed=prefix)
    return tf.loss_fn(params, cfg, b2)


def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            cache_len: int) -> Tuple[Dict[str, Any], jax.Array]:
    prefix = project(params, batch["image_emb"])
    return tf.prefill(params, cfg, batch["tokens"], cache_len,
                      prefix_embed=prefix)


cache_spec = tf.cache_spec
init_cache = tf.init_cache
decode_step = tf.decode_step
decode_step_multi = tf.decode_step_multi
