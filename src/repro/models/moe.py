"""Mixture-of-Experts FFN — granite-moe (32e top-8), mixtral (8e top-2) and
the jamba MoE layers (16e top-2).

Two dispatch implementations:

* ``einsum`` (default/baseline): GShard-style one-hot dispatch/combine tensors
  with a fixed capacity per expert.  Static shapes, GSPMD-safe — the expert
  all-to-all materializes from resharding the (groups, capacity, d) dispatch
  tensor from token-sharded to expert-sharded layout.  Costs extra FLOPs
  (T·E·C·D per einsum); that overhead is visible in the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio and is a §Perf hillclimb target.
* ``ragged`` (beyond-paper optimization): sort tokens by expert and use
  ``jax.lax.ragged_dot`` — removes the dispatch-einsum FLOPs entirely.

Tokens are processed in groups of ``group`` (default 512) so the dispatch
tensors stay small; capacity C = ceil(group · top_k / E · capacity_factor).
Router uses an auxiliary load-balance loss (Switch §2.2) during training.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common as cm

Params = Dict[str, Any]

GROUP = 512  # tokens per dispatch group


def moe_spec(cfg: ArchConfig) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = cfg.dtype
    return {
        "router": jax.ShapeDtypeStruct((d, e), jnp.float32),
        "w_gate": jax.ShapeDtypeStruct((e, d, f), dt),
        "w_up": jax.ShapeDtypeStruct((e, d, f), dt),
        "w_down": jax.ShapeDtypeStruct((e, f, d), dt),
    }


def moe_init(key: jax.Array, cfg: ArchConfig) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out).astype(cfg.dtype),
    }


def _capacity(group: int, cfg: ArchConfig) -> int:
    c = int(np.ceil(group * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(c, 1)


def _route(p: Params, cfg: ArchConfig, x: jax.Array
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: returns (gate_weights (G,T,K), expert_idx (G,T,K), aux_loss).

    The router matmul runs in the activation dtype (softmax still f32): doing
    it in f32 makes the activation *gradient* f32 and doubles every
    tensor-parallel all-reduce on the residual stream (§Perf, granite)."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)                 # (G, T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * mean(frac_tokens * frac_probs).
    e = cfg.n_experts
    onehot = jax.nn.one_hot(idx[..., 0], e)                     # top-1 counts
    frac_tokens = onehot.mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return gate, idx, aux


def moe_forward_einsum(p: Params, cfg: ArchConfig, x: jax.Array,
                       group: Optional[int] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """GShard one-hot dispatch.  x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = b * s
    group = min(group or cfg.moe_group, tokens)
    assert tokens % group == 0, (tokens, group)
    g = tokens // group
    c = _capacity(group, cfg)
    xg = x.reshape(g, group, d)

    gate, idx, aux = _route(p, cfg, xg)                         # (G,T,K)

    # Position-in-expert with slot priority: slot 0 of every token beats
    # slot 1 (standard GShard ordering), then token order.
    mask = jax.nn.one_hot(idx, e, dtype=jnp.float32)            # (G,T,K,E)
    mask_flat = mask.transpose(0, 2, 1, 3).reshape(g, k * group, e)
    pos_flat = jnp.cumsum(mask_flat, axis=1) - mask_flat        # (G,KT,E)
    pos = pos_flat.reshape(g, k, group, e).transpose(0, 2, 1, 3)  # (G,T,K,E)
    pos = jnp.sum(pos * mask, axis=-1).astype(jnp.int32)        # (G,T,K)
    keep = (pos < c) & (gate > 0)
    gate = gate * keep

    # Dispatch/combine tensors (G, T, E, C).
    pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32)          # (G,T,K,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", mask * keep[..., None], pos_oh)
    combine = jnp.einsum("gtke,gtkc->gtec", mask * gate[..., None], pos_oh)

    # To experts: (G,E,C,D), resharded expert-major => all-to-all under pjit.
    ddt = jnp.dtype(cfg.moe_dispatch_dtype)
    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(ddt),
                     x.reshape(g, group, d).astype(ddt))
    xin = cm.constrain(xin.astype(cfg.dtype), "expert_in")

    h = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    hu = jax.nn.silu(h) * u
    out_e = jnp.einsum("gecf,efd->gecd", hu, p["w_down"])
    out_e = cm.constrain(out_e, "expert_in")

    out = jnp.einsum("gtec,gecd->gtd", combine.astype(ddt),
                     out_e.astype(ddt))
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_forward_ragged(p: Params, cfg: ArchConfig, x: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Sorted ragged_dot dispatch (beyond-paper §Perf optimization).

    No capacity drop and no one-hot matmul FLOPs: tokens are argsorted by
    expert and hit ``jax.lax.ragged_dot`` grouped matmuls directly.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    gate, idx, aux = _route(p, cfg, xt[None])                   # (1,T,K)
    gate, idx = gate[0], idx[0]

    flat_expert = idx.reshape(-1)                               # (T*K,)
    order = jnp.argsort(flat_expert)                            # stable
    token_of = order // k
    xs = xt[token_of].astype(cfg.dtype)                         # (T*K, D)
    sizes = jnp.bincount(flat_expert, length=e)                 # (E,)

    h = jax.lax.ragged_dot(xs, p["w_gate"], sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], sizes)
    hu = (jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)).astype(cfg.dtype)
    ys = jax.lax.ragged_dot(hu, p["w_down"], sizes)             # (T*K, D)

    w = gate.reshape(-1)[order].astype(jnp.float32)
    out = jnp.zeros((t, d), jnp.float32).at[token_of].add(
        ys.astype(jnp.float32) * w[:, None]
    )
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_forward(p: Params, cfg: ArchConfig, x: jax.Array,
                dispatch: str = "einsum") -> jax.Array:
    """FFN-interface wrapper (aux loss stashed via jax custom side channel is
    avoided; training adds the aux term through `loss_with_aux`)."""
    fn = moe_forward_einsum if dispatch == "einsum" else moe_forward_ragged
    out, _ = fn(p, cfg, x)
    return out


def make_ffn_apply(cfg: ArchConfig, dispatch: str = "einsum"):
    return lambda p, h: moe_forward(p, cfg, h, dispatch)


# ---------------------------------------------------------------------------
# Full MoE decoder (granite, mixtral): transformer blocks with MoE FFN and the
# load-balance aux loss threaded through the layer scan.
# ---------------------------------------------------------------------------

AUX_WEIGHT = 0.01


def model_spec(cfg: ArchConfig) -> Params:
    from repro.models import transformer as tf
    return tf.decoder_spec(cfg, ffn_spec=lambda: moe_spec(cfg))


def model_init(key: jax.Array, cfg: ArchConfig) -> Params:
    from repro.models import transformer as tf
    return tf.decoder_init(key, cfg, ffn_init=lambda k: moe_init(k, cfg))


def forward_logits(params: Params, cfg: ArchConfig, tokens: jax.Array,
                   dispatch: str = "einsum") -> Tuple[jax.Array, jax.Array]:
    from repro.models import transformer as tf
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    fwd = moe_forward_einsum if dispatch == "einsum" else moe_forward_ragged

    def body(carry, blk):
        h, aux = carry
        hn = cm.rmsnorm(blk["ln1"], h)
        a = cm.attn_forward(blk["attn"], tf._attn_cfg(cfg), hn, positions)
        h = h + a
        out, aux_l = fwd(blk["ffn"], cfg, cm.rmsnorm(blk["ln2"], h))
        h = cm.constrain(h + out, "btd")
        return (h, aux + aux_l), None

    body_fn = jax.checkpoint(body) if cfg.remat == "layer" else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["blocks"],
                               unroll=cfg.scan_unroll)
    x = cm.rmsnorm(params["final_norm"], x)
    return cm.unembed(params["embed"], x), aux / cfg.n_layers


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            dispatch: str = "einsum") -> jax.Array:
    logits, aux = forward_logits(params, cfg, batch["tokens"], dispatch)
    return cm.cross_entropy(logits, batch["labels"]) + AUX_WEIGHT * aux


def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array, cache_len: int,
            dispatch: str = "einsum"):
    from repro.models import transformer as tf
    return tf.prefill(params, cfg, tokens, cache_len,
                      ffn_apply=make_ffn_apply(cfg, dispatch))


def decode_step(params: Params, cfg: ArchConfig, cache, tokens, pos,
                dispatch: str = "einsum"):
    from repro.models import transformer as tf
    return tf.decode_step(params, cfg, cache, tokens, pos,
                          ffn_apply=make_ffn_apply(cfg, dispatch))


def decode_step_multi(params: Params, cfg: ArchConfig, cache, tokens, pos,
                      dispatch: str = "einsum"):
    """Per-slot-position decode (pos (B,)) — see transformer.decode_step_multi."""
    from repro.models import transformer as tf
    return tf.decode_step_multi(params, cfg, cache, tokens, pos,
                                ffn_apply=make_ffn_apply(cfg, dispatch))


def cache_spec(cfg: ArchConfig, batch: int, cache_len: int):
    from repro.models import transformer as tf
    return tf.cache_spec(cfg, batch, cache_len)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    from repro.models import transformer as tf
    return tf.init_cache(cfg, batch, cache_len)
