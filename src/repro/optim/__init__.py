"""Optimizers + schedules (self-contained, like ICSML's §4.2.4 substrate)."""

from repro.optim.adamw import OptState, adamw, apply_updates, global_norm, sgd
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "OptState", "adamw", "apply_updates", "global_norm", "sgd",
    "constant", "cosine_decay", "linear_warmup_cosine",
]
