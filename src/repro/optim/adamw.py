"""AdamW + SGD, pytree-native, sharding-transparent.

Optimizer state mirrors the parameter pytree (same shapes/shardings →
ZeRO-like partitioning falls out of the parameter sharding rules).  Moments
are kept in f32 regardless of parameter dtype; integer leaves (quantized
weights) are not updated (serving-only parameters).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (f32, like params)
    nu: Any          # second moment (f32)


def _trainable(leaf: jax.Array) -> bool:
    return jnp.issubdtype(leaf.dtype, jnp.floating)


def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: Optional[float] = 1.0,
):
    """Returns (init_fn, update_fn) in the optax convention."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params: Any) -> OptState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if _trainable(p) else jnp.zeros((), jnp.float32),
            params,
        )
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                        nu=jax.tree.map(jnp.copy, zeros))

    def update(grads: Any, state: OptState, params: Any) -> Tuple[Any, OptState]:
        step = state.step + 1
        if grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        def moments(g, m, v):
            g = g.astype(jnp.float32)
            return b1 * m + (1 - b1) * g, b2 * v + (1 - b2) * g * g

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)

        new_m, new_v, updates = [], [], []
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            if not _trainable(p):
                new_m.append(m); new_v.append(v)
                updates.append(jnp.zeros_like(p))
                continue
            m2, v2 = moments(g, m, v)
            mhat = m2 / bc1
            vhat = v2 / bc2
            upd = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            new_m.append(m2); new_v.append(v2)
            updates.append((-lr_t * upd).astype(p.dtype))

        return (
            treedef.unflatten(updates),
            OptState(step=step, mu=treedef.unflatten(new_m),
                     nu=treedef.unflatten(new_v)),
        )

    return init, update


def sgd(lr: float, momentum: float = 0.0):
    def init(params):
        mu = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32) if _trainable(p) else jnp.zeros((), jnp.float32),
            params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=jax.tree.map(jnp.zeros_like, mu))

    def update(grads, state, params):
        step = state.step + 1
        mu = jax.tree.map(
            lambda g, m, p: momentum * m + g.astype(jnp.float32)
            if _trainable(p) else m,
            grads, state.mu, params)
        updates = jax.tree.map(
            lambda m, p: (-lr * m).astype(p.dtype)
            if _trainable(p) else jnp.zeros_like(p),
            mu, params)
        return updates, OptState(step=step, mu=mu, nu=state.nu)

    return init, update


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
