"""Pallas TPU kernel: block-sparse matmul — the pruning 'operation skip' (§6.2).

The paper shows per-element IF-skipping of zero weights only pays off when the
check is cheap relative to the MAC.  A TPU MXU cannot predicate individual
MACs, so the skip must be *structural*: the pruned weight matrix is stored as
a list of nonzero (block_k × block_n) tiles plus their block coordinates, and
the kernel grid iterates **only over nonzero blocks** — pruned blocks cost
exactly zero FLOPs and zero HBM traffic.  This is the 'precompiled model'
optimization the paper sketches in §8.1.

Implementation: scalar-prefetch grid (PrefetchScalarGridSpec).  The block
coordinate arrays live in SMEM and drive the BlockSpec index_maps, so the
x-tile and out-tile for step ``s`` are chosen by data, not by affine grid
math.  Blocks are pre-sorted by output column so each output tile is visited
by one contiguous run of grid steps; the accumulator initializes on the first
step of a run and writes through on every step (out stays resident in VMEM
within a run — Pallas keeps revisited blocks live).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.prune import BlockSparseWeight


def _sparse_kernel(
    # scalar-prefetch operands (SMEM):
    bi_ref,       # (nnz,) int32 — input-block row of step s
    bj_ref,       # (nnz,) int32 — output-block col of step s
    first_ref,    # (nnz,) int32 — 1 iff step s starts a new output tile
    # tensor operands (VMEM):
    x_ref,        # (bm, bk) f32 — activation tile for block row bi[s]
    v_ref,        # (1, bk, bn) f32 — nonzero weight tile s
    out_ref,      # (bm, bn) f32 — output tile for block col bj[s]
):
    s = pl.program_id(0)

    @pl.when(first_ref[s] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        x_ref[...], v_ref[0], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "shape_n", "interpret"))
def _sparse_matmul_impl(
    x: jax.Array,
    values: jax.Array,
    bi: jax.Array,
    bj: jax.Array,
    first: jax.Array,
    *,
    block_m: int,
    shape_n: int,
    interpret: bool,
) -> jax.Array:
    m, k = x.shape
    nnz, bk, bn = values.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nnz,),
        in_specs=[
            pl.BlockSpec((block_m, bk), lambda s, bi, bj, first: (0, bi[s])),
            pl.BlockSpec((1, bk, bn), lambda s, bi, bj, first: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, bn), lambda s, bi, bj, first: (0, bj[s])),
    )
    return pl.pallas_call(
        _sparse_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, shape_n), jnp.float32),
        interpret=interpret,
    )(bi, bj, first, x, values)


def sparse_matmul(
    x: jax.Array,
    w: BlockSparseWeight,
    *,
    interpret: bool = False,
) -> jax.Array:
    """``out = x @ w`` where pruned (zero) blocks of ``w`` are skipped.

    Note: output tiles with *no* nonzero blocks are never visited and retain
    whatever was in the output buffer; callers must treat fully-pruned output
    columns as zero.  ``ops.sparse_dense`` handles this by masking.

    Args:
      x: (M, K) f32 activations; M must match a single block_m tile here
         (serving uses M = batch tile), K = w.shape[0].
      w: plan-time block-sparse weight (sorted internally by output column).
    """
    n_rows, n_cols = w.shape
    bk, bn = w.block
    m = x.shape[0]
    assert x.shape[1] == n_rows, (x.shape, w.shape)

    # Sort blocks by output column so each out tile is a contiguous run.
    order = np.lexsort((w.indices[:, 0], w.indices[:, 1]))
    idx = w.indices[order]
    values = w.values[jnp.asarray(order)]
    bj = idx[:, 1]
    first = np.ones_like(bj)
    first[1:] = (bj[1:] != bj[:-1]).astype(bj.dtype)

    out = _sparse_matmul_impl(
        x,
        values,
        jnp.asarray(idx[:, 0], jnp.int32),
        jnp.asarray(bj, jnp.int32),
        jnp.asarray(first, jnp.int32),
        block_m=m,
        shape_n=n_cols,
        interpret=interpret,
    )
    # Zero out columns whose block-column had no nonzero blocks at all.
    # (Unvisited output tiles are uninitialized — possibly NaN — so select,
    # don't multiply: NaN * 0 == NaN.)
    present = np.zeros((n_cols // bn,), bool)
    present[np.unique(bj)] = True
    col_mask = jnp.asarray(np.repeat(present, bn))
    return jnp.where(col_mask[None, :], out, 0.0)
