"""Pallas TPU kernel: integer-quantized matmul with fused dequantization.

This is the compute hot-spot of the paper's §6.1 quantization: the integer
dot product (N*M int mult + N*M int add) followed by the REAL rescale
(M float mult) and bias add (M float add).  On the PLC the win comes from
integer ALU ops being cheaper than float; on TPU the win is structural — the
MXU executes int8×int8→int32 at twice the bf16 rate (≈394 TOP/s vs 197 TF/s
on v5e) and the weights move over HBM at 1/4 the bytes of f32.

TPU adaptation (DESIGN.md §2): the per-element arithmetic of the ST loop is
re-tiled for the memory hierarchy — HBM→VMEM block staging via BlockSpecs,
128×128-aligned tiles for the MXU systolic array, int32 accumulation in a VMEM
scratch across the K grid dimension, and the dequant epilogue fused into the
final K step so the int32 accumulator never round-trips to HBM.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _qmatmul_kernel(
    x_ref,        # (bm, bk) int8/int16 — quantized activations
    w_ref,        # (bk, bn) int8/int16 — quantized weights
    scale_ref,    # (1, bn) f32 — combined x_scale * w_scale (per channel)
    bias_ref,     # (1, bn) f32
    out_ref,      # (bm, bn) f32
    acc_ref,      # (bm, bn) int32 VMEM scratch
    *,
    k_steps: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Integer dot product on the MXU with a wide accumulator.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # Fused dequantization: REAL rescale + bias (the paper's M float
        # mults + M float adds) applied once, in VMEM.
        out_ref[...] = (
            acc_ref[...].astype(jnp.float32) * scale_ref[...] + bias_ref[...]
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def qmatmul(
    xq: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Quantized matmul: ``out = (xq @ wq) * scale + bias`` in f32.

    Args:
      xq: (M, K) integer activations.
      wq: (K, N) integer weights.
      scale: () or (N,) f32 combined scale (x_scale * w_scale).
      bias: optional (N,) f32.
      block_*: VMEM tile sizes; MXU-aligned multiples of 128 on real TPUs.
      interpret: run the kernel body in Python (CPU validation mode).
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, (xq.shape, wq.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape {(m, k, n)} not divisible by blocks {(block_m, block_k, block_n)}"
    )
    scale2d = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (n,))[None, :]
    bias2d = (
        jnp.zeros((1, n), jnp.float32)
        if bias is None
        else jnp.asarray(bias, jnp.float32)[None, :]
    )
    k_steps = k // block_k
    grid = (m // block_m, n // block_n, k_steps)

    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, wq, scale2d, bias2d)
