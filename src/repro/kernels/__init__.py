"""Pallas TPU kernels for the paper's compute hot-spots.

* :mod:`repro.kernels.qmatmul` — int8/int16 quantized matmul with fused
  dequant epilogue (§6.1 quantization, MXU int8 path).
* :mod:`repro.kernels.sparse_matmul` — block-sparse matmul skipping pruned
  blocks (§6.2 operation skipping, made structural for the MXU).
* :mod:`repro.kernels.ssd_scan` — Mamba-2 SSD chunked scan (assigned
  mamba2/jamba architectures).

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import quantized_matmul, sparse_dense, ssd

__all__ = ["ops", "ref", "quantized_matmul", "sparse_dense", "ssd"]
