"""Pallas TPU kernels for the paper's compute hot-spots.

* :mod:`repro.kernels.qmatmul` — int8/int16 quantized matmul with fused
  dequant epilogue (§6.1 quantization, MXU int8 path).
* :mod:`repro.kernels.fused_mlp` — the whole detector MLP (every Dense
  layer, activations and SINT requantization included) in ONE dispatch,
  weights VMEM-resident (§6 loop-unrolling/fusion, re-hosted on TPU).
* :mod:`repro.kernels.sparse_matmul` — block-sparse matmul skipping pruned
  blocks (§6.2 operation skipping, made structural for the MXU).
* :mod:`repro.kernels.ssd_scan` — Mamba-2 SSD chunked scan (assigned
  mamba2/jamba architectures).

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
"""

from repro.kernels import ops, ref
from repro.kernels.fused_mlp import FUSED_ACTIVATIONS, FusedLayer
from repro.kernels.ops import (can_fuse, dense_stack, fused_forward,
                               model_fusable, quantized_matmul, sparse_dense,
                               ssd)

# NB: the fused_mlp *function* is deliberately not re-exported here — it
# would shadow the repro.kernels.fused_mlp submodule on the package object;
# call it via ops.fused_forward or import the submodule directly.
__all__ = ["ops", "ref", "FUSED_ACTIVATIONS", "FusedLayer",
           "can_fuse", "dense_stack", "fused_forward", "model_fusable",
           "quantized_matmul", "sparse_dense", "ssd"]
