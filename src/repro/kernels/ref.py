"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the mathematically transparent implementation the kernels
must match (asserted over shape/dtype sweeps in ``tests/test_kernels.py``).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.layers import ACTIVATIONS
from repro.core.prune import BlockSparseWeight


def qmatmul_ref(
    xq: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Integer matmul + REAL rescale + bias, f32 out (§6.1 arithmetic)."""
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    out = acc.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)
    if bias is not None:
        out = out + bias
    return out


def dense_layer_ref(x: jax.Array, p: Dict[str, jax.Array], act: str) -> jax.Array:
    """One Dense layer over an (M, K) batch, float or quantized (§6.1).

    The single-layer building block of :func:`fused_mlp_ref`; semantics match
    ``layers._quantized_matvec`` exactly (symmetric clip to ``[-qmax, qmax]``,
    int8 native int32 accumulation, INT/DINT emulated in f32).
    """
    if "qw" in p:
        qw = p["qw"]
        qmax = jnp.iinfo(qw.dtype).max
        xq = jnp.clip(jnp.round(x / p["x_scale"]), -qmax, qmax)
        if qw.dtype == jnp.int8:
            acc = jax.lax.dot_general(
                xq.astype(qw.dtype), qw, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            ).astype(jnp.float32)
        else:
            # f32 emulation without the int round-trip, matching the fused
            # kernel (int32's qmax is not f32-representable; the cast would
            # overflow at the clip rail).
            acc = jax.lax.dot_general(
                xq, qw.astype(jnp.float32),
                (((1,), (0,)), ((), ())),
            )
        y = acc * (p["x_scale"] * p["w_scale"])
        if p.get("b") is not None:
            y = y + p["b"]
    else:
        y = x @ p["w"]
        if p.get("b") is not None:
            y = y + p["b"]
    return ACTIVATIONS[act](y)


def fused_mlp_ref(
    x: jax.Array,
    stack: Sequence[Tuple[Dict[str, jax.Array], str]],
) -> jax.Array:
    """Whole Dense stack, layer by layer in pure jnp — the fused kernel's
    oracle.  ``stack`` is ``[(layer_params, activation_name), ...]`` in
    schedule order (the ``StreamEngine`` layer-stack layout)."""
    for p, act in stack:
        x = dense_layer_ref(x, p, act)
    return x


def grouped_mlp_ref(
    x: jax.Array,
    stacks: Sequence[Sequence[Tuple[Dict[str, jax.Array], str]]],
    *,
    kinds: Sequence[int],
    true_k0s: Sequence[int],
    n_outs: Sequence[int],
    tgt: jax.Array,
    n_pay: int,
) -> jax.Array:
    """The grouped megakernel's oracle: per-group true-dimension math.

    Each group's window rows ``x[g]`` are sliced to the group's true input
    width, folded through its OWN stack with :func:`dense_layer_ref` (softmax
    runs unmasked at the true width), then reduced by the head epilogue:
    ``kind`` 0 (logits) passes the final activations through, ``kind`` 1
    (score) writes ``mean((h - tgt)^2)`` over the group's true output lanes
    into payload lane 0.  Returns (G, M, n_pay) f32, zero-padded lanes.

    This is bit-exact against serving's per-group path by construction — the
    identical op sequence on identical values — so it doubles as the exact
    fallback forward inside ``ops.grouped_apply``.
    """
    pays = []
    for g, stack in enumerate(stacks):
        h = x[g][:, :true_k0s[g]]
        for p, act in stack:
            h = dense_layer_ref(h, p, act)
        if kinds[g] == 0:
            pay = h
        else:
            pay = jnp.mean(jnp.square(h - tgt[g][:, :n_outs[g]]),
                           axis=-1)[:, None]
        pad = n_pay - pay.shape[1]
        if pad:
            pay = jnp.pad(pay, ((0, 0), (0, pad)))
        pays.append(pay)
    return jnp.stack(pays)


def sparse_matmul_ref(x: jax.Array, w: BlockSparseWeight) -> jax.Array:
    """Dense reference for the block-sparse matmul: x @ densify(w)."""
    return x @ w.to_dense()


def ssd_scan_ref(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
) -> jax.Array:
    """Sequential (step-by-step) SSD recurrence — the ground-truth scan.

      S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * (x_t ⊗ B_t);  y_t = C_t · S_t
    """
    t, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # (H,P), (H,), (H,N), (H,N)
        decay = jnp.exp(dtt * a)[:, None, None]   # (H,1,1)
        state = decay * state + (dtt[:, None] * xt)[..., None] * bt[:, None, :]
        yt = jnp.einsum("hpn,hn->hp", state, ct)
        return state, yt

    init = jnp.zeros((h, p, n), jnp.float32)
    _, y = jax.lax.scan(step, init, (x, dt, b, c))
    return y


def ssd_chunked_ref(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    chunk: int = 128,
) -> jax.Array:
    """Chunk-parallel SSD (the kernel's math, pure jnp).

    Used as the FLOP-faithful train/prefill path on CPU: the intra-chunk work
    is batched matmuls (what the Pallas kernel does per grid step) and only a
    (H, P, N) state crosses chunks via a short ``lax.scan``.
    """
    t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    xc = x.reshape(nc, chunk, h, p)
    dtc = dt.reshape(nc, chunk, h)
    bc = b.reshape(nc, chunk, h, n)
    cc = c.reshape(nc, chunk, h, n)

    alpha = dtc * a                                   # (nc, L, H)
    s = jnp.cumsum(alpha, axis=1)                     # (nc, L, H)
    s_tot = s[:, -1]                                  # (nc, H)

    # Intra-chunk (no state dependency — fully parallel over chunks).
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    ds = s[:, :, None, :] - s[:, None, :, :]
    decay = jnp.exp(jnp.where(mask[None, :, :, None], ds, -jnp.inf))
    cb = jnp.einsum("clhn,cmhn->clmh", cc, bc)
    y_intra = jnp.einsum("clmh,cmh,cmhp->clhp", decay * cb, dtc, xc)

    # Chunk contributions to the carried state.
    w = jnp.exp(s_tot[:, None, :] - s) * dtc          # (nc, L, H)
    contrib = jnp.einsum("clh,clhp,clhn->chpn", w, xc, bc)

    def carry(state, inp):
        s_chunk, c_chunk, contrib_chunk, stot_chunk = inp
        # inter-chunk output: prior state read through decayed C
        y_inter = jnp.exp(s_chunk)[..., None] * jnp.einsum(
            "lhn,hpn->lhp", c_chunk, state
        )
        state = jnp.exp(stot_chunk)[:, None, None] * state + contrib_chunk
        return state, y_inter

    init = jnp.zeros((h, p, n), jnp.float32)
    _, y_inter = jax.lax.scan(carry, init, (s, cc, contrib, s_tot))
    return (y_intra + y_inter).reshape(t, h, p)


def ssd_update_ref(
    state: jax.Array,
    xt: jax.Array,
    dtt: jax.Array,
    a: jax.Array,
    bt: jax.Array,
    ct: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD step (decode path): returns (new_state, y_t)."""
    decay = jnp.exp(dtt * a)[:, None, None]
    state = decay * state + (dtt[:, None] * xt)[..., None] * bt[:, None, :]
    yt = jnp.einsum("hpn,hn->hp", state, ct)
    return state, yt
