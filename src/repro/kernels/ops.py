"""Jit'd public wrappers around the Pallas kernels, with shape handling,
padding and automatic CPU fallback to the pure-jnp oracles.

On this container (CPU) the kernels execute via ``interpret=True`` for
validation; model code calls these wrappers with ``backend='auto'`` so that
full-size runs use the oracle math (same numerics) while kernel tests pin
``backend='pallas'``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.layers import Dense, Input
from repro.core.prune import BlockSparseWeight
from repro.kernels import fused_mlp as _fused_mod
from repro.kernels import ref
from repro.kernels.fused_mlp import (FUSED_ACTIVATIONS, FusedLayer,
                                     fused_mlp as _fused_pallas)
from repro.kernels.qmatmul import qmatmul as _qmatmul_pallas
from repro.kernels.sparse_matmul import sparse_matmul as _sparse_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

LayerStack = Sequence[Tuple[Dict[str, jax.Array], str]]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Quantized matmul
# ---------------------------------------------------------------------------


def quantized_matmul(
    xq: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    backend: str = "auto",
    block: int = 128,
) -> jax.Array:
    """``(xq @ wq) * scale + bias`` with int accumulation, f32 out.

    backend: 'auto' (pallas on TPU else oracle), 'pallas' (interpret off-TPU),
    'ref'.
    """
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.qmatmul_ref(xq, wq, scale, bias)
    m, k = xq.shape
    n = wq.shape[1]
    # Small-M batches (e.g. the detection service's ready-stream windows, M =
    # fleet size) pad to a 32-row granule — the int8 MXU minimum tile — not to
    # a full 128 block, so a 16-stream step doesn't do 8x the row work.
    block_m = min(block, max(32, -(-m // 32) * 32))
    xp = _pad_to(_pad_to(xq, 0, block_m), 1, block)
    wp = _pad_to(_pad_to(wq, 0, block), 1, block)
    scale_p = _pad_to(jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (n,)), 0, block)
    # Normalize bias exactly like scale: ref.qmatmul_ref broadcasts whatever
    # it gets, so a scalar or non-f32 bias must become a f32 (n,) vector
    # before padding or the pallas path diverges from (or rejects) what the
    # oracle accepts.
    bias_p = None if bias is None else _pad_to(
        jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (n,)), 0, block)
    out = _qmatmul_pallas(
        xp, wp, scale_p, bias_p,
        block_m=block_m,
        block_n=block,
        block_k=block,
        interpret=not _on_tpu(),
    )
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Fused whole-MLP forward (the detector's single-dispatch verdict step)
# ---------------------------------------------------------------------------


def dense_stack(model, params) -> list:
    """(params, activation) per Dense node in schedule order — the
    layer-stack layout shared by ``StreamEngine``, ``sim.detector`` and
    :func:`fused_forward`."""
    return [(params[n.uid], n.layer.activation)
            for n in model.graph.nodes if isinstance(n.layer, Dense)]


def model_fusable(model, stack: LayerStack) -> bool:
    """True when ``stack`` (built from ``model``) can run as one fused
    dispatch: every node is Input/Dense — a non-Dense node would have been
    dropped from the stack — and the stack itself passes :func:`can_fuse`."""
    return (all(isinstance(n.layer, (Input, Dense))
                for n in model.graph.nodes)
            and can_fuse(stack))


def _padded_shapes(stack: LayerStack,
                   block_k: Optional[int]) -> Tuple[list, int]:
    """((Kp, Np, itemsize) per layer, effective block_k) after the wrapper's
    padding: every dim to the 128-lane tile, and layer 0's K additionally to
    a ``block_k`` multiple (the K grid needs whole slabs; the extra K lanes
    are zero activations times zero weight rows)."""
    pad128 = lambda v: -(-v // 128) * 128
    k0 = pad128(stack[0][0]["qw" if "qw" in stack[0][0] else "w"].shape[0])
    bk = pad128(min(block_k or _fused_mod.DEFAULT_BLOCK_K, k0))
    shapes = []
    for i, (p, _) in enumerate(stack):
        w = p["qw"] if "qw" in p else p["w"]
        kp, np_ = pad128(w.shape[0]), pad128(w.shape[1])
        if i == 0:
            kp = -(-kp // bk) * bk
        shapes.append((kp, np_, w.dtype.itemsize))
    return shapes, bk


def fuse_reason(stack: LayerStack, *,
                block_k: Optional[int] = None) -> Optional[str]:
    """None when a layer stack can run as one fused Pallas dispatch, else a
    human-readable reason it cannot — the diagnosable form of
    :func:`can_fuse`, surfaced by the engines' ``fused=True`` errors (a
    heterogeneous model-group fleet mixes many stacks, and "group 3 of 7 is
    not fusable" needs a *why* attached)."""
    if not stack:
        return "empty layer stack"
    for i, (p, act) in enumerate(stack):
        if act not in FUSED_ACTIVATIONS:
            return (f"layer {i} activation {act!r} is not pad-safe "
                    f"(fusable: {sorted(FUSED_ACTIVATIONS)})")
        if "qw" in p:
            if p["qw"].ndim != 2 or "w_scale" not in p or "x_scale" not in p:
                return (f"layer {i} quantized params are malformed "
                        "(need 2-D qw with w_scale and x_scale)")
        elif "w" not in p or p["w"].ndim != 2:
            return f"layer {i} has no 2-D dense weight"
    shapes, bk = _padded_shapes(stack, block_k)
    # Mirror fused_mlp's estimate at the worst-case 128-row tile.
    vmem = _fused_mod.fused_vmem_bytes(shapes, block_m=128, block_k=bk)
    if vmem > _fused_mod.VMEM_BUDGET_BYTES:
        return (f"VMEM resident set {vmem} bytes exceeds the kernel budget "
                f"{_fused_mod.VMEM_BUDGET_BYTES}")
    return None


def can_fuse(stack: LayerStack, *, block_k: Optional[int] = None) -> bool:
    """True when a layer stack can run as one fused Pallas dispatch.

    Requires every layer to be a plain or §6.1-quantized Dense param dict
    (``w``/``qw``) with a pad-safe (element-wise) activation, and the
    stack's VMEM *resident set* to fit the kernel budget.  The first layer
    is K-gridded, so only one ``block_k`` slab of it is charged — a wide
    input (or a wide autoencoder decoder output) no longer disqualifies
    fusion; each *later* layer must still fit in full (widest-layer check).
    Oversized stacks fall back to the per-layer path instead of failing at
    dispatch time.  (:func:`fuse_reason` is the diagnosable form.)
    """
    return fuse_reason(stack, block_k=block_k) is None


def _fused_layer(p: Dict[str, jax.Array], act: str, block: int) -> FusedLayer:
    """Pad one layer's params into the fused kernel's VMEM layout."""
    if "qw" in p:
        qw = p["qw"]
        n = qw.shape[1]
        wp = _pad_to(_pad_to(qw, 0, block), 1, block)
        combined = jnp.broadcast_to(
            jnp.asarray(p["x_scale"] * p["w_scale"], jnp.float32), (n,))
        scale = _pad_to(combined, 0, block)[None, :]
        x_scale = jnp.asarray(p["x_scale"], jnp.float32).reshape(1, 1)
    else:
        w = p["w"]
        n = w.shape[1]
        wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, block), 1, block)
        scale = x_scale = None
    b = p.get("b")
    bias = _pad_to(
        jnp.broadcast_to(
            jnp.zeros((), jnp.float32) if b is None
            else jnp.asarray(b, jnp.float32), (n,)),
        0, block)[None, :]
    return FusedLayer(w=wp, bias=bias, scale=scale, x_scale=x_scale, act=act)


def fused_forward(
    x: jax.Array,
    stack: LayerStack,
    *,
    backend: str = "auto",
    block: int = 128,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Whole Dense stack in ONE dispatch: ``x -> logits`` (M, N_last).

    ``stack`` is ``[(layer_params, activation), ...]`` in schedule order —
    the ``StreamEngine`` layer-stack layout; params may be float (``w``) or
    §6.1-quantized (``qw``/``w_scale``/``x_scale``) per layer.  All weights
    are staged into VMEM once and activations never round-trip to HBM
    between layers; SINT layers requantize in-kernel (int8 MXU layer to
    layer).  The first layer is K-gridded (``block_k``, default
    ``fused_mlp.DEFAULT_BLOCK_K``): wide inputs stream through VMEM one
    slab per grid step, and inputs not divisible by the slab are zero-padded
    up to it (annihilated by zero weight rows — same contract as the lane
    padding).

    backend: 'auto' (pallas on TPU else oracle), 'pallas' (interpret
    off-TPU), 'ref'.
    """
    if not can_fuse(stack, block_k=block_k):
        raise ValueError("layer stack is not fusable; see ops.can_fuse")
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.fused_mlp_ref(x, stack)
    m = x.shape[0]
    n_out = (stack[-1][0]["qw"] if "qw" in stack[-1][0]
             else stack[-1][0]["w"]).shape[1]
    # Small-M row granule, like quantized_matmul: a fleet-sized batch pads to
    # the minimum sublane tile of the narrowest dtype in the stack (int8 MXU
    # wants 32 rows, f32 8), not to a full 128 block.
    granule = 32 if any(
        "qw" in p and p["qw"].dtype == jnp.int8 for p, _ in stack) else 8
    block_m = min(block, max(granule, -(-m // granule) * granule))
    layers = [_fused_layer(p, act, block) for p, act in stack]
    shapes, bk = _padded_shapes(stack, block_k)
    kp = shapes[0][0]       # layer-0 K after lane + K-slab padding
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, block_m), 1, kp)
    if layers[0].w.shape[0] != kp:
        layers[0] = layers[0]._replace(w=_pad_to(layers[0].w, 0, kp))
    out = _fused_pallas(xp, layers, block_m=block_m, block_k=bk,
                        interpret=not _on_tpu())
    return out[:m, :n_out]


# ---------------------------------------------------------------------------
# Block-sparse matmul (pruning op-skip)
# ---------------------------------------------------------------------------


def sparse_dense(
    x: jax.Array,
    w: BlockSparseWeight,
    *,
    backend: str = "auto",
) -> jax.Array:
    """Pruned matmul skipping zero blocks entirely."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.sparse_matmul_ref(x, w)
    return _sparse_pallas(x, w, interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# SSD scan (mamba2)
# ---------------------------------------------------------------------------


def ssd(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    n_groups: int = 1,
    chunk: int = 128,
    backend: str = "auto",
) -> jax.Array:
    """Batched, grouped SSD scan.

    Args:
      x:  (B, T, H, P);  dt: (B, T, H);  a: (H,)
      b/c: (B, T, G, N) with G groups broadcast over H heads.
    Returns (B, T, H, P).
    """
    bsz, t, h, p = x.shape
    g = b.shape[2]
    reps = h // g
    b_full = jnp.repeat(b, reps, axis=2)
    c_full = jnp.repeat(c, reps, axis=2)

    if backend == "ref":
        return jax.vmap(ref.ssd_scan_ref, in_axes=(0, 0, None, 0, 0))(
            x, dt, a, b_full, c_full
        )
    if backend in ("chunked",) or (backend == "auto" and not _on_tpu()):
        ck = min(chunk, t) if t % min(chunk, t) == 0 else t
        fn = functools.partial(ref.ssd_chunked_ref, chunk=ck)
        return jax.vmap(fn, in_axes=(0, 0, None, 0, 0))(x, dt, a, b_full, c_full)

    pad_t = (-t) % chunk
    fn = functools.partial(_ssd_pallas, chunk=chunk, interpret=not _on_tpu())
    xp = _pad_to(x, 1, chunk)
    dtp = _pad_to(dt, 1, chunk)
    bp = _pad_to(b_full, 1, chunk)
    cp = _pad_to(c_full, 1, chunk)
    y = jax.vmap(fn, in_axes=(0, 0, None, 0, 0))(xp, dtp, a, bp, cp)
    return y[:, :t] if pad_t else y
