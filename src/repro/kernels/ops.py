"""Jit'd public wrappers around the Pallas kernels, with shape handling,
padding and automatic CPU fallback to the pure-jnp oracles.

On this container (CPU) the kernels execute via ``interpret=True`` for
validation; model code calls these wrappers with ``backend='auto'`` so that
full-size runs use the oracle math (same numerics) while kernel tests pin
``backend='pallas'``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import ACTIVATIONS, Dense, Input
from repro.core.prune import BlockSparseWeight
from repro.kernels import fused_mlp as _fused_mod
from repro.kernels import ref
from repro.kernels.fused_mlp import (FUSED_ACTIVATIONS, GROUPED_ACT_IDS,
                                     GROUPED_KIND_LOGITS, GROUPED_KIND_SCORE,
                                     FusedLayer, GroupedLayer,
                                     fused_mlp as _fused_pallas,
                                     grouped_fused_mlp as _grouped_pallas,
                                     grouped_vmem_bytes)
from repro.kernels.qmatmul import qmatmul as _qmatmul_pallas
from repro.kernels.sparse_matmul import sparse_matmul as _sparse_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

LayerStack = Sequence[Tuple[Dict[str, jax.Array], str]]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Quantized matmul
# ---------------------------------------------------------------------------


def quantized_matmul(
    xq: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    backend: str = "auto",
    block: int = 128,
) -> jax.Array:
    """``(xq @ wq) * scale + bias`` with int accumulation, f32 out.

    backend: 'auto' (pallas on TPU else oracle), 'pallas' (interpret off-TPU),
    'ref'.
    """
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.qmatmul_ref(xq, wq, scale, bias)
    m, k = xq.shape
    n = wq.shape[1]
    # Small-M batches (e.g. the detection service's ready-stream windows, M =
    # fleet size) pad to a 32-row granule — the int8 MXU minimum tile — not to
    # a full 128 block, so a 16-stream step doesn't do 8x the row work.
    block_m = min(block, max(32, -(-m // 32) * 32))
    xp = _pad_to(_pad_to(xq, 0, block_m), 1, block)
    wp = _pad_to(_pad_to(wq, 0, block), 1, block)
    scale_p = _pad_to(jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (n,)), 0, block)
    # Normalize bias exactly like scale: ref.qmatmul_ref broadcasts whatever
    # it gets, so a scalar or non-f32 bias must become a f32 (n,) vector
    # before padding or the pallas path diverges from (or rejects) what the
    # oracle accepts.
    bias_p = None if bias is None else _pad_to(
        jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (n,)), 0, block)
    out = _qmatmul_pallas(
        xp, wp, scale_p, bias_p,
        block_m=block_m,
        block_n=block,
        block_k=block,
        interpret=not _on_tpu(),
    )
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Fused whole-MLP forward (the detector's single-dispatch verdict step)
# ---------------------------------------------------------------------------


def dense_stack(model, params) -> list:
    """(params, activation) per Dense node in schedule order — the
    layer-stack layout shared by ``StreamEngine``, ``sim.detector`` and
    :func:`fused_forward`."""
    return [(params[n.uid], n.layer.activation)
            for n in model.graph.nodes if isinstance(n.layer, Dense)]


def model_fusable(model, stack: LayerStack) -> bool:
    """True when ``stack`` (built from ``model``) can run as one fused
    dispatch: every node is Input/Dense — a non-Dense node would have been
    dropped from the stack — and the stack itself passes :func:`can_fuse`."""
    return (all(isinstance(n.layer, (Input, Dense))
                for n in model.graph.nodes)
            and can_fuse(stack))


def _padded_shapes(stack: LayerStack,
                   block_k: Optional[int]) -> Tuple[list, int]:
    """((Kp, Np, itemsize) per layer, effective block_k) after the wrapper's
    padding: every dim to the 128-lane tile, and layer 0's K additionally to
    a ``block_k`` multiple (the K grid needs whole slabs; the extra K lanes
    are zero activations times zero weight rows)."""
    pad128 = lambda v: -(-v // 128) * 128
    k0 = pad128(stack[0][0]["qw" if "qw" in stack[0][0] else "w"].shape[0])
    bk = pad128(min(block_k or _fused_mod.DEFAULT_BLOCK_K, k0))
    shapes = []
    for i, (p, _) in enumerate(stack):
        w = p["qw"] if "qw" in p else p["w"]
        kp, np_ = pad128(w.shape[0]), pad128(w.shape[1])
        if i == 0:
            kp = -(-kp // bk) * bk
        shapes.append((kp, np_, w.dtype.itemsize))
    return shapes, bk


def _layer_reason(i: int, p: Dict[str, jax.Array], act: str, *,
                  final: bool, allow_final_softmax: bool) -> Optional[str]:
    """Per-layer fusability check shared by the single-stack and grouped
    paths; the grouped megakernel masks a FINAL-layer softmax in-kernel, so
    only it sets ``allow_final_softmax``."""
    if act not in FUSED_ACTIVATIONS and not (allow_final_softmax and final
                                             and act == "softmax"):
        return (f"layer {i} activation {act!r} is not pad-safe "
                f"(fusable: {sorted(FUSED_ACTIVATIONS)})")
    if "qw" in p:
        if p["qw"].ndim != 2 or "w_scale" not in p or "x_scale" not in p:
            return (f"layer {i} quantized params are malformed "
                    "(need 2-D qw with w_scale and x_scale)")
    elif "w" not in p or p["w"].ndim != 2:
        return f"layer {i} has no 2-D dense weight"
    return None


def fuse_reason(stack: LayerStack, *,
                block_k: Optional[int] = None) -> Optional[str]:
    """None when a layer stack can run as one fused Pallas dispatch, else a
    human-readable reason it cannot — the diagnosable form of
    :func:`can_fuse`, surfaced by the engines' ``fused=True`` errors (a
    heterogeneous model-group fleet mixes many stacks, and "group 3 of 7 is
    not fusable" needs a *why* attached)."""
    if not stack:
        return "empty layer stack"
    for i, (p, act) in enumerate(stack):
        r = _layer_reason(i, p, act, final=(i == len(stack) - 1),
                          allow_final_softmax=False)
        if r is not None:
            return r
    shapes, bk = _padded_shapes(stack, block_k)
    # Mirror fused_mlp's estimate at the worst-case 128-row tile.
    vmem = _fused_mod.fused_vmem_bytes(shapes, block_m=128, block_k=bk)
    if vmem > _fused_mod.VMEM_BUDGET_BYTES:
        return (f"VMEM resident set {vmem} bytes exceeds the kernel budget "
                f"{_fused_mod.VMEM_BUDGET_BYTES}")
    return None


def can_fuse(stack: LayerStack, *, block_k: Optional[int] = None) -> bool:
    """True when a layer stack can run as one fused Pallas dispatch.

    Requires every layer to be a plain or §6.1-quantized Dense param dict
    (``w``/``qw``) with a pad-safe (element-wise) activation, and the
    stack's VMEM *resident set* to fit the kernel budget.  The first layer
    is K-gridded, so only one ``block_k`` slab of it is charged — a wide
    input (or a wide autoencoder decoder output) no longer disqualifies
    fusion; each *later* layer must still fit in full (widest-layer check).
    Oversized stacks fall back to the per-layer path instead of failing at
    dispatch time.  (:func:`fuse_reason` is the diagnosable form.)
    """
    return fuse_reason(stack, block_k=block_k) is None


def _fused_layer(p: Dict[str, jax.Array], act: str, block: int) -> FusedLayer:
    """Pad one layer's params into the fused kernel's VMEM layout."""
    if "qw" in p:
        qw = p["qw"]
        n = qw.shape[1]
        wp = _pad_to(_pad_to(qw, 0, block), 1, block)
        combined = jnp.broadcast_to(
            jnp.asarray(p["x_scale"] * p["w_scale"], jnp.float32), (n,))
        scale = _pad_to(combined, 0, block)[None, :]
        x_scale = jnp.asarray(p["x_scale"], jnp.float32).reshape(1, 1)
    else:
        w = p["w"]
        n = w.shape[1]
        wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, block), 1, block)
        scale = x_scale = None
    b = p.get("b")
    bias = _pad_to(
        jnp.broadcast_to(
            jnp.zeros((), jnp.float32) if b is None
            else jnp.asarray(b, jnp.float32), (n,)),
        0, block)[None, :]
    return FusedLayer(w=wp, bias=bias, scale=scale, x_scale=x_scale, act=act)


def fused_forward(
    x: jax.Array,
    stack: LayerStack,
    *,
    backend: str = "auto",
    block: int = 128,
    block_k: Optional[int] = None,
) -> jax.Array:
    """Whole Dense stack in ONE dispatch: ``x -> logits`` (M, N_last).

    ``stack`` is ``[(layer_params, activation), ...]`` in schedule order —
    the ``StreamEngine`` layer-stack layout; params may be float (``w``) or
    §6.1-quantized (``qw``/``w_scale``/``x_scale``) per layer.  All weights
    are staged into VMEM once and activations never round-trip to HBM
    between layers; SINT layers requantize in-kernel (int8 MXU layer to
    layer).  The first layer is K-gridded (``block_k``, default
    ``fused_mlp.DEFAULT_BLOCK_K``): wide inputs stream through VMEM one
    slab per grid step, and inputs not divisible by the slab are zero-padded
    up to it (annihilated by zero weight rows — same contract as the lane
    padding).

    backend: 'auto' (pallas on TPU else oracle), 'pallas' (interpret
    off-TPU), 'ref'.
    """
    if not can_fuse(stack, block_k=block_k):
        raise ValueError("layer stack is not fusable; see ops.can_fuse")
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.fused_mlp_ref(x, stack)
    m = x.shape[0]
    n_out = (stack[-1][0]["qw"] if "qw" in stack[-1][0]
             else stack[-1][0]["w"]).shape[1]
    # Small-M row granule, like quantized_matmul: a fleet-sized batch pads to
    # the minimum sublane tile of the narrowest dtype in the stack (int8 MXU
    # wants 32 rows, f32 8), not to a full 128 block.
    granule = 32 if any(
        "qw" in p and p["qw"].dtype == jnp.int8 for p, _ in stack) else 8
    block_m = min(block, max(granule, -(-m // granule) * granule))
    layers = [_fused_layer(p, act, block) for p, act in stack]
    shapes, bk = _padded_shapes(stack, block_k)
    kp = shapes[0][0]       # layer-0 K after lane + K-slab padding
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, block_m), 1, kp)
    if layers[0].w.shape[0] != kp:
        layers[0] = layers[0]._replace(w=_pad_to(layers[0].w, 0, kp))
    out = _fused_pallas(xp, layers, block_m=block_m, block_k=bk,
                        interpret=not _on_tpu())
    return out[:m, :n_out]


# ---------------------------------------------------------------------------
# Grouped megakernel packing: a whole heterogeneous fleet in ONE dispatch
# ---------------------------------------------------------------------------


def _stack_w(p: Dict[str, jax.Array]) -> jax.Array:
    return p["qw"] if "qw" in p else p["w"]


def _pad128(v: int) -> int:
    return -(-v // 128) * 128


def _grouped_widths(stacks: Sequence[LayerStack],
                    k0: Optional[int] = None) -> Tuple[int, list]:
    """Tight-union arena geometry: per position l, K is the previous union
    width and N the widest active layer — widened to every *finished* group's
    true output so skip pass-through never truncates a payload."""
    n_layers = max(len(s) for s in stacks)
    true_k0s = [int(_stack_w(s[0][0]).shape[0]) for s in stacks]
    k0 = max(true_k0s) if k0 is None else k0
    assert k0 >= max(true_k0s), (k0, true_k0s)
    widths, prev = [], k0
    for l in range(n_layers):
        n = max(int(_stack_w(s[l][0]).shape[1]) if len(s) > l
                else int(_stack_w(s[-1][0]).shape[1]) for s in stacks)
        widths.append((prev, n))
        prev = n
    return k0, widths


def grouped_fuse_reason(stacks: Sequence[LayerStack], *,
                        names: Optional[Sequence[str]] = None,
                        k0: Optional[int] = None) -> Optional[str]:
    """None when a fleet of layer stacks can pack into ONE grouped megakernel
    dispatch, else a human-readable reason.

    Beyond the per-stack :func:`fuse_reason` checks (relaxed to allow a
    FINAL-layer softmax, which the grouped kernel masks in-kernel), the
    packed arena needs one MXU mode per layer position — mixed weight dtypes
    at a position cannot share a dot — and the *union* (widest-slab) arena
    must fit the VMEM budget.  The VMEM message carries the per-group slab
    accounting so ``fused=True`` failures on grouped fleets are diagnosable.
    """
    if not stacks:
        return "no layer stacks"
    names = list(names) if names is not None else [
        f"group{g}" for g in range(len(stacks))]
    for g, stack in enumerate(stacks):
        if not stack:
            return f"{names[g]}: empty layer stack"
        for i, (p, act) in enumerate(stack):
            r = _layer_reason(i, p, act, final=(i == len(stack) - 1),
                              allow_final_softmax=True)
            if r is not None:
                return f"{names[g]}: {r}"
    n_layers = max(len(s) for s in stacks)
    for l in range(n_layers):
        dtypes = {jnp.dtype(_stack_w(s[l][0]).dtype)
                  for s in stacks if len(s) > l}
        if len(dtypes) > 1:
            return (f"layer position {l} mixes weight dtypes "
                    f"{sorted(d.name for d in dtypes)} across groups; the "
                    "packed arena needs one MXU mode per position")
    k0u, widths = _grouped_widths(stacks, k0)
    pos_shapes = []
    prev = _pad128(k0u)
    for l, (_, n) in enumerate(widths):
        itemsize = next(jnp.dtype(_stack_w(s[l][0]).dtype).itemsize
                        for s in stacks if len(s) > l)
        pos_shapes.append((prev, _pad128(n), itemsize))
        prev = _pad128(n)
    vmem = grouped_vmem_bytes(pos_shapes, block_m=128,
                              n_pay=pos_shapes[-1][1])
    if vmem > _fused_mod.VMEM_BUDGET_BYTES:
        slabs = []
        for g, stack in enumerate(stacks):
            b = sum(_pad128(int(_stack_w(p).shape[0]))
                    * _pad128(int(_stack_w(p).shape[1]))
                    * jnp.dtype(_stack_w(p).dtype).itemsize
                    for p, _ in stack)
            slabs.append((names[g], b))
        widest = max(slabs, key=lambda s: s[1])[0]
        detail = ", ".join(f"{n}={b}B" for n, b in slabs)
        return (f"packed-arena VMEM resident set {vmem} bytes exceeds the "
                f"kernel budget {_fused_mod.VMEM_BUDGET_BYTES} (per-group "
                f"slabs: {detail}; widest slab {widest!r} drives the union "
                "arena) — serve this fleet per-group")
    return None


def can_fuse_grouped(stacks: Sequence[LayerStack], *,
                     names: Optional[Sequence[str]] = None,
                     k0: Optional[int] = None) -> bool:
    """True when a fleet of layer stacks can run as ONE grouped megakernel
    dispatch (:func:`grouped_fuse_reason` is the diagnosable form)."""
    return grouped_fuse_reason(stacks, names=names, k0=k0) is None


@dataclasses.dataclass(frozen=True)
class GroupedPlan:
    """Static (trace-time) description of a packed heterogeneous fleet.

    Every field is a plain int/str tuple, so the plan is hashable and two
    fleets with identical *geometry* (shapes, dtypes, activations, head
    kinds) produce equal plans — serving keys compiled megakernel steps on
    the plan, and identity-distinct same-shape fleets share one executable.
    The actual numbers (weight arenas, scales, meta table, per-group true
    stacks) live in the companion arrays pytree from
    :func:`build_grouped_plan` and enter the jitted step as runtime operands.
    """

    n_groups: int
    k0: int                                   # union input width (tight)
    n_layers: int
    widths: Tuple[Tuple[int, int], ...]       # union (K, N) per position
    modes: Tuple[str, ...]                    # 'real' | 'int8' | 'emu'
    qmaxes: Tuple[int, ...]
    pos_acts: Tuple[Tuple[str, ...], ...]     # distinct acts per position
    acts: Tuple[Tuple[str, ...], ...]         # per group: its own stack acts
    skips: Tuple[Tuple[int, ...], ...]        # per group x position
    kinds: Tuple[int, ...]                    # GROUPED_KIND_* per group
    n_outs: Tuple[int, ...]                   # true final width per group
    true_k0s: Tuple[int, ...]                 # true input width per group
    n_out: int                                # union true final width
    payload_width: int                        # max(n_out | 1) over groups


def build_grouped_plan(
    stacks: Sequence[LayerStack],
    kinds: Sequence[int],
    *,
    k0: Optional[int] = None,
) -> Tuple[GroupedPlan, Dict]:
    """Pack per-group layer stacks into the megakernel's arena layout.

    Returns ``(plan, arrays)``: the hashable static plan and a pytree of
    device arrays — per-position ``w``/``scale``/``bias``/``x_scale``
    arenas, the (G, 2+2L) int32 ``meta`` table, and the per-group true
    ``stacks`` params (for the bit-exact per-group fallback forward).  Pad
    slots follow the zero-row contract; skip slots keep ``x_scale`` at 1 so
    ``round(h/x_scale)`` never divides by zero.

    ``k0`` widens the union input beyond the widest true input (serving
    passes the window width so heads whose ``prepare`` drops trailing lanes
    — the forecast head — are handled by zero weight rows instead of
    per-group slicing).
    """
    reason = grouped_fuse_reason(stacks, k0=k0)
    if reason is not None:
        raise ValueError(f"fleet cannot pack into one dispatch: {reason}")
    n_groups = len(stacks)
    n_layers = max(len(s) for s in stacks)
    k0u, widths = _grouped_widths(stacks, k0)
    true_k0s = tuple(int(_stack_w(s[0][0]).shape[0]) for s in stacks)
    n_outs = tuple(int(_stack_w(s[-1][0]).shape[1]) for s in stacks)
    kinds = tuple(int(k) for k in kinds)
    assert len(kinds) == n_groups, (len(kinds), n_groups)
    payload_width = max(n if kind == GROUPED_KIND_LOGITS else 1
                        for n, kind in zip(n_outs, kinds))

    modes, qmaxes, pos_acts = [], [], []
    w_arenas, s_arenas, b_arenas, xs_arenas = [], [], [], []
    act_ids = np.zeros((n_groups, n_layers), np.int32)
    skips = np.zeros((n_groups, n_layers), np.int32)
    for l, (k, n) in enumerate(widths):
        dtype = jnp.dtype(next(_stack_w(s[l][0]).dtype
                               for s in stacks if len(s) > l))
        mode = _fused_mod._layer_mode(dtype)
        modes.append(mode)
        qmaxes.append(int(jnp.iinfo(dtype).max) if mode != "real" else 0)
        w = np.zeros((n_groups, k, n), dtype)
        sc = np.zeros((n_groups, 1, n), np.float32)
        bi = np.zeros((n_groups, 1, n), np.float32)
        xs = np.ones((n_groups, 1), np.float32)
        acts_here = set()
        for g, stack in enumerate(stacks):
            if len(stack) <= l:
                skips[g, l] = 1
                continue
            p, act = stack[l]
            wg = np.asarray(_stack_w(p))
            kg, ng = wg.shape
            w[g, :kg, :ng] = wg
            if "qw" in p:
                combined = np.broadcast_to(
                    np.asarray(p["x_scale"] * p["w_scale"], np.float32),
                    (ng,))
                sc[g, 0, :ng] = combined
                xs[g, 0] = np.float32(p["x_scale"])
            b = p.get("b")
            if b is not None:
                bi[g, 0, :ng] = np.broadcast_to(
                    np.asarray(b, np.float32), (ng,))
            act_ids[g, l] = GROUPED_ACT_IDS[act]
            acts_here.add(act)
        pos_acts.append(tuple(sorted(acts_here)))
        w_arenas.append(jnp.asarray(w))
        s_arenas.append(jnp.asarray(sc))
        b_arenas.append(jnp.asarray(bi))
        xs_arenas.append(jnp.asarray(xs))

    meta = np.concatenate(
        [np.asarray(kinds, np.int32)[:, None],
         np.asarray(n_outs, np.int32)[:, None], act_ids, skips], axis=1)
    arrays = {
        "w": w_arenas, "scale": s_arenas, "bias": b_arenas,
        "x_scale": xs_arenas, "meta": jnp.asarray(meta),
        "stacks": [[{k: jnp.asarray(v) for k, v in p.items()
                     if v is not None} for p, _ in stack]
                   for stack in stacks],
    }
    plan = GroupedPlan(
        n_groups=n_groups, k0=k0u, n_layers=n_layers,
        widths=tuple(widths), modes=tuple(modes), qmaxes=tuple(qmaxes),
        pos_acts=tuple(pos_acts),
        acts=tuple(tuple(act for _, act in stack) for stack in stacks),
        skips=tuple(tuple(int(v) for v in row) for row in skips),
        kinds=kinds, n_outs=n_outs, true_k0s=true_k0s,
        n_out=max(n_outs), payload_width=payload_width)
    return plan, arrays


def _grouped_acts_batched(y: jax.Array, plan: GroupedPlan, l: int,
                          meta: jax.Array) -> jax.Array:
    """Per-group activation select on a batched (G, M, N) tile, mirroring
    the kernel: statically unrolled over the position's distinct activations,
    softmax masked to each group's true output width."""
    act_id = meta[:, 2 + l][:, None, None]
    out = y
    for name in plan.pos_acts[l]:
        if name == "softmax":
            n_outs = meta[:, 1][:, None, None]
            lanes = jnp.arange(y.shape[-1])[None, None, :]
            z = jnp.where(lanes < n_outs, y, -jnp.inf)
            zmax = jnp.max(z, axis=-1, keepdims=True)
            ez = jnp.exp(z - zmax)
            a = ez / jnp.sum(ez, axis=-1, keepdims=True)
        else:
            a = ACTIVATIONS[name](y)
        if len(plan.pos_acts[l]) == 1:
            out = a
        else:
            out = jnp.where(act_id == GROUPED_ACT_IDS[name], a, out)
    return out


def _fit_cols(x: jax.Array, n: int) -> jax.Array:
    if x.shape[-1] < n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, n - x.shape[-1])]
        return jnp.pad(x, pad)
    return x[..., :n]


def _grouped_forward_batched(x: jax.Array, plan: GroupedPlan,
                             arrays: Dict) -> jax.Array:
    """Tight-union batched forward for uniformly-int8 fleets: one batched
    int8 dot per layer position (int32 accumulation is associativity-exact,
    so this bit-matches the per-group path) instead of one dot per group
    per layer."""
    meta = arrays["meta"]
    h = x
    for l in range(plan.n_layers):
        xs = arrays["x_scale"][l][:, :, None]
        hq = jnp.clip(jnp.round(h / xs), -plan.qmaxes[l], plan.qmaxes[l])
        acc = jax.lax.dot_general(
            hq.astype(jnp.int8), arrays["w"][l],
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
        y = acc * arrays["scale"][l] + arrays["bias"][l]
        y = _grouped_acts_batched(y, plan, l, meta)
        if any(row[l] for row in plan.skips):
            skip = meta[:, 2 + plan.n_layers + l][:, None, None]
            y = jnp.where(skip == 1, _fit_cols(h, y.shape[-1]), y)
        h = y
    return h


def grouped_apply(
    x: jax.Array,
    plan: GroupedPlan,
    arrays: Dict,
    tgt: jax.Array,
    *,
    backend: str = "auto",
    block: int = 128,
) -> jax.Array:
    """One forward + head-epilogue dispatch for a packed heterogeneous fleet.

    Args:
      x: (G, M, plan.k0) f32 — every group's window rows, zero-padded on the
        trailing lanes up to the union input width.
      plan/arrays: from :func:`build_grouped_plan`; ``arrays`` may be traced
        operands inside a jitted step (the plan alone is static).
      tgt: (G, M, plan.n_out) f32 epilogue targets — the window itself for
        reconstruction heads, its tail reading for forecast heads, the
        center row for margin heads, zeros for classifiers.

    Returns (G, M, plan.payload_width) f32 payloads: logits for
    ``GROUPED_KIND_LOGITS`` groups, the score in lane 0 for
    ``GROUPED_KIND_SCORE`` groups.

    backend: 'auto' (pallas on TPU else oracle math), 'pallas' (interpret
    off-TPU), 'ref'.  The oracle path runs per-group true-dimension math
    (bit-exact against per-group serving for every scheme); uniformly-int8
    fleets batch each layer position into one grouped int8 dot, which is
    *also* bit-exact (int32 accumulation).
    """
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        if all(m == "int8" for m in plan.modes):
            h = _grouped_forward_batched(x, plan, arrays)
            with jax.named_scope("head_epilogue"):
                pays = []
                for g in range(plan.n_groups):
                    n = plan.n_outs[g]
                    if plan.kinds[g] == GROUPED_KIND_LOGITS:
                        pay = h[g][:, :n]
                    else:
                        pay = jnp.mean(
                            jnp.square(h[g][:, :n] - tgt[g][:, :n]),
                            axis=-1)[:, None]
                    pays.append(_fit_cols(pay, plan.payload_width))
                return jnp.stack(pays)
        with jax.named_scope("head_epilogue"):
            return ref.grouped_mlp_ref(
                x, [list(zip(arrays["stacks"][g],
                             plan.acts[g])) for g in range(plan.n_groups)],
                kinds=plan.kinds, true_k0s=plan.true_k0s,
                n_outs=plan.n_outs, tgt=tgt, n_pay=plan.payload_width)

    # Pallas path: pad the tight arenas to the 128-lane tile and dispatch
    # the whole fleet as one pallas_call.
    g, m, _ = x.shape
    granule = 32 if any(mode == "int8" for mode in plan.modes) else 8
    block_m = min(block, max(granule, -(-m // granule) * granule))
    mp = -(-m // block_m) * block_m
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 1, block_m), 2,
                 _pad128(plan.k0))
    layers = []
    for l in range(plan.n_layers):
        np_ = _pad128(plan.widths[l][1])
        layers.append(GroupedLayer(
            w=_pad_to(_pad_to(arrays["w"][l], 1, 128), 2, 128),
            bias=_pad_to(arrays["bias"][l], 2, np_),
            scale=_pad_to(arrays["scale"][l], 2, np_),
            x_scale=arrays["x_scale"][l]))
    n_last_p = _pad128(plan.widths[-1][1])
    tgtp = _pad_to(_pad_to(tgt.astype(jnp.float32), 1, block_m), 2, n_last_p)
    n_pay_p = _pad128(plan.payload_width)
    out = _grouped_pallas(
        xp, layers, arrays["meta"], tgtp, n_pay=n_pay_p,
        modes=plan.modes, qmaxes=plan.qmaxes, pos_acts=plan.pos_acts,
        block_m=block_m, interpret=not _on_tpu())
    return out[:, :m, :plan.payload_width]


def sparse_dense(
    x: jax.Array,
    w: BlockSparseWeight,
    *,
    backend: str = "auto",
) -> jax.Array:
    """Pruned matmul skipping zero blocks entirely."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.sparse_matmul_ref(x, w)
    return _sparse_pallas(x, w, interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# SSD scan (mamba2)
# ---------------------------------------------------------------------------


def ssd(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    n_groups: int = 1,
    chunk: int = 128,
    backend: str = "auto",
) -> jax.Array:
    """Batched, grouped SSD scan.

    Args:
      x:  (B, T, H, P);  dt: (B, T, H);  a: (H,)
      b/c: (B, T, G, N) with G groups broadcast over H heads.
    Returns (B, T, H, P).
    """
    bsz, t, h, p = x.shape
    g = b.shape[2]
    reps = h // g
    b_full = jnp.repeat(b, reps, axis=2)
    c_full = jnp.repeat(c, reps, axis=2)

    if backend == "ref":
        return jax.vmap(ref.ssd_scan_ref, in_axes=(0, 0, None, 0, 0))(
            x, dt, a, b_full, c_full
        )
    if backend in ("chunked",) or (backend == "auto" and not _on_tpu()):
        ck = min(chunk, t) if t % min(chunk, t) == 0 else t
        fn = functools.partial(ref.ssd_chunked_ref, chunk=ck)
        return jax.vmap(fn, in_axes=(0, 0, None, 0, 0))(x, dt, a, b_full, c_full)

    pad_t = (-t) % chunk
    fn = functools.partial(_ssd_pallas, chunk=chunk, interpret=not _on_tpu())
    xp = _pad_to(x, 1, chunk)
    dtp = _pad_to(dt, 1, chunk)
    bp = _pad_to(b_full, 1, chunk)
    cp = _pad_to(c_full, 1, chunk)
    y = jax.vmap(fn, in_axes=(0, 0, None, 0, 0))(xp, dtp, a, bp, cp)
    return y[:, :t] if pad_t else y
