"""Jit'd public wrappers around the Pallas kernels, with shape handling,
padding and automatic CPU fallback to the pure-jnp oracles.

On this container (CPU) the kernels execute via ``interpret=True`` for
validation; model code calls these wrappers with ``backend='auto'`` so that
full-size runs use the oracle math (same numerics) while kernel tests pin
``backend='pallas'``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.prune import BlockSparseWeight
from repro.kernels import ref
from repro.kernels.qmatmul import qmatmul as _qmatmul_pallas
from repro.kernels.sparse_matmul import sparse_matmul as _sparse_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Quantized matmul
# ---------------------------------------------------------------------------


def quantized_matmul(
    xq: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    backend: str = "auto",
    block: int = 128,
) -> jax.Array:
    """``(xq @ wq) * scale + bias`` with int accumulation, f32 out.

    backend: 'auto' (pallas on TPU else oracle), 'pallas' (interpret off-TPU),
    'ref'.
    """
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.qmatmul_ref(xq, wq, scale, bias)
    m, k = xq.shape
    n = wq.shape[1]
    # Small-M batches (e.g. the detection service's ready-stream windows, M =
    # fleet size) pad to a 32-row granule — the int8 MXU minimum tile — not to
    # a full 128 block, so a 16-stream step doesn't do 8x the row work.
    block_m = min(block, max(32, -(-m // 32) * 32))
    xp = _pad_to(_pad_to(xq, 0, block_m), 1, block)
    wp = _pad_to(_pad_to(wq, 0, block), 1, block)
    scale_p = _pad_to(jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (n,)), 0, block)
    bias_p = None if bias is None else _pad_to(bias, 0, block)
    out = _qmatmul_pallas(
        xp, wp, scale_p, bias_p,
        block_m=block_m,
        block_n=block,
        block_k=block,
        interpret=not _on_tpu(),
    )
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Block-sparse matmul (pruning op-skip)
# ---------------------------------------------------------------------------


def sparse_dense(
    x: jax.Array,
    w: BlockSparseWeight,
    *,
    backend: str = "auto",
) -> jax.Array:
    """Pruned matmul skipping zero blocks entirely."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.sparse_matmul_ref(x, w)
    return _sparse_pallas(x, w, interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# SSD scan (mamba2)
# ---------------------------------------------------------------------------


def ssd(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    n_groups: int = 1,
    chunk: int = 128,
    backend: str = "auto",
) -> jax.Array:
    """Batched, grouped SSD scan.

    Args:
      x:  (B, T, H, P);  dt: (B, T, H);  a: (H,)
      b/c: (B, T, G, N) with G groups broadcast over H heads.
    Returns (B, T, H, P).
    """
    bsz, t, h, p = x.shape
    g = b.shape[2]
    reps = h // g
    b_full = jnp.repeat(b, reps, axis=2)
    c_full = jnp.repeat(c, reps, axis=2)

    if backend == "ref":
        return jax.vmap(ref.ssd_scan_ref, in_axes=(0, 0, None, 0, 0))(
            x, dt, a, b_full, c_full
        )
    if backend in ("chunked",) or (backend == "auto" and not _on_tpu()):
        ck = min(chunk, t) if t % min(chunk, t) == 0 else t
        fn = functools.partial(ref.ssd_chunked_ref, chunk=ck)
        return jax.vmap(fn, in_axes=(0, 0, None, 0, 0))(x, dt, a, b_full, c_full)

    pad_t = (-t) % chunk
    fn = functools.partial(_ssd_pallas, chunk=chunk, interpret=not _on_tpu())
    xp = _pad_to(x, 1, chunk)
    dtp = _pad_to(dt, 1, chunk)
    bp = _pad_to(b_full, 1, chunk)
    cp = _pad_to(c_full, 1, chunk)
    y = jax.vmap(fn, in_axes=(0, 0, None, 0, 0))(xp, dtp, a, bp, cp)
    return y[:, :t] if pad_t else y
