"""Compatibility shims for Pallas API renames across jax versions."""

from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; accept either.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
