"""Pallas TPU kernel: a whole Dense-stack MLP fused into ONE dispatch.

The paper's §6 domain-specific optimizations (loop unrolling, fused quantized
arithmetic) exist because per-layer dispatch overhead dominates small-MLP
inference on constrained hardware.  The TPU port had the same pathology: each
fleet verdict step issued one ``qmatmul``/matmul dispatch per Dense layer with
inter-layer HBM round-trips, for detector-sized networks whose weights fit in
a sliver of one VMEM tile.

This kernel executes **all** Dense layers in a single ``pallas_call``:

* every layer's weights/scales/biases are staged HBM→VMEM once,
* activations stay resident in VMEM between layers (no HBM round-trip),
* activation functions are applied in-kernel,
* quantized (SINT) layers run an **in-kernel requantize epilogue**: the f32
  activations out of layer *i* are re-quantized against layer *i+1*'s
  activation scale inside the kernel, so the int8 MXU path is used
  layer-to-layer without host-side ``x/x_scale`` re-quantization dispatches.

Layer kinds (mirroring ``layers._quantized_matvec`` / §6.1 semantics):

* f32 weights      -> f32 MXU dot + bias,
* int8 (SINT)      -> in-kernel quantize, int8×int8→int32 MXU dot, fused
                      rescale+bias dequant epilogue,
* int16/int32      -> in-kernel quantize with the integer grid's clip, dot
  (INT/DINT)          emulated in f32 (no int16/int32 MXU mode — DESIGN.md §2),
                      rescale+bias.

Grid: ``(M/block_m, K0/block_k)`` — rows tile as before, and the **first
layer is K-gridded**: its input width (the detector's 400-wide window — the
widest dimension of both §7 workloads) streams through VMEM one
``(block_m, block_k)`` x-tile and ``(block_k, N1)`` weight slab at a time,
accumulating into a VMEM scratch (int32 for an int8 first layer — split-K
integer accumulation is exact — f32 otherwise).  The last K step runs the
dequant/bias/activation epilogue and every remaining layer back to back in
VMEM.  This lifts the old whole-net-in-VMEM restriction to a *widest-layer*
budget: the VMEM bill charges layer 0 one K-slab (not its full K extent)
plus every later layer in full, so wide-input stacks — and the autoencoder's
400-wide decoder output — fuse as long as each resident layer fits.

Padding contract (the ``ops.fused_forward`` wrapper maintains it): weights
are zero-padded, scales and biases zero-padded, so padded output lanes carry
``act(0)`` garbage that the *zero-padded rows* of the next layer's weights
annihilate — correctness never depends on masking inside the kernel.  K
padding of layer 0 is likewise zero x-lanes times zero weight rows.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layers import ACTIVATIONS
from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

# Softmax normalizes across the (padded) lane axis, so it cannot run on
# zero-padded tiles without masking; every other §4.1 activation is
# element-wise and pad-safe (garbage lanes are killed by the next layer's
# zero-padded weight rows).
FUSED_ACTIVATIONS = frozenset(ACTIVATIONS) - {"softmax"}

# VMEM is ~16 MB/core; the *resident set* — one K-slab of the first layer,
# every later layer in full, one activation tile per layer, the split-K
# scratch — must fit, since the whole point is never spilling to HBM between
# layers.  ops.can_fuse applies the same budget so auto-selection falls back
# to the per-layer path for oversized stacks instead of failing at dispatch.
VMEM_BUDGET_BYTES = 12 * 2**20

# Default K tile of the first layer: one 512-lane slab covers both detector
# workloads' padded 400-wide input in a single K step (nk=1 — bit-identical
# to un-split accumulation) while capping the resident slab for wider inputs.
DEFAULT_BLOCK_K = 512


class FusedLayer(NamedTuple):
    """One Dense layer, padded and ready for the fused kernel.

    ``w``: (Kp, Np) f32 weights, or int8/int16/int32 quantized weights.
    ``bias``: (1, Np) f32 (zeros when the layer has no bias).
    ``scale``: (1, Np) f32 combined x_scale * w_scale — quantized layers only.
    ``x_scale``: (1, 1) f32 activation scale — quantized layers only.
    ``act``: activation name from ``FUSED_ACTIVATIONS``.
    """

    w: jax.Array
    bias: jax.Array
    scale: Optional[jax.Array]
    x_scale: Optional[jax.Array]
    act: str

    @property
    def quantized(self) -> bool:
        return self.scale is not None


def _layer_mode(dtype) -> str:
    if dtype == jnp.float32:
        return "real"
    if dtype == jnp.int8:
        return "int8"
    if dtype in (jnp.int16, jnp.int32):
        return "emu"
    raise ValueError(f"unsupported fused-layer weight dtype {dtype}")


def fused_vmem_bytes(
    layer_shapes: Sequence[tuple],
    *,
    block_m: int = 128,
    block_k: Optional[int] = None,
) -> int:
    """The kernel's VMEM resident-set estimate for a padded stack.

    ``layer_shapes`` is ``[(K, N, itemsize), ...]``; layer 0 is charged one
    ``block_k`` K-slab (the K grid streams the rest), later layers their full
    extent, plus per-layer activation tiles, 8 B/lane of scale+bias, and the
    split-K accumulator scratch.  ``ops.can_fuse`` and :func:`fused_mlp`
    share this accounting so auto-selection and dispatch agree.
    """
    k0 = layer_shapes[0][0]
    bk = min(block_k or DEFAULT_BLOCK_K, k0)
    total = block_m * layer_shapes[0][1] * 4        # split-K scratch
    for i, (k, n, itemsize) in enumerate(layer_shapes):
        k_res = bk if i == 0 else k
        total += k_res * n * itemsize + 8 * n
        # Activation tiles: max(k_res, n) covers both the layer's input tile
        # (the x slab for layer 0) and its output tile at the 4 B f32 width.
        total += block_m * max(k_res, n) * 4
    return total


def _fused_kernel(*refs, modes: Sequence[str], acts: Sequence[str],
                  qmaxes: Sequence[int], nk: int):
    """One grid step: accumulate layer 0 over a K slab; on the last K step,
    run its epilogue and every remaining layer in VMEM."""
    x_ref, out_ref, acc_ref = refs[0], refs[-2], refs[-1]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # -- first layer: partial product over this (block_m, block_k) tile.
    idx = 1
    if modes[0] == "real":
        w0_ref, b0_ref = refs[idx], refs[idx + 1]
        idx += 2
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w0_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        def _finish0(acc):
            return acc + b0_ref[...]
    else:
        xs0_ref, w0_ref, s0_ref, b0_ref = refs[idx:idx + 4]
        idx += 4
        # In-kernel (re)quantization is element-wise, so quantizing one K
        # slab at a time is identical to quantizing the whole row.
        hq = jnp.clip(jnp.round(x_ref[...] / xs0_ref[0, 0]),
                      -qmaxes[0], qmaxes[0])
        if modes[0] == "int8":
            # int32 scratch: split-K integer accumulation is exact, so the
            # K grid cannot perturb SINT numerics.
            acc_ref[...] += jax.lax.dot_general(
                hq.astype(jnp.int8), w0_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
        else:
            acc_ref[...] += jax.lax.dot_general(
                hq, w0_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            )

        def _finish0(acc):
            return acc.astype(jnp.float32) * s0_ref[...] + b0_ref[...]

    rest = refs[idx:-2]

    @pl.when(j == nk - 1)
    def _epilogue():
        h = ACTIVATIONS[acts[0]](_finish0(acc_ref[...]).astype(jnp.float32))
        i = 0
        for mode, act, qmax in zip(modes[1:], acts[1:], qmaxes[1:]):
            if mode == "real":
                w_ref, b_ref = rest[i], rest[i + 1]
                i += 2
                h = jax.lax.dot_general(
                    h, w_ref[...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ) + b_ref[...]
            else:
                xs_ref, w_ref, s_ref, b_ref = rest[i:i + 4]
                i += 4
                xs = xs_ref[0, 0]
                # In-kernel requantization: the §6.1 activation-quantization
                # step, fused so f32 activations never leave VMEM.
                hq = jnp.clip(jnp.round(h / xs), -qmax, qmax)
                if mode == "int8":
                    acc = jax.lax.dot_general(
                        hq.astype(jnp.int8), w_ref[...],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    ).astype(jnp.float32)
                else:
                    # INT/DINT: integer grid, f32 arithmetic (emulated — the
                    # MXU has no int16/int32 mode and int32 accumulation
                    # overflows).
                    acc = jax.lax.dot_general(
                        hq, w_ref[...].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                    )
                # Fused dequant epilogue: REAL rescale + bias, still in VMEM.
                h = acc * s_ref[...] + b_ref[...]
            h = ACTIVATIONS[act](h)
        out_ref[...] = h


def fused_mlp(
    x: jax.Array,
    layers: Sequence[FusedLayer],
    *,
    block_m: int = 128,
    block_k: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Run a whole Dense stack as ONE Pallas dispatch.

    Args:
      x: (M, K0) f32 activations; M divisible by ``block_m``, K0 and every
        layer dim already padded to the 128-lane tile.
      layers: padded :class:`FusedLayer` specs; layer i's ``w.shape[0]`` must
        equal layer i-1's ``w.shape[1]`` (and ``x.shape[1]`` for layer 0).
      block_m: row tile.
      block_k: K tile of the *first* layer (default ``DEFAULT_BLOCK_K``,
        clamped to K0); K0 must divide by it.  One K step (nk=1) is
        bit-identical to the un-split kernel; more steps stream the first
        layer's weights through VMEM one slab at a time.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns (M, N_last) f32 logits (padded lanes included — callers slice).
    """
    if not layers:
        raise ValueError("fused_mlp needs at least one layer")
    m, k0 = x.shape
    assert m % block_m == 0, (m, block_m)
    assert k0 % 128 == 0, x.shape
    block_k = min(block_k or DEFAULT_BLOCK_K, k0)
    assert block_k % 128 == 0, block_k
    assert k0 % block_k == 0, (k0, block_k)
    nk = k0 // block_k
    prev_n = k0
    shapes = []
    for i, layer in enumerate(layers):
        k, n = layer.w.shape
        assert k == prev_n, f"layer {i}: K {k} != previous width {prev_n}"
        assert k % 128 == 0 and n % 128 == 0, layer.w.shape
        assert layer.bias.shape == (1, n), layer.bias.shape
        if layer.quantized:
            assert layer.scale.shape == (1, n), layer.scale.shape
            assert layer.x_scale.shape == (1, 1), layer.x_scale.shape
        if layer.act not in FUSED_ACTIVATIONS:
            raise ValueError(
                f"activation {layer.act!r} is not fusable (padded lanes); "
                f"pick from {sorted(FUSED_ACTIVATIONS)}")
        shapes.append((k, n, layer.w.dtype.itemsize))
        prev_n = n
    vmem_bytes = fused_vmem_bytes(shapes, block_m=block_m, block_k=block_k)
    if vmem_bytes > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"fused stack needs ~{vmem_bytes} B of VMEM resident (> "
            f"{VMEM_BUDGET_BYTES}); the K grid already streams the first "
            "layer, so a later layer is too wide to keep in VMEM — fall "
            "back to the per-layer path")

    modes = tuple(_layer_mode(layer.w.dtype) for layer in layers)
    acts = tuple(layer.act for layer in layers)
    qmaxes = tuple(
        int(jnp.iinfo(layer.w.dtype).max) if layer.quantized else 0
        for layer in layers
    )

    n1 = layers[0].w.shape[1]
    acc_dtype = jnp.int32 if modes[0] == "int8" else jnp.float32

    operands = [x]
    in_specs = [pl.BlockSpec((block_m, block_k), lambda i, j: (i, j))]
    for li, layer in enumerate(layers):
        k, n = layer.w.shape
        if layer.quantized:
            operands.append(layer.x_scale)
            in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                                         memory_space=pltpu.SMEM))
        operands.append(layer.w)
        if li == 0:
            # The only K-gridded operand: one (block_k, N1) slab per K step.
            in_specs.append(pl.BlockSpec((block_k, n), lambda i, j: (j, 0)))
        else:
            in_specs.append(pl.BlockSpec((k, n), lambda i, j: (0, 0)))
        if layer.quantized:
            operands.append(layer.scale)
            in_specs.append(pl.BlockSpec((1, n), lambda i, j: (0, 0)))
        operands.append(layer.bias)
        in_specs.append(pl.BlockSpec((1, n), lambda i, j: (0, 0)))

    n_last = layers[-1].w.shape[1]
    return pl.pallas_call(
        functools.partial(_fused_kernel, modes=modes, acts=acts,
                          qmaxes=qmaxes, nk=nk),
        grid=(m // block_m, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, n_last), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_last), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, n1), acc_dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
