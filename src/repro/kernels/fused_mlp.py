"""Pallas TPU kernel: the whole detector MLP fused into ONE dispatch.

The paper's §6 domain-specific optimizations (loop unrolling, fused quantized
arithmetic) exist because per-layer dispatch overhead dominates small-MLP
inference on constrained hardware.  The TPU port had the same pathology: each
fleet verdict step issued one ``qmatmul``/matmul dispatch per Dense layer with
inter-layer HBM round-trips, for a 400-64-32-16-2 network whose *entire*
weight set (f32: ~110 KB, SINT: ~28 KB) fits in a sliver of one VMEM tile.

This kernel executes **all** Dense layers in a single ``pallas_call``:

* every layer's weights/scales/biases are staged HBM→VMEM once,
* activations stay resident in VMEM between layers (no HBM round-trip),
* activation functions are applied in-kernel,
* quantized (SINT) layers run an **in-kernel requantize epilogue**: the f32
  activations out of layer *i* are re-quantized against layer *i+1*'s
  activation scale inside the kernel, so the int8 MXU path is used
  layer-to-layer without host-side ``x/x_scale`` re-quantization dispatches.

Layer kinds (mirroring ``layers._quantized_matvec`` / §6.1 semantics):

* f32 weights      -> f32 MXU dot + bias,
* int8 (SINT)      -> in-kernel quantize, int8×int8→int32 MXU dot, fused
                      rescale+bias dequant epilogue,
* int16/int32      -> in-kernel quantize with the integer grid's clip, dot
  (INT/DINT)          emulated in f32 (no int16/int32 MXU mode — DESIGN.md §2),
                      rescale+bias.

Grid: (M/block_m,) — M is the only dimension worth tiling; all K/N dims of
the detector are single 128-lane tiles after padding.  Padding contract (the
``ops.fused_forward`` wrapper maintains it): weights are zero-padded, scales
and biases zero-padded, so padded output lanes carry ``act(0)`` garbage that
the *zero-padded rows* of the next layer's weights annihilate — correctness
never depends on masking inside the kernel.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layers import ACTIVATIONS
from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

# Softmax normalizes across the (padded) lane axis, so it cannot run on
# zero-padded tiles without masking; every other §4.1 activation is
# element-wise and pad-safe (garbage lanes are killed by the next layer's
# zero-padded weight rows).
FUSED_ACTIVATIONS = frozenset(ACTIVATIONS) - {"softmax"}

# VMEM is ~16 MB/core; weights + one activation tile per layer must fit since
# the whole point is never spilling to HBM between layers.  ops.can_fuse
# applies the same budget so auto-selection falls back to the per-layer path
# for oversized stacks instead of failing at dispatch time.
VMEM_BUDGET_BYTES = 12 * 2**20


class FusedLayer(NamedTuple):
    """One Dense layer, padded and ready for the fused kernel.

    ``w``: (Kp, Np) f32 weights, or int8/int16/int32 quantized weights.
    ``bias``: (1, Np) f32 (zeros when the layer has no bias).
    ``scale``: (1, Np) f32 combined x_scale * w_scale — quantized layers only.
    ``x_scale``: (1, 1) f32 activation scale — quantized layers only.
    ``act``: activation name from ``FUSED_ACTIVATIONS``.
    """

    w: jax.Array
    bias: jax.Array
    scale: Optional[jax.Array]
    x_scale: Optional[jax.Array]
    act: str

    @property
    def quantized(self) -> bool:
        return self.scale is not None


def _layer_mode(dtype) -> str:
    if dtype == jnp.float32:
        return "real"
    if dtype == jnp.int8:
        return "int8"
    if dtype in (jnp.int16, jnp.int32):
        return "emu"
    raise ValueError(f"unsupported fused-layer weight dtype {dtype}")


def _fused_kernel(*refs, modes: Sequence[str], acts: Sequence[str],
                  qmaxes: Sequence[int]):
    """One grid step: a (block_m, K0) row tile through every layer in VMEM."""
    x_ref, out_ref = refs[0], refs[-1]
    h = x_ref[...]
    idx = 1
    for mode, act, qmax in zip(modes, acts, qmaxes):
        if mode == "real":
            w_ref, b_ref = refs[idx], refs[idx + 1]
            idx += 2
            h = jax.lax.dot_general(
                h, w_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) + b_ref[...]
        else:
            xs_ref, w_ref, s_ref, b_ref = refs[idx:idx + 4]
            idx += 4
            xs = xs_ref[0, 0]
            # In-kernel (re)quantization: N float mults + round + symmetric
            # clip — the §6.1 activation-quantization step, fused so the f32
            # activations never leave VMEM between layers.
            hq = jnp.clip(jnp.round(h / xs), -qmax, qmax)
            if mode == "int8":
                acc = jax.lax.dot_general(
                    hq.astype(jnp.int8), w_ref[...],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                ).astype(jnp.float32)
            else:
                # INT/DINT: integer grid, f32 arithmetic (emulated — the MXU
                # has no int16/int32 mode and int32 accumulation overflows).
                acc = jax.lax.dot_general(
                    hq, w_ref[...].astype(jnp.float32),
                    (((1,), (0,)), ((), ())),
                )
            # Fused dequant epilogue: REAL rescale + bias, still in VMEM.
            h = acc * s_ref[...] + b_ref[...]
        h = ACTIVATIONS[act](h)
    out_ref[...] = h


def fused_mlp(
    x: jax.Array,
    layers: Sequence[FusedLayer],
    *,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Run a whole Dense stack as ONE Pallas dispatch.

    Args:
      x: (M, K0) f32 activations; M divisible by ``block_m``, K0 and every
        layer dim already padded to the 128-lane tile.
      layers: padded :class:`FusedLayer` specs; layer i's ``w.shape[0]`` must
        equal layer i-1's ``w.shape[1]`` (and ``x.shape[1]`` for layer 0).
      block_m: row tile; the only gridded dimension.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns (M, N_last) f32 logits (padded lanes included — callers slice).
    """
    if not layers:
        raise ValueError("fused_mlp needs at least one layer")
    m, k0 = x.shape
    assert m % block_m == 0, (m, block_m)
    assert k0 % 128 == 0, x.shape
    prev_n = k0
    vmem_bytes = 0
    for i, layer in enumerate(layers):
        k, n = layer.w.shape
        assert k == prev_n, f"layer {i}: K {k} != previous width {prev_n}"
        assert k % 128 == 0 and n % 128 == 0, layer.w.shape
        assert layer.bias.shape == (1, n), layer.bias.shape
        if layer.quantized:
            assert layer.scale.shape == (1, n), layer.scale.shape
            assert layer.x_scale.shape == (1, 1), layer.x_scale.shape
        if layer.act not in FUSED_ACTIVATIONS:
            raise ValueError(
                f"activation {layer.act!r} is not fusable (padded lanes); "
                f"pick from {sorted(FUSED_ACTIVATIONS)}")
        vmem_bytes += layer.w.size * layer.w.dtype.itemsize + 8 * n
        vmem_bytes += block_m * max(k, n) * 4
        prev_n = n
    if vmem_bytes > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"fused stack needs ~{vmem_bytes} B of VMEM (> "
            f"{VMEM_BUDGET_BYTES}); this kernel is for whole-net-in-VMEM "
            "MLPs — fall back to the per-layer path")

    modes = tuple(_layer_mode(layer.w.dtype) for layer in layers)
    acts = tuple(layer.act for layer in layers)
    qmaxes = tuple(
        int(jnp.iinfo(layer.w.dtype).max) if layer.quantized else 0
        for layer in layers
    )

    operands = [x]
    in_specs = [pl.BlockSpec((block_m, k0), lambda i: (i, 0))]
    for layer in layers:
        k, n = layer.w.shape
        if layer.quantized:
            operands.append(layer.x_scale)
            in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0),
                                         memory_space=pltpu.SMEM))
        operands.append(layer.w)
        in_specs.append(pl.BlockSpec((k, n), lambda i: (0, 0)))
        if layer.quantized:
            operands.append(layer.scale)
            in_specs.append(pl.BlockSpec((1, n), lambda i: (0, 0)))
        operands.append(layer.bias)
        in_specs.append(pl.BlockSpec((1, n), lambda i: (0, 0)))

    n_last = layers[-1].w.shape[1]
    return pl.pallas_call(
        functools.partial(_fused_kernel, modes=modes, acts=acts,
                          qmaxes=qmaxes),
        grid=(m // block_m,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, n_last), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_last), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*operands)
