"""Pallas TPU kernel: a whole Dense-stack MLP fused into ONE dispatch.

The paper's §6 domain-specific optimizations (loop unrolling, fused quantized
arithmetic) exist because per-layer dispatch overhead dominates small-MLP
inference on constrained hardware.  The TPU port had the same pathology: each
fleet verdict step issued one ``qmatmul``/matmul dispatch per Dense layer with
inter-layer HBM round-trips, for detector-sized networks whose weights fit in
a sliver of one VMEM tile.

This kernel executes **all** Dense layers in a single ``pallas_call``:

* every layer's weights/scales/biases are staged HBM→VMEM once,
* activations stay resident in VMEM between layers (no HBM round-trip),
* activation functions are applied in-kernel,
* quantized (SINT) layers run an **in-kernel requantize epilogue**: the f32
  activations out of layer *i* are re-quantized against layer *i+1*'s
  activation scale inside the kernel, so the int8 MXU path is used
  layer-to-layer without host-side ``x/x_scale`` re-quantization dispatches.

Layer kinds (mirroring ``layers._quantized_matvec`` / §6.1 semantics):

* f32 weights      -> f32 MXU dot + bias,
* int8 (SINT)      -> in-kernel quantize, int8×int8→int32 MXU dot, fused
                      rescale+bias dequant epilogue,
* int16/int32      -> in-kernel quantize with the integer grid's clip, dot
  (INT/DINT)          emulated in f32 (no int16/int32 MXU mode — DESIGN.md §2),
                      rescale+bias.

Grid: ``(M/block_m, K0/block_k)`` — rows tile as before, and the **first
layer is K-gridded**: its input width (the detector's 400-wide window — the
widest dimension of both §7 workloads) streams through VMEM one
``(block_m, block_k)`` x-tile and ``(block_k, N1)`` weight slab at a time,
accumulating into a VMEM scratch (int32 for an int8 first layer — split-K
integer accumulation is exact — f32 otherwise).  The last K step runs the
dequant/bias/activation epilogue and every remaining layer back to back in
VMEM.  This lifts the old whole-net-in-VMEM restriction to a *widest-layer*
budget: the VMEM bill charges layer 0 one K-slab (not its full K extent)
plus every later layer in full, so wide-input stacks — and the autoencoder's
400-wide decoder output — fuse as long as each resident layer fits.

Padding contract (the ``ops.fused_forward`` wrapper maintains it): weights
are zero-padded, scales and biases zero-padded, so padded output lanes carry
``act(0)`` garbage that the *zero-padded rows* of the next layer's weights
annihilate — correctness never depends on masking inside the kernel.  K
padding of layer 0 is likewise zero x-lanes times zero weight rows.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layers import ACTIVATIONS
from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

# Softmax normalizes across the (padded) lane axis, so it cannot run on
# zero-padded tiles without masking; every other §4.1 activation is
# element-wise and pad-safe (garbage lanes are killed by the next layer's
# zero-padded weight rows).  The *grouped* megakernel additionally supports a
# FINAL-layer softmax by masking against the group's true output width in
# SMEM (the one place a softmax head can fuse).
FUSED_ACTIVATIONS = frozenset(ACTIVATIONS) - {"softmax"}

# Stable activation-id table for the grouped kernel's SMEM act selector
# (softmax included: it is legal at the final position, where the kernel
# masks pad lanes against the group's true output width).
GROUPED_ACT_IDS = {name: i for i, name in enumerate(sorted(ACTIVATIONS))}

# Grouped-payload kinds: what the in-kernel epilogue writes per group.
GROUPED_KIND_LOGITS = 0     # classifier: the final activations themselves
GROUPED_KIND_SCORE = 1      # score head: mean squared error vs the target

# VMEM is ~16 MB/core; the *resident set* — one K-slab of the first layer,
# every later layer in full, one activation tile per layer, the split-K
# scratch — must fit, since the whole point is never spilling to HBM between
# layers.  ops.can_fuse applies the same budget so auto-selection falls back
# to the per-layer path for oversized stacks instead of failing at dispatch.
VMEM_BUDGET_BYTES = 12 * 2**20

# Default K tile of the first layer: one 512-lane slab covers both detector
# workloads' padded 400-wide input in a single K step (nk=1 — bit-identical
# to un-split accumulation) while capping the resident slab for wider inputs.
DEFAULT_BLOCK_K = 512


class FusedLayer(NamedTuple):
    """One Dense layer, padded and ready for the fused kernel.

    ``w``: (Kp, Np) f32 weights, or int8/int16/int32 quantized weights.
    ``bias``: (1, Np) f32 (zeros when the layer has no bias).
    ``scale``: (1, Np) f32 combined x_scale * w_scale — quantized layers only.
    ``x_scale``: (1, 1) f32 activation scale — quantized layers only.
    ``act``: activation name from ``FUSED_ACTIVATIONS``.
    """

    w: jax.Array
    bias: jax.Array
    scale: Optional[jax.Array]
    x_scale: Optional[jax.Array]
    act: str

    @property
    def quantized(self) -> bool:
        return self.scale is not None


def _layer_mode(dtype) -> str:
    if dtype == jnp.float32:
        return "real"
    if dtype == jnp.int8:
        return "int8"
    if dtype in (jnp.int16, jnp.int32):
        return "emu"
    raise ValueError(f"unsupported fused-layer weight dtype {dtype}")


def fused_vmem_bytes(
    layer_shapes: Sequence[tuple],
    *,
    block_m: int = 128,
    block_k: Optional[int] = None,
) -> int:
    """The kernel's VMEM resident-set estimate for a padded stack.

    ``layer_shapes`` is ``[(K, N, itemsize), ...]``; layer 0 is charged one
    ``block_k`` K-slab (the K grid streams the rest), later layers their full
    extent, plus per-layer activation tiles, 8 B/lane of scale+bias, and the
    split-K accumulator scratch.  ``ops.can_fuse`` and :func:`fused_mlp`
    share this accounting so auto-selection and dispatch agree.
    """
    k0 = layer_shapes[0][0]
    bk = min(block_k or DEFAULT_BLOCK_K, k0)
    total = block_m * layer_shapes[0][1] * 4        # split-K scratch
    for i, (k, n, itemsize) in enumerate(layer_shapes):
        k_res = bk if i == 0 else k
        total += k_res * n * itemsize + 8 * n
        # Activation tiles: max(k_res, n) covers both the layer's input tile
        # (the x slab for layer 0) and its output tile at the 4 B f32 width.
        total += block_m * max(k_res, n) * 4
    return total


def _fused_kernel(*refs, modes: Sequence[str], acts: Sequence[str],
                  qmaxes: Sequence[int], nk: int):
    """One grid step: accumulate layer 0 over a K slab; on the last K step,
    run its epilogue and every remaining layer in VMEM."""
    x_ref, out_ref, acc_ref = refs[0], refs[-2], refs[-1]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # -- first layer: partial product over this (block_m, block_k) tile.
    idx = 1
    if modes[0] == "real":
        w0_ref, b0_ref = refs[idx], refs[idx + 1]
        idx += 2
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w0_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        def _finish0(acc):
            return acc + b0_ref[...]
    else:
        xs0_ref, w0_ref, s0_ref, b0_ref = refs[idx:idx + 4]
        idx += 4
        # In-kernel (re)quantization is element-wise, so quantizing one K
        # slab at a time is identical to quantizing the whole row.
        hq = jnp.clip(jnp.round(x_ref[...] / xs0_ref[0, 0]),
                      -qmaxes[0], qmaxes[0])
        if modes[0] == "int8":
            # int32 scratch: split-K integer accumulation is exact, so the
            # K grid cannot perturb SINT numerics.
            acc_ref[...] += jax.lax.dot_general(
                hq.astype(jnp.int8), w0_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
        else:
            acc_ref[...] += jax.lax.dot_general(
                hq, w0_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            )

        def _finish0(acc):
            return acc.astype(jnp.float32) * s0_ref[...] + b0_ref[...]

    rest = refs[idx:-2]

    @pl.when(j == nk - 1)
    def _epilogue():
        h = ACTIVATIONS[acts[0]](_finish0(acc_ref[...]).astype(jnp.float32))
        i = 0
        for mode, act, qmax in zip(modes[1:], acts[1:], qmaxes[1:]):
            if mode == "real":
                w_ref, b_ref = rest[i], rest[i + 1]
                i += 2
                h = jax.lax.dot_general(
                    h, w_ref[...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ) + b_ref[...]
            else:
                xs_ref, w_ref, s_ref, b_ref = rest[i:i + 4]
                i += 4
                xs = xs_ref[0, 0]
                # In-kernel requantization: the §6.1 activation-quantization
                # step, fused so f32 activations never leave VMEM.
                hq = jnp.clip(jnp.round(h / xs), -qmax, qmax)
                if mode == "int8":
                    acc = jax.lax.dot_general(
                        hq.astype(jnp.int8), w_ref[...],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    ).astype(jnp.float32)
                else:
                    # INT/DINT: integer grid, f32 arithmetic (emulated — the
                    # MXU has no int16/int32 mode and int32 accumulation
                    # overflows).
                    acc = jax.lax.dot_general(
                        hq, w_ref[...].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                    )
                # Fused dequant epilogue: REAL rescale + bias, still in VMEM.
                h = acc * s_ref[...] + b_ref[...]
            h = ACTIVATIONS[act](h)
        out_ref[...] = h


def fused_mlp(
    x: jax.Array,
    layers: Sequence[FusedLayer],
    *,
    block_m: int = 128,
    block_k: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Run a whole Dense stack as ONE Pallas dispatch.

    Args:
      x: (M, K0) f32 activations; M divisible by ``block_m``, K0 and every
        layer dim already padded to the 128-lane tile.
      layers: padded :class:`FusedLayer` specs; layer i's ``w.shape[0]`` must
        equal layer i-1's ``w.shape[1]`` (and ``x.shape[1]`` for layer 0).
      block_m: row tile.
      block_k: K tile of the *first* layer (default ``DEFAULT_BLOCK_K``,
        clamped to K0); K0 must divide by it.  One K step (nk=1) is
        bit-identical to the un-split kernel; more steps stream the first
        layer's weights through VMEM one slab at a time.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns (M, N_last) f32 logits (padded lanes included — callers slice).
    """
    if not layers:
        raise ValueError("fused_mlp needs at least one layer")
    m, k0 = x.shape
    assert m % block_m == 0, (m, block_m)
    assert k0 % 128 == 0, x.shape
    block_k = min(block_k or DEFAULT_BLOCK_K, k0)
    assert block_k % 128 == 0, block_k
    assert k0 % block_k == 0, (k0, block_k)
    nk = k0 // block_k
    prev_n = k0
    shapes = []
    for i, layer in enumerate(layers):
        k, n = layer.w.shape
        assert k == prev_n, f"layer {i}: K {k} != previous width {prev_n}"
        assert k % 128 == 0 and n % 128 == 0, layer.w.shape
        assert layer.bias.shape == (1, n), layer.bias.shape
        if layer.quantized:
            assert layer.scale.shape == (1, n), layer.scale.shape
            assert layer.x_scale.shape == (1, 1), layer.x_scale.shape
        if layer.act not in FUSED_ACTIVATIONS:
            raise ValueError(
                f"activation {layer.act!r} is not fusable (padded lanes); "
                f"pick from {sorted(FUSED_ACTIVATIONS)}")
        shapes.append((k, n, layer.w.dtype.itemsize))
        prev_n = n
    vmem_bytes = fused_vmem_bytes(shapes, block_m=block_m, block_k=block_k)
    if vmem_bytes > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"fused stack needs ~{vmem_bytes} B of VMEM resident (> "
            f"{VMEM_BUDGET_BYTES}); the K grid already streams the first "
            "layer, so a later layer is too wide to keep in VMEM — fall "
            "back to the per-layer path")

    modes = tuple(_layer_mode(layer.w.dtype) for layer in layers)
    acts = tuple(layer.act for layer in layers)
    qmaxes = tuple(
        int(jnp.iinfo(layer.w.dtype).max) if layer.quantized else 0
        for layer in layers
    )

    n1 = layers[0].w.shape[1]
    acc_dtype = jnp.int32 if modes[0] == "int8" else jnp.float32

    operands = [x]
    in_specs = [pl.BlockSpec((block_m, block_k), lambda i, j: (i, j))]
    for li, layer in enumerate(layers):
        k, n = layer.w.shape
        if layer.quantized:
            operands.append(layer.x_scale)
            in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                                         memory_space=pltpu.SMEM))
        operands.append(layer.w)
        if li == 0:
            # The only K-gridded operand: one (block_k, N1) slab per K step.
            in_specs.append(pl.BlockSpec((block_k, n), lambda i, j: (j, 0)))
        else:
            in_specs.append(pl.BlockSpec((k, n), lambda i, j: (0, 0)))
        if layer.quantized:
            operands.append(layer.scale)
            in_specs.append(pl.BlockSpec((1, n), lambda i, j: (0, 0)))
        operands.append(layer.bias)
        in_specs.append(pl.BlockSpec((1, n), lambda i, j: (0, 0)))

    n_last = layers[-1].w.shape[1]
    return pl.pallas_call(
        functools.partial(_fused_kernel, modes=modes, acts=acts,
                          qmaxes=qmaxes, nk=nk),
        grid=(m // block_m, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, n_last), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_last), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, n1), acc_dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Grouped megakernel: a whole heterogeneous fleet in ONE dispatch
# ---------------------------------------------------------------------------
#
# The grouped-GEMM / MoE-expert-batching idea applied to the detector zoo:
# every group's (padded) weight/bias/scale slabs for layer position l live in
# one (G, K_l, N_l) arena, the grid spans (group, M-blocks), and per-group
# geometry is resolved by index maps plus a small SMEM scalar table — kind,
# true output width, activation id and skip flag per position.  Groups
# shallower than the deepest stack "skip" their trailing positions: the SMEM
# flag passes activations through untouched, and the union width at those
# positions is kept at least as wide as every finished group's true output so
# nothing is truncated.  Pad lanes obey the same zero-row annihilation
# contract as the single-stack kernel; a group's garbage lanes beyond its
# true width are killed by ITS zero-padded next-layer rows because each group
# reads only its own arena slice.
#
# The epilogue also runs in-kernel, per group: classifiers write their final
# activations (with softmax masked to the true lane count — the one fused-
# scope gap the single-stack kernel cannot close), score heads write
# ``mean((h - tgt)^2)`` over true lanes into payload lane 0.


class GroupedLayer(NamedTuple):
    """One layer *position* of the packed fleet, arena layout.

    ``w``: (G, K, N) weights — one dtype per position (f32/int8/int16/int32).
    ``bias``: (G, 1, N) f32; ``scale``: (G, 1, N) f32 combined
    x_scale * w_scale (zeros on real/skip slots); ``x_scale``: (G, 1) f32
    activation scale (ones on real/skip slots — a 0 would round ``h/0`` into
    NaNs even though the zero weight slab annihilates the product).
    """

    w: jax.Array
    bias: jax.Array
    scale: jax.Array
    x_scale: jax.Array


def grouped_vmem_bytes(pos_shapes: Sequence[tuple], *,
                       block_m: int = 128, n_pay: int = 128) -> int:
    """VMEM resident-set estimate for the grouped megakernel.

    ``pos_shapes`` is ``[(K, N, itemsize), ...]`` — the *union* (widest-slab)
    arena geometry per layer position, padded.  Each position charges two
    arena slabs (the revolving group axis double-buffers the next group's
    slab while the current one computes), scale+bias lanes, and an activation
    tile; the x block, target block and payload block ride on top.  There is
    no K grid — the whole union input width is resident — so the budget is
    the honest whole-fleet bill.
    """
    total = block_m * pos_shapes[0][0] * 4            # x block
    total += 2 * block_m * n_pay * 4                  # target + payload
    for k, n, itemsize in pos_shapes:
        total += 2 * (k * n * itemsize + 8 * n)       # double-buffered slabs
        total += block_m * n * 4                      # activation tile
    return total


def _grouped_kernel(*refs, modes: Sequence[str], qmaxes: Sequence[int],
                    pos_acts: Sequence[Sequence[str]], n_layers: int):
    """One (group, M-block) grid step: the group's whole stack + epilogue.

    Ref order: meta (SMEM), x, then per position (x_scale SMEM, w, scale,
    bias), then tgt, out.  ``meta`` rows are
    ``[kind, n_out_true, act_id * L, skip * L]``.
    """
    meta_ref, x_ref = refs[0], refs[1]
    tgt_ref, out_ref = refs[-2], refs[-1]
    kind = meta_ref[0, 0]
    n_out = meta_ref[0, 1]
    h = x_ref[0]
    for l in range(n_layers):
        xs_ref, w_ref, s_ref, b_ref = refs[2 + 4 * l: 6 + 4 * l]
        w = w_ref[0]
        if modes[l] == "real":
            y = jax.lax.dot_general(
                h, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) + b_ref[0]
        else:
            hq = jnp.clip(jnp.round(h / xs_ref[0, 0]),
                          -qmaxes[l], qmaxes[l])
            if modes[l] == "int8":
                acc = jax.lax.dot_general(
                    hq.astype(jnp.int8), w, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                ).astype(jnp.float32)
            else:
                acc = jax.lax.dot_general(
                    hq, w.astype(jnp.float32), (((1,), (0,)), ((), ())),
                )
            y = acc * s_ref[0] + b_ref[0]
        # Per-group activation: select among the distinct activations used at
        # this position by the SMEM act id (statically unrolled — typically
        # one).  Softmax is masked to the group's true output width.
        act_id = meta_ref[0, 2 + l]
        out_l = y
        for name in pos_acts[l]:
            if name == "softmax":
                lanes = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
                z = jnp.where(lanes < n_out, y, -jnp.inf)
                zmax = jnp.max(z, axis=1, keepdims=True)
                ez = jnp.exp(z - zmax)
                a = ez / jnp.sum(ez, axis=1, keepdims=True)
            else:
                a = ACTIVATIONS[name](y)
            if len(pos_acts[l]) == 1:
                out_l = a
            else:
                out_l = jnp.where(act_id == GROUPED_ACT_IDS[name], a, out_l)
        # Skip pass-through for groups shallower than this position: carry
        # the previous activations (their true payload sits in the leading
        # lanes; the union width never truncates it).
        skip = meta_ref[0, 2 + n_layers + l]
        n_l = out_l.shape[1]
        prev = h
        if prev.shape[1] < n_l:
            prev = jnp.pad(prev, ((0, 0), (0, n_l - prev.shape[1])))
        elif prev.shape[1] > n_l:
            prev = prev[:, :n_l]
        h = jnp.where(skip == 1, prev, out_l)
    # In-kernel head epilogue: logits pass through, score heads reduce to a
    # masked mean-squared-error against the (full-width) target block in
    # payload lane 0.  The payload block is narrower than the target block —
    # pad128(max payload width) vs the last position's union width.
    n_pay = out_ref.shape[2]
    tgt = tgt_ref[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
    d = jnp.where(lanes < n_out, h - tgt, 0.0)
    score = jnp.sum(d * d, axis=1, keepdims=True) / n_out.astype(jnp.float32)
    pay_score = jnp.where(lanes[:, :n_pay] == 0, score, 0.0)
    out_ref[0] = jnp.where(kind == GROUPED_KIND_LOGITS,
                           h[:, :n_pay], pay_score)


def grouped_fused_mlp(
    x: jax.Array,
    layers: Sequence[GroupedLayer],
    meta: jax.Array,
    tgt: jax.Array,
    *,
    n_pay: int,
    modes: Sequence[str],
    qmaxes: Sequence[int],
    pos_acts: Sequence[Sequence[str]],
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Run a whole heterogeneous fleet as ONE Pallas dispatch.

    Args:
      x: (G, M, K0) f32 — every group's (padded) input windows; M divisible
        by ``block_m``, K0 and all arena dims padded to the 128-lane tile.
      layers: :class:`GroupedLayer` arenas per position; position l's
        ``w.shape[1]`` feeds position l+1's ``w.shape[2]``.
      meta: (G, 2 + 2L) int32 SMEM table —
        ``[kind, n_out_true, act_id x L, skip x L]`` per group.
      tgt: (G, M, N_last) f32 epilogue targets at the last position's union
        width (window / tail / center rows; zeros for classifiers).
      n_pay: payload lane count (128-padded max over groups: a classifier's
        true output width, 1 for score heads); at most ``N_last``.
      modes/qmaxes/pos_acts: static per-position dtype mode, quantization
        clip rail and the distinct activation names used at that position.

    Returns (G, M, n_pay) f32 payloads: final activations for
    ``GROUPED_KIND_LOGITS`` groups (softmax masked to true lanes), masked
    MSE-vs-target in lane 0 for ``GROUPED_KIND_SCORE`` groups.
    """
    if not layers:
        raise ValueError("grouped_fused_mlp needs at least one position")
    g, m, k0 = x.shape
    n_layers = len(layers)
    assert m % block_m == 0, (m, block_m)
    assert k0 % 128 == 0, x.shape
    assert meta.shape == (g, 2 + 2 * n_layers), meta.shape
    n_last = layers[-1].w.shape[2]
    assert tgt.shape == (g, m, n_last), (tgt.shape, n_last)
    assert n_pay % 128 == 0 and n_pay <= n_last, (n_pay, n_last)
    prev_n = k0
    shapes = []
    for l, layer in enumerate(layers):
        gw, k, n = layer.w.shape
        assert gw == g and k == prev_n, (l, layer.w.shape, prev_n)
        assert k % 128 == 0 and n % 128 == 0, layer.w.shape
        assert layer.bias.shape == (g, 1, n), layer.bias.shape
        assert layer.scale.shape == (g, 1, n), layer.scale.shape
        assert layer.x_scale.shape == (g, 1), layer.x_scale.shape
        shapes.append((k, n, layer.w.dtype.itemsize))
        prev_n = n
    vmem = grouped_vmem_bytes(shapes, block_m=block_m, n_pay=n_pay)
    if vmem > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"grouped arena needs ~{vmem} B of VMEM resident (> "
            f"{VMEM_BUDGET_BYTES}); fall back to per-group dispatch")

    meta_cols = meta.shape[1]
    operands = [meta, x]
    in_specs = [
        pl.BlockSpec((1, meta_cols), lambda gi, i: (gi, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, block_m, k0), lambda gi, i: (gi, i, 0)),
    ]
    for layer in layers:
        _, k, n = layer.w.shape
        operands += [layer.x_scale, layer.w, layer.scale, layer.bias]
        in_specs += [
            pl.BlockSpec((1, 1), lambda gi, i: (gi, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k, n), lambda gi, i: (gi, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda gi, i: (gi, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda gi, i: (gi, 0, 0)),
        ]
    operands.append(tgt)
    in_specs.append(pl.BlockSpec((1, block_m, n_last),
                                 lambda gi, i: (gi, i, 0)))
    return pl.pallas_call(
        functools.partial(_grouped_kernel, modes=tuple(modes),
                          qmaxes=tuple(qmaxes),
                          pos_acts=tuple(tuple(a) for a in pos_acts),
                          n_layers=n_layers),
        grid=(g, m // block_m),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_m, n_pay),
                               lambda gi, i: (gi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, m, n_pay), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "parallel"),
        ),
        interpret=interpret,
    )(*operands)
