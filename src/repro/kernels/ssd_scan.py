"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

The mamba2-370m assigned architecture is attention-free; its hot loop is the
selective-state-space recurrence

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * (x_t ⊗ B_t)        (state update)
    y_t = C_t · S_t                                              (readout)

[arXiv:2405.21060].  The SSD formulation evaluates it chunk-parallel: within a
chunk of L steps the output is a masked (decay-weighted) L×L matmul — MXU
work — and only a compressed (P×N) state crosses chunk boundaries.

TPU mapping: grid = (heads, chunks) with heads parallel and chunks sequential
('arbitrary'); the running state lives in a VMEM scratch that persists across
the sequential chunk dimension, so the recurrence never round-trips to HBM.
All per-chunk math is 2-D matmuls (L×N @ N×P, L×L @ L×P, P×L @ L×N) aligned
to the MXU.  ICSML applicability (DESIGN.md §4): the in/out projections around
this kernel are int8-quantized via qmatmul; the scan itself stays f32 exactly
like the paper keeps scales/biases REAL — state accumulation needs precision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _ssd_kernel(
    x_ref,      # (L, 1, P) f32 — inputs for this (chunk, head)
    dt_ref,     # (L, 1) f32 — positive step sizes
    a_ref,      # (1, 1) f32 — negative decay rate A_h
    b_ref,      # (L, 1, N) f32
    c_ref,      # (L, 1, N) f32
    y_ref,      # (L, 1, P) f32 out
    state_ref,  # (P, N) f32 VMEM scratch — carried across chunks
):
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[:, 0, :]          # (L, P)
    dt = dt_ref[...]            # (L, 1)
    a = a_ref[0, 0]             # ()
    b = b_ref[:, 0, :]          # (L, N)
    c = c_ref[:, 0, :]          # (L, N)

    alpha = dt * a                              # (L, 1) log-decay per step
    s = jnp.cumsum(alpha, axis=0)               # (L, 1) cumulative log-decay
    s_total = s[-1, 0]                          # ()

    # Inter-chunk: prior state read out through the decayed C.
    #   y_inter[t] = exp(s_t) * C_t @ S_prev^T          (L,N)@(N,P)
    y_inter = jnp.exp(s) * jnp.dot(
        c, state_ref[...].T, preferred_element_type=jnp.float32
    )

    # Intra-chunk: masked decay-weighted attention-like matmul.
    #   M[t,τ] = exp(s_t - s_τ) for τ <= t else 0
    mask = jnp.tril(jnp.ones((s.shape[0], s.shape[0]), bool))
    decay = jnp.exp(jnp.where(mask, s - s[:, 0][None, :], -jnp.inf))  # (L, L)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (L, L)
    y_intra = jnp.dot(
        decay * cb * dt[:, 0][None, :], x, preferred_element_type=jnp.float32
    )

    y_ref[:, 0, :] = y_inter + y_intra

    # State update: decay old state, add decayed chunk contributions.
    #   S_new = exp(s_L) S + Σ_τ exp(s_L - s_τ) dt_τ x_τ ⊗ B_τ   (P,L)@(L,N)
    w = jnp.exp(s_total - s) * dt                       # (L, 1)
    state_ref[...] = jnp.exp(s_total) * state_ref[...] + jnp.dot(
        (x * w).T, b, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Chunked SSD scan over one sequence.

    Args:
      x:  (T, H, P) f32 inputs (post in-projection, per-head channels).
      dt: (T, H) f32 positive step sizes (softplus already applied).
      a:  (H,) f32 negative decay rates.
      b:  (T, H, N) f32 input-projection states (already broadcast to heads).
      c:  (T, H, N) f32 output-projection states.
      chunk: SSD chunk length L (sequence must divide; wrapper pads).

    Returns:
      y: (T, H, P) f32.
    """
    t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, f"T={t} must be a multiple of chunk={chunk}"
    assert dt.shape == (t, h) and a.shape == (h,)
    assert b.shape == (t, h, n) and c.shape == (t, h, n)

    grid = (h, t // chunk)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, 1, p), lambda hh, cc: (cc, hh, 0)),
            pl.BlockSpec((chunk, 1), lambda hh, cc: (cc, hh)),
            pl.BlockSpec((1, 1), lambda hh, cc: (0, hh)),
            pl.BlockSpec((chunk, 1, n), lambda hh, cc: (cc, hh, 0)),
            pl.BlockSpec((chunk, 1, n), lambda hh, cc: (cc, hh, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, 1, p), lambda hh, cc: (cc, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt.reshape(t, h), a.reshape(1, h), b, c)
