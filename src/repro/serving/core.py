"""The shared fleet-serving core behind ``StreamEngine`` and
``GroupedStreamEngine``.

Both public engines used to carry a private copy of the same pipeline —
ring-arena geometry (pending trim, span/``eff_pos`` write-position math,
wraparound scatter), the pad-stream contract, device placement, warmup
schedules, serve accounting and the adapt-recalibration host loop — ~400
mirrored lines that had to be fixed twice per bug.  :class:`ServingCore`
is now the single owner; the engines are thin façades that translate
their constructor vocabulary (one model vs a list of
:class:`~repro.serving.grouped.ModelGroup`) into :class:`ServingUnit`
specs and inherit everything else.

**The unit model.**  A serving core drives a list of *units*: contiguous
stream-axis slices, each with its own model, detector head, window
geometry, quantization scales, fused/per-layer step flavor and optional
drift adaptation.  ``StreamEngine`` is the one-unit special case (its
unit is anonymous, so verdicts keep ``group=None``); ``GroupedStreamEngine``
is the N-unit case with named groups.  Per verdict cadence the core runs
ONE jitted, donated step over the tuple of ready units' ring arenas —
each distinct ready-combination ``((unit, block_len), ...)`` compiles
once and steady state reuses a single executable.

**Megakernel (single-dispatch multi-group steps).**  When every unit's
stack packs (``ops.grouped_fuse_reason``: all-Dense, one MXU mode per
layer position, packed-arena VMEM in budget) and every head exposes an
in-kernel epilogue (``DetectorHead.kernel_epilogue``), a multi-unit ready
step lowers to exactly ONE dispatch: the co-firing units' rings are
stacked, scattered and windowed batched over a leading group axis, and
``ops.grouped_apply`` runs the whole fleet — per-group quantization,
activations (a final-layer softmax masked to each group's true class
count) and head epilogues included — as one grouped Pallas call.
Compiled mega steps are keyed on the *block shape* (the hashable
``GroupedPlan`` + serving geometry), not the ready subset, so the
per-ready-combination step-cache explosion collapses to one compiled
step per shape.  ``megakernel=None`` auto-enables on the unsharded
path; ``False`` pins the per-group path; ``True`` forces it (sharded
included) and raises with the packing reason when the fleet cannot
lower.  Sharded fleets stay per-group by default: the megakernel's
sharded step bit-matches the canonical *unsharded* math, but the
per-group sharded graph it would replace rounds 1 ulp differently
(XLA fusion context), so the default would perturb REAL verdicts
bitwise — opt in with ``megakernel=True``.  Ready subsets whose
geometry cannot stack (mixed window or padded-stream extents) fall
back to the per-group step for that boundary only.  Verdicts
bit-match (REAL) / epsilon-match (quantized) the per-group path —
the oracle route is the identical op sequence.

**Async double-buffering (``async_depth=1``).**  Synchronous serving
blocks the host on every verdict step: dispatch, ``block_until_ready``,
build verdicts, repeat — so host ingest and device compute take turns
and the wall is their *sum*.  With ``async_depth=1`` the core pipelines
them: ``ingest()`` at a ready boundary first **harvests** the previous
step's in-flight outputs (they have had a whole inter-boundary interval
to finish), then **dispatches** the new step and returns immediately —
device compute for step N overlaps the host-side ingest of the cycles
feeding step N+1.  Consequences, all deliberate:

* Verdicts are delivered one ready boundary late, but are **bit-identical**
  to synchronous mode (same executables, same operands — the harvest
  happens before the next dispatch, so adapt-threshold recalibration sees
  exactly the state ordering of the sync loop).  ``Verdict.cycle`` still
  names the boundary the window completed at.
* ``flush()`` drains the last in-flight step (a no-op returning ``[]``
  in sync mode).  ``run()`` does NOT auto-flush — streaming may continue.
* ``latency_s``/``deadline_miss`` are redefined as **dispatch→harvest**
  time: the window completes at dispatch, the verdict exists on host at
  harvest, and everything between (including the overlapped host ingest)
  is genuine verdict-visibility delay.  ``stats.steps`` counts at
  dispatch; ``windows``/``deadline_misses``/``latencies_s`` count at
  harvest.
* ``stats.wall_s`` still accumulates host time spent inside
  ``ingest()``/``flush()`` only — the overlapped device time is exactly
  what it no longer contains, which is the point: ``windows_per_s()``
  measures *sustained host throughput under continuous arrival*.

**2-D ``("data", "model")`` mesh.**  Stream-axis data sharding composes
with model-axis weight sharding (``launch.mesh.make_fleet_mesh(...,
model_shards=m)``): wide Dense layers (output width >=
``MODEL_SHARD_MIN_WIDTH``) are column-sharded over the model axis —
every model rank computes its own column slice of the layer (weights,
bias and per-channel quantization scales sliced by ``axis_index``) and
one tiled ``all_gather`` recombines the activations, mesh-transformer-jax
``TransformerLayerShard`` style (but gathered, not ``psum``-paired, so
each output column is the SAME full-K dot product as the unsharded oracle
and REAL parity stays bit-exact).  Narrow layers stay replicated — a
collective per 2-wide layer would cost more than it shards — so the §7
detector runs exactly ONE collective per step.  Ring arenas, pending
blocks and outputs keep their ``P("data", ...)`` shardings (replicated
over the model axis).  On this host-emulation target the sliced weights
are compile-time constants on every rank (each rank *computes* 1/m of
the wide layers; weight *storage* sharding is part of the ROADMAP TPU
validation pass).  The fused whole-MLP kernel cannot span the gather, so
``fused=None`` auto-resolves to the per-layer path under a model-sharded
mesh and ``fused=True`` raises.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import msf_detector as spec
from repro.core.layers import ACTIVATIONS, Dense, Input
from repro.core.model import Model, ParamTree
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.sim.heads import (ClassifierHead, DetectorHead, ForecastHead,
                             ScoreHead)

# Column-shard a Dense layer over the mesh's "model" axis only when its
# output is at least this wide: below it the all_gather costs more than the
# sharded columns save (the detector's 2-wide logit layer is the extreme
# case), and the recombination stops being "minimal-collective".
MODEL_SHARD_MIN_WIDTH = 64


@dataclasses.dataclass
class Verdict:
    """One per-stream verdict on a completed window.

    The payload depends on the engine's :class:`~repro.sim.heads.DetectorHead`:
    a classifier head fills ``pred``/``prob`` (argmax class + its softmax
    probability, ``score``/``threshold`` None); a reconstruction head fills
    ``pred``/``score``/``threshold`` (pred = score over threshold, ``prob``
    None).  ``pred != 0`` always means "anomalous".
    """

    stream: int               # stream index in the fleet
    cycle: int                # scan cycle at which the window completed
    pred: int                 # verdict class (0 = normal)
    prob: Optional[float]     # classifier: softmax prob of the predicted class
    latency_s: float          # window-completion -> verdict-on-host wall time
                              # (async: dispatch -> harvest)
    deadline_miss: bool       # latency_s > deadline_s
    score: Optional[float] = None       # score heads: anomaly score
    threshold: Optional[float] = None   # score heads: calibrated cutoff
    group: Optional[str] = None         # model-group name (grouped fleets)


# Default reservoir seeds come from a process-global counter, so every
# engine's reservoir draws a distinct replacement sequence: with a shared
# fixed seed, split engines (the grouped-vs-split bench) replaced the SAME
# retained indices in lockstep, correlating their percentile estimates.
_reservoir_seeds = itertools.count()


class LatencyReservoir:
    """Bounded uniform sample of verdict latencies (Vitter's Algorithm R).

    A long-lived fleet engine emits one latency per verdict step forever; an
    unbounded list leaks O(steps) host memory at millions of cycles.  The
    reservoir retains the first ``capacity`` samples verbatim (append order
    preserved, so short runs — tests, bench passes — see an exact list) and
    thereafter replaces a uniformly random retained sample with probability
    ``capacity / seen``, keeping the retained set a uniform draw from the
    whole history — percentile estimates stay statistically valid while
    memory stays O(capacity).

    List-like where it matters: ``len`` / truthiness / iteration / indexing
    and slicing cover every pre-reservoir consumer.  Slicing is only
    meaningful while the retained items are the exact append-ordered list,
    so once ``seen`` exceeds ``capacity`` (Algorithm R has replaced random
    retained indices) slice access **raises** instead of silently returning
    a uniform jumble — per-pass latency tails should come from
    :meth:`StreamStats.reset_latencies` instead.

    ``seed=None`` (the default) draws an engine-unique seed from a process
    counter; pass an explicit seed for reproducible replacement sequences.
    """

    __slots__ = ("capacity", "seen", "seed", "_items", "_rng")

    def __init__(self, capacity: int = 4096, seed: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.seen = 0                 # total appends ever observed
        self.seed = next(_reservoir_seeds) if seed is None else seed
        self._items: List[float] = []
        self._rng = np.random.default_rng(self.seed)

    def append(self, value: float) -> None:
        self.seen += 1
        if len(self._items) < self.capacity:
            self._items.append(float(value))
        else:
            j = int(self._rng.integers(self.seen))
            if j < self.capacity:
                self._items[j] = float(value)

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, idx):
        if isinstance(idx, slice) and self.seen > self.capacity:
            raise ValueError(
                f"latency tail slices are only exact below the reservoir "
                f"capacity ({self.capacity}); after {self.seen} appends "
                "Algorithm R has replaced random retained indices, so a "
                "slice is a uniform jumble, not a pass tail — take "
                "per-pass tails via StreamStats.reset_latencies()")
        return self._items[idx]

    def percentile(self, q: float) -> float:
        """Latency percentile of the retained sample.

        Raises on an empty reservoir: an engine that never fired a verdict
        step has no latency distribution, and the old ``0.0`` read as a
        perfect 0 ms tail in dashboards — check ``len(reservoir)`` (or
        ``stats.windows``) before asking for a percentile.
        """
        if not self._items:
            raise ValueError(
                "percentile of an empty latency reservoir: no verdict step "
                "has fired yet (returning 0.0 here would report a perfect "
                "0 ms tail for an engine that never served)")
        return float(np.percentile(self._items, q))


@dataclasses.dataclass
class StreamStats:
    """Aggregate serve accounting (ServeStats conventions).

    ``latencies_s`` is a bounded :class:`LatencyReservoir`, not a list: the
    engine appends one latency per verdict step for the life of the process,
    and the reservoir keeps ``latency_p`` statistically valid at O(1)
    memory (exact below its capacity).  ``latency_p`` raises while the
    reservoir is empty (no verdict step has fired yet).

    Under ``async_depth=1`` the split matters: ``steps`` counts at
    dispatch, ``windows``/``deadline_misses``/``latencies_s`` at harvest,
    and ``wall_s`` is host time inside ``ingest()``/``flush()`` only —
    device compute overlapped with ingest is deliberately absent, so
    ``windows_per_s`` reads as sustained host throughput.

    ``dispatches`` counts *logical kernel dispatches* per step: a megakernel
    step is 1 regardless of how many groups co-fired; the per-group path
    charges each ready unit its flavor's cost (fused = 1, per-layer = one
    per Dense layer).  ``dispatches == steps`` is the single-dispatch
    guarantee the grouped benches assert."""

    steps: int                       # jitted detector steps executed
    cycles: int                      # scan cycles ingested
    windows: int                     # verdicts emitted (streams x steps)
    deadline_misses: int
    wall_s: float                    # total time spent inside ingest()
    dispatches: int = 0              # logical kernel dispatches issued
    latencies_s: LatencyReservoir = dataclasses.field(
        default_factory=LatencyReservoir)

    def latency_p(self, q: float) -> float:
        return self.latencies_s.percentile(q)

    def reset_latencies(self) -> LatencyReservoir:
        """Swap in a fresh (same-capacity, fresh-seed) reservoir and return
        the retired one — the sanctioned way to take per-pass latency tails
        (benchmark passes): tail *slices* of a reservoir past its capacity
        are silently wrong, because Algorithm R replaces random retained
        indices, and therefore raise."""
        old = self.latencies_s
        self.latencies_s = LatencyReservoir(capacity=old.capacity)
        return old

    def windows_per_s(self) -> float:
        return self.windows / self.wall_s if self.wall_s > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Streaming threshold-recalibration policy (online drift adaptation).

    ``capacity`` is the per-stream rolling score-ring length (the sketch
    window: the live threshold is the conservative quantile of the trailing
    ``<= capacity`` admitted scores per stream, pooled fleet-wide).
    ``every`` recalibrates once per that many fired verdict steps; the
    device-side state update runs every step regardless.  ``min_count``
    holds the threshold at its offline-calibrated seed until that many
    scores have been admitted fleet-wide (early tiny pools make noisy
    quantiles).  ``headroom`` is the admission gate: scores at most
    ``headroom`` times the live threshold enter the calibration state —
    wide enough that gradual benign drift passes through the gate even when
    it crosses the threshold, tight enough that attack scores (orders of
    magnitude out) never poison the state.
    """

    capacity: int = 32
    every: int = 1
    min_count: int = 16
    headroom: float = 4.0

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if self.min_count < 1:
            raise ValueError(f"min_count must be >= 1, got {self.min_count}")
        if self.headroom < 1.0:
            raise ValueError(
                f"headroom must be >= 1 (the gate must at least admit "
                f"sub-threshold scores), got {self.headroom}")


def _resolve_adapt(adapt: Union[bool, AdaptConfig, None],
                   head: DetectorHead, what: str = "") -> Optional[AdaptConfig]:
    """Validate and normalize an ``adapt=`` knob: None/False off, True the
    default policy, an :class:`AdaptConfig` verbatim.  Adaptation requires a
    calibrated :class:`ScoreHead` with a recorded ``target_fpr`` (the
    streaming quantile chases the same operating point the offline
    calibration chose)."""
    if adapt is None or adapt is False:
        return None
    cfg = AdaptConfig() if adapt is True else adapt
    if not isinstance(cfg, AdaptConfig):
        raise ValueError(f"{what}adapt must be None/bool/AdaptConfig, "
                         f"got {cfg!r}")
    if not isinstance(head, ScoreHead):
        raise ValueError(
            f"{what}adapt=True needs a score-vs-threshold head (ScoreHead); "
            f"the {head.name!r} head has no score distribution to "
            "recalibrate on")
    if head.threshold is None or head.target_fpr is None:
        raise ValueError(
            f"{what}adapt=True needs a calibrated head with a recorded "
            "target_fpr to seed and steer the live threshold "
            "(head.calibrate / the sim.detector trainers set both)")
    return cfg


def _layer_stack(model: Model, params: ParamTree) -> List[Tuple[Dict, str]]:
    """(params, activation) per Dense node in schedule order."""
    stack = ops.dense_stack(model, params)
    if not stack:
        raise ValueError("model has no Dense layers to serve")
    return stack


def _dense_batched(x: jax.Array, p: Dict, act: str, backend: str) -> jax.Array:
    """One Dense layer over a (M, K) batch, float or quantized (§6.1)."""
    if "qw" in p:
        qw = p["qw"]
        # Symmetric activation clip, matching quantize.quantize_tensor and
        # layers._quantized_matvec (the scale decodes [-qmax, qmax]).
        qmax = jnp.iinfo(qw.dtype).max
        xq = jnp.clip(jnp.round(x / p["x_scale"]), -qmax, qmax)
        scale = p["x_scale"] * p["w_scale"]
        if qw.dtype == jnp.int8:
            # SINT: native int8 dot product — the Pallas qmatmul MXU path.
            y = ops.quantized_matmul(xq.astype(qw.dtype), qw, scale,
                                     p.get("b"), backend=backend)
        else:
            # INT/DINT: int16/int32 products overflow int32 accumulation on
            # TPU, so the integer arithmetic is emulated in f32 (storage
            # compression is what these schemes buy — see layers.py).  No
            # round-trip through the int dtype: int32's qmax is not f32-
            # representable, so the cast would overflow at the clip rail.
            y = xq @ qw.astype(jnp.float32) * scale
            if p.get("b") is not None:
                y = y + p["b"]
    else:
        y = x @ p["w"]
        if "b" in p:
            y = y + p["b"]
    return ACTIVATIONS[act](y)


def _pad_layer_cols(p: Dict, n_pad: int) -> Dict:
    """Pad a Dense layer's output columns to ``n_pad`` (host-side, once at
    engine build) so every model rank owns an equal column slice.  Bias and
    per-channel weight scales are normalized to per-column vectors and
    padded alongside; pad columns are sliced off after the gather, so their
    values never surface."""
    wkey = "qw" if "qw" in p else "w"
    w = np.asarray(p[wkey])
    n = w.shape[1]
    q = dict(p)
    q[wkey] = jnp.asarray(np.pad(w, ((0, 0), (0, n_pad - n))))
    if p.get("b") is not None:
        b = np.broadcast_to(np.asarray(p["b"], np.float32), (n,))
        q["b"] = jnp.asarray(np.pad(b, (0, n_pad - n)))
    if "w_scale" in p:
        s = np.broadcast_to(np.asarray(p["w_scale"], np.float32), (n,))
        # Pad scale 1.0, not 0.0: a zero scale would make the (discarded)
        # pad columns 0 * 0 under emulated-int math — fine — but keeps the
        # invariant that every stored scale decodes *some* grid.
        q["w_scale"] = jnp.asarray(np.pad(s, (0, n_pad - n),
                                          constant_values=1.0))
    return q


def _model_shard_plan(stack, model_shards: int):
    """Per layer: ``(params, act, cols_per_rank | None, true_width)``.

    ``cols_per_rank`` is set (and the params column-padded) only for layers
    wide enough to shard; ``None`` keeps the replicated
    :func:`_dense_batched` path."""
    plan = []
    for p, act in stack:
        n_out = int((p["qw"] if "qw" in p else p["w"]).shape[1])
        if model_shards > 1 and n_out >= MODEL_SHARD_MIN_WIDTH:
            nc = -(-n_out // model_shards)
            plan.append((_pad_layer_cols(p, nc * model_shards), act, nc,
                         n_out))
        else:
            plan.append((p, act, None, n_out))
    return plan


def _dense_model_sharded(x: jax.Array, p: Dict, act: str, backend: str,
                         nc: int, n_out: int, axis: str) -> jax.Array:
    """One Dense layer column-sharded over the mesh's ``axis``.

    Each model rank slices its ``nc`` output columns (weights, bias and
    per-channel scales) by ``axis_index`` and computes the full-K dot for
    just those columns — the exact arithmetic of the unsharded layer, so
    REAL recombines bit-exactly.  One tiled ``all_gather`` rebuilds the
    full activation row for the next layer (mesh-transformer-jax's
    ``TransformerLayerShard`` recombination, gather flavor)."""
    j = jax.lax.axis_index(axis) * nc
    if "qw" in p:
        qw = jax.lax.dynamic_slice_in_dim(p["qw"], j, nc, axis=1)
        w_scale = jax.lax.dynamic_slice_in_dim(p["w_scale"], j, nc, axis=0)
        b = p.get("b")
        if b is not None:
            b = jax.lax.dynamic_slice_in_dim(b, j, nc, axis=0)
        qmax = jnp.iinfo(qw.dtype).max
        xq = jnp.clip(jnp.round(x / p["x_scale"]), -qmax, qmax)
        scale = p["x_scale"] * w_scale
        if qw.dtype == jnp.int8:
            y = ops.quantized_matmul(xq.astype(qw.dtype), qw, scale, b,
                                     backend=backend)
        else:
            y = xq @ qw.astype(jnp.float32) * scale
            if b is not None:
                y = y + b
    else:
        y = x @ jax.lax.dynamic_slice_in_dim(p["w"], j, nc, axis=1)
        if p.get("b") is not None:
            y = y + jax.lax.dynamic_slice_in_dim(p["b"], j, nc, axis=0)
    y = ACTIVATIONS[act](y)
    return jax.lax.all_gather(y, axis, axis=1, tiled=True)[:, :n_out]


@dataclasses.dataclass
class ServingUnit:
    """One detector population inside a serving core (the façades build
    these from their constructor vocabulary).

    ``name=None`` marks the anonymous single-model case — its verdicts
    carry ``group=None``.  ``window`` overrides the head-derived ring
    extent (``StreamEngine``'s explicit-window knob); ``what`` prefixes
    this unit's constructor error messages (``"group 'x': "`` for grouped
    fleets) so the façades keep their historical diagnostics."""

    name: Optional[str]
    model: Model
    params: ParamTree
    n_streams: int
    head: Optional[DetectorHead] = None
    fused: Optional[bool] = None
    adapt: Union[bool, AdaptConfig, None] = None
    window: Optional[int] = None
    what: str = ""


class _UnitState:
    """Per-unit serving state: geometry, compiled-body closure, ring."""

    __slots__ = ("name", "head", "window", "offset", "n_streams", "s_pad",
                 "body", "pos", "consumed", "use_fused", "windows",
                 "adapt", "live_threshold", "fires", "stack", "kernel_epi",
                 "fused_knob", "all_dense", "dispatch_cost")

    def __init__(self, name, head, window, offset, n_streams):
        self.name = name
        self.head = head
        self.window = window
        self.offset = offset          # first global stream index
        self.n_streams = n_streams
        self.pos = 0                  # next ring write index (host-tracked)
        self.consumed = 0             # scan count at the last fired step
        self.windows = 0              # verdicts emitted for this unit
        self.fires = 0                # steps this unit participated in


def _unpack_pergroup(outs) -> List[np.ndarray]:
    """Per-group step outputs -> one host array per ready unit."""
    return [np.asarray(o) for o in outs]


class _InFlight:
    """One dispatched-but-unharvested verdict step (async_depth=1)."""

    __slots__ = ("key", "outs", "cycle", "t0", "unpack")

    def __init__(self, key, outs, cycle, t0, unpack=_unpack_pergroup):
        self.key = key                # ready-combination the step ran under
        self.outs = outs              # per-unit output futures
        self.cycle = cycle            # boundary cycle the windows completed at
        self.t0 = t0                  # dispatch wall-clock (latency origin)
        self.unpack = unpack          # outs -> [host array per ready unit]


class _MegaPack:
    """One ready-subset's packed megakernel operands + static geometry.

    ``sig`` is the step-cache key material: the hashable
    :class:`~repro.kernels.ops.GroupedPlan` plus the per-slot serving
    geometry, epilogue selectors and adapt policy — everything the traced
    step closes over.  Two identity-distinct subsets with equal ``sig``
    share ONE compiled step; their numbers (``arrays``/``centers``) enter
    as runtime operands.  (``calib_update`` must therefore be instance-
    stateless, which the :class:`~repro.sim.heads.ScoreHead` base impl is.)
    """

    __slots__ = ("plan", "arrays", "centers", "tgt_sels", "widths",
                 "heads", "adapts", "sig", "unpack")

    def __init__(self, plan, arrays, centers, tgt_sels, widths, heads,
                 adapts, sig):
        self.plan = plan
        self.arrays = arrays          # packed arenas + meta (operands)
        self.centers = centers        # (G, 1, plan.n_out) margin centers
        self.tgt_sels = tgt_sels      # per slot: none|window|tail|center
        self.widths = widths          # true payload width per slot
        self.heads = heads
        self.adapts = adapts
        self.sig = sig

        def unpack(payload) -> List[np.ndarray]:
            pay = np.asarray(payload)
            return [pay[k, :, :w] for k, w in enumerate(widths)]
        self.unpack = unpack


class ServingCore:
    """Batched sliding-window serving over a list of :class:`ServingUnit`.

    This is the machinery layer — see the module docstring for the serving
    model and :class:`~repro.serving.streams.StreamEngine` /
    :class:`~repro.serving.grouped.GroupedStreamEngine` for the public
    constructor contracts.  Everything here is unit-count agnostic: the
    single-model engine is served exactly like a one-group fleet.
    """

    def __init__(self, units: Sequence[ServingUnit], *,
                 n_features: int = spec.N_FEATURES,
                 stride: int = spec.STRIDE,
                 deadline_s: float = spec.DEADLINE_S,
                 norm_mean: Sequence[float] = spec.NORM_MEAN,
                 norm_std: Sequence[float] = spec.NORM_STD,
                 backend: str = "auto",
                 shard: Optional[bool] = None,
                 mesh: Optional[Mesh] = None,
                 async_depth: int = 0,
                 megakernel: Optional[bool] = None):
        if not units:
            raise ValueError("need at least one serving unit")
        if any(u.n_streams < 1 for u in units):
            raise ValueError("every unit needs n_streams >= 1")
        if not 1 <= stride:
            raise ValueError("stride must be >= 1")
        if async_depth not in (0, 1):
            raise ValueError(
                f"async_depth must be 0 (synchronous) or 1 (double-"
                f"buffered), got {async_depth!r}")
        self.n_features = n_features
        self.stride = stride
        self.deadline_s = deadline_s
        self.async_depth = async_depth
        self._mean = np.asarray(norm_mean, np.float32)
        self._std = np.asarray(norm_std, np.float32)
        if self._mean.shape != (n_features,) or \
                self._std.shape != (n_features,):
            raise ValueError("norm_mean/norm_std must have one entry per "
                             "feature")
        self._backend = backend
        self.n_streams = sum(u.n_streams for u in units)

        # -- mesh ("data" stream sharding x optional "model" axis) ---------
        if shard is False and mesh is not None:
            raise ValueError("shard=False contradicts an explicit mesh")
        if mesh is None and (shard or (shard is None
                                       and len(jax.devices()) > 1)):
            # Never mesh wider than the smallest unit: pure-pad shards would
            # burn a dispatch per device on zero streams every cadence.
            mesh = make_fleet_mesh(min(len(jax.devices()),
                                       *(u.n_streams for u in units)))
        if mesh is not None:
            if "data" not in mesh.axis_names:
                raise ValueError(f"fleet mesh needs a 'data' axis, got "
                                 f"{mesh.axis_names}")
            extra = [a for a in mesh.axis_names
                     if a not in ("data", "model") and mesh.shape[a] != 1]
            if extra:
                raise ValueError(
                    f"non-'data' mesh axes must have size 1, got {extra} "
                    "(weight sharding lives on the 'model' axis)")
        self.mesh = mesh
        self.n_shards = 1 if mesh is None else mesh.shape["data"]
        self.model_shards = (mesh.shape["model"]
                             if mesh is not None
                             and "model" in mesh.axis_names else 1)
        self._model_axis = "model" if self.model_shards > 1 else None
        if mesh is None:
            self._arena_sharding = None
            self._calib_sharding = None
            self._counts_sharding = None
            self._block4_sharding = None
        else:
            self._arena_sharding = NamedSharding(mesh, P("data", None, None))
            self._calib_sharding = NamedSharding(mesh, P("data", None))
            self._counts_sharding = NamedSharding(mesh, P("data"))
            # Megakernel block operand: (group, stream, reading, feature).
            self._block4_sharding = NamedSharding(
                mesh, P(None, "data", None, None))

        # -- per-unit geometry, bodies, rings -----------------------------
        self._units: List[_UnitState] = []
        self._rings: List[jax.Array] = []
        self._calibs: List[jax.Array] = []
        self._counts: List[jax.Array] = []
        offset = 0
        for u in units:
            head = ClassifierHead() if u.head is None else u.head
            (input_size,) = u.model.input_shape
            # Window geometry is the head's contract: for every head but
            # forecast the window IS the model input; the forecast head asks
            # the ring for one extra reading (its prediction target) and
            # slices the model input out of the window on device.
            window = (head.ring_window(input_size, n_features)
                      if u.window is None else u.window)
            if head.model_input_size(window, n_features) != input_size:
                raise ValueError(
                    f"window {window} x features {n_features} (head "
                    f"{head.name!r}) != model input {input_size}")
            stack = _layer_stack(u.model, u.params)
            last = stack[-1][0]
            n_out = (last["qw"] if "qw" in last else last["w"]).shape[1]
            head.validate(input_size, n_out)
            fusable = ops.model_fusable(u.model, stack)
            if u.fused and not fusable:
                reason = ops.fuse_reason(stack) or \
                    "the model graph has non-Dense nodes"
                raise ValueError(
                    f"{u.what}fused=True but the model cannot fuse: {reason}")
            if u.fused and self._model_axis is not None:
                raise ValueError(
                    f"{u.what}fused=True cannot serve on a model-sharded "
                    "mesh: the all_gather between column-sharded layers "
                    "cannot live inside one pallas_call — use fused=None/"
                    "False, or a mesh with model_shards=1")
            # Constructor-only knob: captured in the body closure so a
            # post-compile mutation can't desynchronize traced steps.  The
            # fused kernel cannot span the model-axis gather, so a model-
            # sharded mesh auto-selects the per-layer path.
            use_fused = ((fusable and self._model_axis is None)
                         if u.fused is None else u.fused)
            st = _UnitState(u.name, head, window, offset, u.n_streams)
            # Pad-stream contract per unit: every device owns an equal
            # contiguous shard of each unit's arena; pad rows are zero
            # streams sliced off before verdicts.
            st.s_pad = -(-u.n_streams // self.n_shards) * self.n_shards
            st.use_fused = use_fused
            st.stack = stack
            st.kernel_epi = head.kernel_epilogue()
            st.fused_knob = u.fused
            st.all_dense = all(isinstance(n.layer, (Input, Dense))
                               for n in u.model.graph.nodes)
            st.dispatch_cost = 1 if use_fused else len(stack)
            st.adapt = _resolve_adapt(u.adapt, head, what=u.what)
            st.live_threshold = (head.threshold
                                 if isinstance(head, ScoreHead) else None)
            st.body = self._make_body(stack, head, use_fused, window,
                                      st.adapt)
            self._units.append(st)
            self._rings.append(self._place(
                jnp.zeros((st.s_pad, window, n_features), jnp.float32)))
            calib, counts = self._calib_state(st)
            self._calibs.append(calib)
            self._counts.append(counts)
            offset += u.n_streams
        self.max_window = max(st.window for st in self._units)

        # Compiled steps keyed by the ready-combination signature
        # ((unit_idx, block_len), ...): steady state — every unit ready
        # with a stride-long block — is one key reused forever; window
        # fill-in transitions each compile once.
        self._steps: Dict[Tuple, Callable] = {}

        # -- megakernel (single-dispatch multi-group steps) ---------------
        # Packs are cached per ready subset; compiled steps are keyed by
        # (pack.sig, block length) — the BLOCK SHAPE, not the subset — so
        # identity-distinct equal-geometry subsets share one executable and
        # the per-ready-combination step-cache explosion collapses.
        self._mega_packs: Dict[Tuple[int, ...], _MegaPack] = {}
        self._mega_steps: Dict[Tuple, Callable] = {}
        self._mega_reason = self._compute_mega_reason()
        if megakernel and self._mega_reason is not None:
            raise ValueError(
                "megakernel=True but the fleet cannot pack into one "
                f"dispatch: {self._mega_reason}")
        # Auto-enable only on the unsharded path.  The megakernel's sharded
        # step is bit-stable against the canonical unsharded math, but the
        # per-group SHARDED graph it replaces rounds a few dot products
        # differently at 1 ulp (XLA codegen is fusion-context dependent), so
        # flipping the default under a mesh would perturb REAL verdicts
        # bitwise against the seed behavior.  ``megakernel=True`` opts the
        # sharded path in explicitly (REAL agreement vs the per-group
        # sharded step is then epsilon-level, not bitwise).
        self._mega = (self._mega_reason is None
                      and (megakernel is True
                           or (megakernel is None and self.mesh is None)))

        self._count = 0
        self._pending: List[np.ndarray] = []
        self._inflight: Optional[_InFlight] = None
        self.last_outputs: Dict[Optional[str], np.ndarray] = {}
        self.stats = StreamStats(steps=0, cycles=0, windows=0,
                                 deadline_misses=0, wall_s=0.0)

    @property
    def mega_reason(self) -> Optional[str]:
        """Why this fleet cannot pack into the single-dispatch megakernel
        (None when it can — the engine may still serve per-group if the
        megakernel is disabled by the knob or the sharded default)."""
        return self._mega_reason

    # -- construction helpers ----------------------------------------------

    def _place(self, arr, sharding=None) -> jax.Array:
        """Commit an array to the fleet mesh (no-op unsharded); ``sharding``
        defaults to the 3-D arena sharding."""
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(
            arr, self._arena_sharding if sharding is None else sharding)

    def _calib_state(self, st: _UnitState) -> Tuple[jax.Array, jax.Array]:
        """A unit's (placed) rolling calibration state.  Non-adaptive units
        carry a minimal dummy so every step has one uniform
        ``(ring, calib, counts, block, pos, thr)`` signature per unit —
        the dummy rides through the donated step untouched."""
        if st.adapt is not None:
            calib, counts = st.head.calib_state(st.s_pad, st.adapt.capacity)
        else:
            calib = jnp.zeros((st.s_pad, 1), jnp.float32)
            counts = jnp.zeros((st.s_pad,), jnp.int32)
        return (self._place(calib, self._calib_sharding),
                self._place(counts, self._counts_sharding))

    @staticmethod
    def _thr(st: _UnitState) -> jnp.float32:
        """The unit's live threshold as the step's scalar operand (0.0 for
        heads with no threshold — the body never reads it then)."""
        return jnp.float32(0.0 if st.live_threshold is None
                           else st.live_threshold)

    def _make_body(self, stack, head, use_fused, window, adapt_cfg):
        """One unit's device step body: ring scatter, oldest-first unroll,
        the head's ``prepare`` view, the (fused Pallas / model-sharded)
        forward, the head's device epilogue and, when the unit adapts, the
        rolling calibration-state write.  Identical math for every façade,
        so grouped serving bit-matches an independent per-model engine."""
        backend = self._backend
        w = window
        plan = _model_shard_plan(stack, self.model_shards)
        axis = self._model_axis

        def _forward(x):
            if use_fused:
                return ops.fused_forward(x, stack, backend=backend)
            for p, act, nc, n_out in plan:
                x = (_dense_batched(x, p, act, backend) if nc is None else
                     _dense_model_sharded(x, p, act, backend, nc, n_out,
                                          axis))
            return x

        def body(ring, calib, counts, block, pos, thr):
            # block: (S, L, F) pending readings; L static per compile (the
            # warmup block is `window` long, steady-state blocks
            # `min(stride, window)` — ingest() trims longer spans host-side).
            # The device trim below is defense in depth for direct callers:
            # only the last `window` readings can ever land, and trimming
            # before scattering keeps the indices provably unique
            # (duplicate-index scatter-set order is undefined off-CPU).
            length = block.shape[1]
            offset = max(length - w, 0)
            idx = (pos + offset + jnp.arange(length - offset)) % w
            ring = ring.at[:, idx, :].set(block[:, offset:])
            # Window unroll, oldest reading first: the ring holds exactly
            # the last `window` readings, ending at (pos + L - 1) mod window.
            end = (pos + length) % w
            widx = (end + jnp.arange(w)) % w
            win = jnp.take(ring, widx, axis=1).reshape(ring.shape[0], -1)
            out = head.epilogue(win, _forward(head.prepare(win)))
            if adapt_cfg is not None:
                # The rolling benign-score state advances INSIDE the donated
                # step: one row-local ring write per stream, gated on the
                # live threshold — no extra dispatch, no new collectives.
                calib, counts = head.calib_update(
                    calib, counts, out, thr, adapt_cfg.headroom)
            return ring, calib, counts, out

        return body

    def _get_step(self, key: Tuple) -> Callable:
        """The jitted donated step for one ready-combination."""
        step = self._steps.get(key)
        if step is not None:
            return step
        bodies = [self._units[gi].body for gi, _ in key]

        def _step(rings, calibs, countss, blocks, poss, thrs):
            outs = [body(ring, calib, counts, block, pos, thr)
                    for body, ring, calib, counts, block, pos, thr
                    in zip(bodies, rings, calibs, countss, blocks, poss,
                           thrs)]
            return (tuple(o[0] for o in outs), tuple(o[1] for o in outs),
                    tuple(o[2] for o in outs), tuple(o[3] for o in outs))

        if self.mesh is not None:
            # One shard_map over the whole multi-unit body: every unit body
            # is stream-local over "data" (the calibration-state write
            # included), so each device serves its contiguous shard of every
            # ready unit; the only collectives are the model-axis gathers of
            # column-sharded wide layers (none on a 1-D mesh).
            # check_rep=False: pallas_call carries no replication rule.
            n = len(key)
            _step = shard_map(
                _step, mesh=self.mesh,
                in_specs=((P("data", None, None),) * n,
                          (P("data", None),) * n, (P("data"),) * n,
                          (P("data", None, None),) * n,
                          (P(),) * n, (P(),) * n),
                out_specs=((P("data", None, None),) * n,
                           (P("data", None),) * n, (P("data"),) * n,
                           (P("data", None),) * n),
                check_rep=False)
        step = self._steps[key] = jax.jit(_step, donate_argnums=(0, 1, 2))
        return step

    def _single_step_view(self):
        """The classic single-model step signature over unit 0's body —
        ``(ring, block, pos) -> (ring, out)`` without adaptation,
        ``(ring, calib, counts, block, pos, thr) -> (ring, calib, counts,
        out)`` with — re-jitted from the exact body (and shard_map
        configuration) the serving steps run.  Back-compat introspection
        surface: the jaxpr dispatch-count suites trace
        ``StreamEngine._step`` through this."""
        st = self._units[0]
        body = st.body
        if st.adapt is not None:
            def step(ring, calib, counts, block, pos, thr):
                return body(ring, calib, counts, block, pos, thr)
            in_specs = (P("data", None, None), P("data", None), P("data"),
                        P("data", None, None), P(), P())
            out_specs = (P("data", None, None), P("data", None), P("data"),
                         P("data", None))
            donate = (0, 1, 2)
        else:
            def step(ring, block, pos):
                ring, _, _, out = body(
                    ring, jnp.zeros((ring.shape[0], 1), jnp.float32),
                    jnp.zeros((ring.shape[0],), jnp.int32),
                    block, pos, jnp.float32(0.0))
                return ring, out
            in_specs = (P("data", None, None), P("data", None, None), P())
            out_specs = (P("data", None, None), P("data", None))
            donate = 0
        if self.mesh is not None:
            step = shard_map(step, mesh=self.mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)
        return jax.jit(step, donate_argnums=donate)

    # -- megakernel: the whole ready fleet in ONE dispatch -----------------

    def _compute_mega_reason(self) -> Optional[str]:
        """None when multi-unit ready steps can lower to one grouped
        megakernel dispatch, else why the engine serves per-group.  The
        checks compose: engine-level prerequisites first (unit count, mesh,
        per-unit step flavor, head epilogue hooks), then the kernel-level
        packing contract (``ops.grouped_fuse_reason`` — per-position MXU
        mode, packed-arena VMEM budget)."""
        if len(self._units) < 2:
            return ("fleet has a single unit; its step is already "
                    "single-dispatch")
        if self._model_axis is not None:
            return ("the megakernel cannot span the model-axis all_gather "
                    "of column-sharded layers")
        for st in self._units:
            what = f"group {st.name!r}: " if st.name else ""
            if st.fused_knob is False:
                return f"{what}fused=False pins the per-layer path"
            if not st.all_dense:
                return f"{what}the model graph has non-Dense nodes"
            epi = st.kernel_epi
            if epi is None:
                return (f"{what}head {st.head.name!r} has no in-kernel "
                        "epilogue (kernel_epilogue() returned None)")
            if epi[0] not in ("logits", "mse") or \
                    epi[1] not in ("none", "window", "tail", "center"):
                return f"{what}unknown kernel epilogue spec {epi!r}"
            if epi[1] == "center" and not hasattr(st.head, "_center"):
                return (f"{what}'center' epilogue needs a head exposing a "
                        "_center() row")
            if type(st.head).prepare not in (DetectorHead.prepare,
                                             ForecastHead.prepare):
                return (f"{what}head {st.head.name!r} overrides prepare(); "
                        "the megakernel feeds the raw window and only "
                        "subsumes the base window/forecast views via the "
                        "zero-row contract")
        return ops.grouped_fuse_reason(
            [st.stack for st in self._units],
            names=[st.name or f"unit{i}"
                   for i, st in enumerate(self._units)],
            k0=max(st.window * self.n_features for st in self._units))

    def _mega_applicable(self, key: Tuple) -> bool:
        """True when THIS ready-combination runs as one megakernel dispatch:
        the engine packs, more than one unit co-fired, and the co-firing
        units agree on (padded streams, window, block length) — stacking
        their rings needs one shape.  Units with equal windows always fire
        with equal block lengths, so steady state of a uniform-geometry
        fleet (the heterogeneous bench fleet) is always mega."""
        if not self._mega or len(key) < 2:
            return False
        sts = [self._units[gi] for gi, _ in key]
        return (len({(st.s_pad, st.window) for st in sts}) == 1
                and len({length for _, length in key}) == 1)

    def _mega_pack(self, subset: Tuple[int, ...]) -> _MegaPack:
        """The packed arenas + static geometry for one ready subset."""
        pack = self._mega_packs.get(subset)
        if pack is not None:
            return pack
        sts = [self._units[gi] for gi in subset]
        kinds = [ops.GROUPED_KIND_LOGITS if st.kernel_epi[0] == "logits"
                 else ops.GROUPED_KIND_SCORE for st in sts]
        plan, arrays = ops.build_grouped_plan(
            [st.stack for st in sts], kinds,
            k0=max(st.window * self.n_features for st in sts))
        centers = np.zeros((len(sts), 1, plan.n_out), np.float32)
        for k, st in enumerate(sts):
            if st.kernel_epi[1] == "center":
                c = np.asarray(st.head._center(), np.float32)
                centers[k, 0, :c.shape[0]] = c
        widths = tuple(
            plan.n_outs[k] if kinds[k] == ops.GROUPED_KIND_LOGITS else 1
            for k in range(len(sts)))
        adapt_sig = tuple(
            None if st.adapt is None else
            (type(st.head).calib_update, st.adapt.capacity,
             st.adapt.headroom) for st in sts)
        sig = (plan, tuple((st.s_pad, st.window) for st in sts),
               tuple(st.kernel_epi for st in sts), adapt_sig)
        pack = _MegaPack(
            plan=plan, arrays=arrays, centers=jnp.asarray(centers),
            tgt_sels=tuple(st.kernel_epi[1] for st in sts), widths=widths,
            heads=tuple(st.head for st in sts),
            adapts=tuple(st.adapt for st in sts), sig=sig)
        self._mega_packs[subset] = pack
        return pack

    def _get_mega_step(self, subset: Tuple[int, ...],
                       length: int) -> Tuple[Callable, _MegaPack]:
        """The jitted single-dispatch step for a ready subset + block shape.

        The step is cached on ``(pack.sig, length)`` — geometry, not unit
        identity — so every equal-shape ready-combination reuses one
        executable; the packed arenas, margin centers, positions and live
        thresholds are runtime operands."""
        pack = self._mega_pack(subset)
        cache_key = (pack.sig, length)
        step = self._mega_steps.get(cache_key)
        if step is not None:
            return step, pack
        plan = pack.plan
        heads, adapts = pack.heads, pack.adapts
        backend = self._backend
        n = len(subset)
        w = self._units[subset[0]].window
        f = self.n_features
        # Per-slot epilogue-target selectors as (G, 1, 1) closure constants:
        # deterministic from pack.sig, so step sharing stays sound.
        t_win = np.asarray([s == "window" for s in pack.tgt_sels]
                           ).reshape(n, 1, 1)
        t_tail = np.asarray([s == "tail" for s in pack.tgt_sels]
                            ).reshape(n, 1, 1)

        def _mega(rings, calibs, countss, block, poss, thrs, arrays,
                  centers):
            # block: (G, S, L, F) stacked pending readings; poss/thrs are
            # (G,) vectors.  Same trim-then-scatter contract as the
            # per-group body, batched over the group axis.
            with jax.named_scope("ring_scatter"):
                arena = jnp.stack(rings)                       # (G, S, W, F)
                s = arena.shape[1]
                length_ = block.shape[2]
                off = max(length_ - w, 0)
                idx = (poss[:, None] + off
                       + jnp.arange(length_ - off)[None, :]) % w
                arena = arena.at[
                    jnp.arange(n)[:, None, None],
                    jnp.arange(s)[None, :, None],
                    idx[:, None, :]].set(block[:, :, off:])
                end = (poss + length_) % w
                widx = (end[:, None] + jnp.arange(w)[None, :]) % w
                win = jnp.take_along_axis(
                    arena, widx[:, None, :, None], axis=2)
                win = win.reshape(n, s, w * f)
            with jax.named_scope("megakernel/group_pack"):
                # Uniform geometry makes the window width the union input
                # width (plan.k0 == w * f); heads whose model eats less
                # (forecast) are handled by zero weight rows, not slicing.
                win_no = ops._fit_cols(win, plan.n_out)
                tail_no = ops._fit_cols(win[:, :, w * f - f:], plan.n_out)
                tgt = jnp.where(
                    t_win, win_no,
                    jnp.where(t_tail, tail_no,
                              jnp.broadcast_to(centers, win_no.shape)))
            payload = ops.grouped_apply(win, plan, arrays, tgt,
                                        backend=backend)
            new_calibs, new_counts = [], []
            for k in range(n):
                if adapts[k] is not None:
                    c, cnt = heads[k].calib_update(
                        calibs[k], countss[k], payload[k][:, :1], thrs[k],
                        adapts[k].headroom)
                else:
                    c, cnt = calibs[k], countss[k]
                new_calibs.append(c)
                new_counts.append(cnt)
            return (tuple(arena[k] for k in range(n)), tuple(new_calibs),
                    tuple(new_counts), payload)

        if self.mesh is not None:
            # Rings/calib state keep their per-unit P("data", ...) specs;
            # the stacked block and payload shard their STREAM axis (axis
            # 1); packed arenas, meta, centers, positions and thresholds
            # are replicated operands.  check_rep=False: pallas_call
            # carries no replication rule.
            _mega = shard_map(
                _mega, mesh=self.mesh,
                in_specs=((P("data", None, None),) * n,
                          (P("data", None),) * n, (P("data"),) * n,
                          P(None, "data", None, None), P(), P(), P(), P()),
                out_specs=((P("data", None, None),) * n,
                           (P("data", None),) * n, (P("data"),) * n,
                           P(None, "data", None)),
                check_rep=False)
        step = jax.jit(_mega, donate_argnums=(0, 1, 2))
        self._mega_steps[cache_key] = step
        return step, pack

    def _dispatch_mega(self, key: Tuple) -> Tuple[Any, _MegaPack]:
        """Build operands for a ready-combination, advance per-unit serving
        state and fire the single-dispatch step.  Returns (payload future,
        pack) — the caller wraps them into an :class:`_InFlight`."""
        sts = [self._units[gi] for gi, _ in key]
        length = key[0][1]
        full = np.stack(self._pending[-length:], axis=1)   # (streams, L, F)
        blocks, poss, thrs = [], [], []
        for (gi, _), st in zip(key, sts):
            span = self._count - st.consumed
            block = full[st.offset:st.offset + st.n_streams]
            if st.s_pad != st.n_streams:
                block = np.pad(
                    block, ((0, st.s_pad - st.n_streams), (0, 0), (0, 0)))
            blocks.append(block)
            poss.append((st.pos + (span - length)) % st.window)
            thrs.append(0.0 if st.live_threshold is None
                        else st.live_threshold)
            st.pos = (st.pos + span) % st.window
            st.consumed = self._count
            st.fires += 1
        step, pack = self._get_mega_step(tuple(gi for gi, _ in key), length)
        new_rings, new_calibs, new_counts, payload = step(
            tuple(self._rings[gi] for gi, _ in key),
            tuple(self._calibs[gi] for gi, _ in key),
            tuple(self._counts[gi] for gi, _ in key),
            self._place(np.stack(blocks), self._block4_sharding),
            jnp.asarray(poss, jnp.int32), jnp.asarray(thrs, jnp.float32),
            pack.arrays, pack.centers)
        for (gi, _), ring, calib, counts in zip(key, new_rings, new_calibs,
                                                new_counts):
            self._rings[gi] = ring
            self._calibs[gi] = calib
            self._counts[gi] = counts
        return payload, pack

    def _mega_example_args(self, key: Tuple) -> Tuple[Callable, Tuple]:
        """(step, zeroed operands) for a ready-combination's megakernel
        step — the warmup compile driver, and the introspection surface the
        jaxpr dispatch-count suites trace (``jax.make_jaxpr(step)(*args)``
        shows exactly one ``pallas_call`` under ``backend='pallas'``)."""
        subset = tuple(gi for gi, _ in key)
        length = key[0][1]
        step, pack = self._get_mega_step(subset, length)
        sts = [self._units[gi] for gi in subset]
        rings = tuple(self._place(jnp.zeros(
            (st.s_pad, st.window, self.n_features), jnp.float32))
            for st in sts)
        states = [self._calib_state(st) for st in sts]
        block = self._place(
            jnp.zeros((len(sts), sts[0].s_pad, length, self.n_features),
                      jnp.float32), self._block4_sharding)
        poss = jnp.zeros((len(sts),), jnp.int32)
        thrs = jnp.asarray([0.0 if st.live_threshold is None
                            else st.live_threshold for st in sts],
                           jnp.float32)
        return step, (rings, tuple(c for c, _ in states),
                      tuple(cnt for _, cnt in states), block, poss, thrs,
                      pack.arrays, pack.centers)

    # -- readiness schedule ------------------------------------------------

    def _ready(self, st: _UnitState, count: int) -> bool:
        return (count >= st.window
                and (count - st.window) % self.stride == 0)

    def _schedule_keys(self) -> List[Tuple]:
        """Every distinct ready-combination key the serve loop will hit, by
        simulating the (deterministic) readiness schedule through window
        fill-in plus one full steady-state stride period."""
        keys: List[Tuple] = []
        consumed = {i: 0 for i in range(len(self._units))}
        for count in range(1, self.max_window + self.stride + 1):
            key = []
            for gi, st in enumerate(self._units):
                if self._ready(st, count):
                    span = count - consumed[gi]
                    key.append((gi, min(span, st.window)))
                    consumed[gi] = count
            if key and tuple(key) not in keys:
                keys.append(tuple(key))
        return keys

    def warmup(self) -> None:
        """Compile every step shape the readiness schedule can produce —
        each unit's window-fill firing and the steady-state all-ready step
        — outside the serve clock, with the serve-time arena sharding.

        Routing mirrors :meth:`ingest`: multi-unit uniform-geometry keys
        compile the megakernel step (cached per BLOCK SHAPE, so distinct
        ready-combinations of equal shape compile once), everything else
        the per-group step."""
        for key in self._schedule_keys():
            if self._mega_applicable(key):
                step, args = self._mega_example_args(key)
                *_, payload = step(*args)
                jax.block_until_ready(payload)
                continue
            rings = tuple(self._place(jnp.zeros(
                (self._units[gi].s_pad, self._units[gi].window,
                 self.n_features), jnp.float32)) for gi, _ in key)
            states = [self._calib_state(self._units[gi]) for gi, _ in key]
            blocks = tuple(self._place(jnp.zeros(
                (self._units[gi].s_pad, length, self.n_features),
                jnp.float32)) for gi, length in key)
            poss = tuple(jnp.int32(0) for _ in key)
            thrs = tuple(self._thr(self._units[gi]) for gi, _ in key)
            *_, outs = self._get_step(key)(
                rings, tuple(c for c, _ in states),
                tuple(n for _, n in states), blocks, poss, thrs)
            jax.block_until_ready(outs)

    # -- ingestion ---------------------------------------------------------

    def ingest(self, readings: np.ndarray) -> List[Verdict]:
        """One scan cycle of fleet readings -> verdicts (usually empty).

        ``readings`` is ``(n_streams, n_features)`` raw sensor values over
        the whole fleet (unit slices concatenated in unit order); the
        engine applies the PLC-side normalization itself.

        Synchronous mode returns this boundary's verdicts.  Under
        ``async_depth=1`` a ready boundary first harvests the *previous*
        in-flight step's verdicts (returned now, one boundary late, with
        dispatch→harvest latency accounting), then dispatches this
        boundary's step without blocking on it.
        """
        t0 = time.perf_counter()
        readings = np.asarray(readings, np.float32)
        if readings.shape != (self.n_streams, self.n_features):
            raise ValueError(
                f"expected ({self.n_streams}, {self.n_features}) readings, "
                f"got {readings.shape}")
        self._pending.append((readings - self._mean) / self._std)
        # stride > window: readings older than the last `max_window` can
        # never land in any ring, so drop them HERE — host memory,
        # host->device transfer and the compiled block shapes all stay
        # capped at the window.
        if len(self._pending) > self.max_window:
            del self._pending[:len(self._pending) - self.max_window]
        self._count += 1
        self.stats.cycles += 1

        ready = [(gi, st) for gi, st in enumerate(self._units)
                 if self._ready(st, self._count)]
        if not ready:
            self.stats.wall_s += time.perf_counter() - t0
            return []

        # Async: harvest BEFORE dispatching — the harvested step's calib
        # state is about to be donated into the new step, and recalibrating
        # the live threshold first reproduces the sync loop's operand
        # ordering exactly (the new step's thr operand bit-matches).
        verdicts = self._harvest() if self.async_depth else []

        mega_key = tuple(
            (gi, min(self._count - st.consumed, st.window))
            for gi, st in ready)
        if self._mega_applicable(mega_key):
            # Single-dispatch megakernel step over the whole ready subset.
            outs, pack = self._dispatch_mega(mega_key)
            key, unpack = list(mega_key), pack.unpack
            self.stats.dispatches += 1
        else:
            key, rings, calibs, countss, blocks, poss, thrs = \
                [], [], [], [], [], [], []
            for gi, st in ready:
                # span = cycles elapsed since the unit's last fired step;
                # the pruned pending tail holds at least the last
                # min(span, window) readings.
                span = self._count - st.consumed
                length = min(span, st.window)
                block = np.stack(self._pending[-length:], axis=1)  # (S,L,F)
                block = block[st.offset:st.offset + st.n_streams]
                if st.s_pad != st.n_streams:
                    block = np.pad(
                        block,
                        ((0, st.s_pad - st.n_streams), (0, 0), (0, 0)))
                # The ring write always ends at (pos + span - 1) mod window;
                # host-side trimming of long spans shifts the start to
                # match.
                eff_pos = (st.pos + (span - length)) % st.window
                key.append((gi, length))
                rings.append(self._rings[gi])
                calibs.append(self._calibs[gi])
                countss.append(self._counts[gi])
                blocks.append(self._place(block))
                poss.append(jnp.int32(eff_pos))
                thrs.append(self._thr(st))
                st.pos = (st.pos + span) % st.window
                st.consumed = self._count
                st.fires += 1

            new_rings, new_calibs, new_counts, outs = \
                self._get_step(tuple(key))(
                    tuple(rings), tuple(calibs), tuple(countss),
                    tuple(blocks), tuple(poss), tuple(thrs))
            for (gi, _), ring, calib, counts in zip(key, new_rings,
                                                    new_calibs, new_counts):
                self._rings[gi] = ring
                self._calibs[gi] = calib
                self._counts[gi] = counts
            unpack = _unpack_pergroup
            self.stats.dispatches += sum(
                self._units[gi].dispatch_cost for gi, _ in key)
        self.stats.steps += 1

        flight = _InFlight(tuple(key), outs, self._count - 1, t0, unpack)
        if self.async_depth:
            # Dispatch-and-return: the step's outputs stay in flight until
            # the next ready boundary (or flush) harvests them — device
            # compute overlaps the host ingest of the next stride.
            self._inflight = flight
        else:
            verdicts = self._finalize(flight)
        self.stats.wall_s += time.perf_counter() - t0
        return verdicts

    def _harvest(self) -> List[Verdict]:
        """Finalize the in-flight step, if any (async_depth=1)."""
        flight, self._inflight = self._inflight, None
        return [] if flight is None else self._finalize(flight)

    def _finalize(self, flight: _InFlight) -> List[Verdict]:
        """Block on a dispatched step's outputs and turn them into verdicts
        (+ harvest-side accounting + adapt recalibration).  Shared verbatim
        between the sync path (called right after dispatch) and the async
        path (called at the next boundary / flush), so verdict content is
        bit-identical across modes."""
        outs = flight.unpack(jax.block_until_ready(flight.outs))
        latency = time.perf_counter() - flight.t0
        miss = latency > self.deadline_s
        verdicts: List[Verdict] = []
        for (gi, _), out in zip(flight.key, outs):
            st = self._units[gi]
            # Gathers each device's shard of outputs to the host (the mega
            # unpack also slices each slot's true payload width); pad-stream
            # rows are dropped here and never surface as verdicts.
            out = out[:st.n_streams]
            self.last_outputs[st.name] = out
            # Streaming recalibration: re-host the offline score-then-
            # quantile sequence on the rolling state (pad rows sliced off —
            # zero streams still score, so they must stay out of the pool).
            # In async mode this runs before the NEXT dispatch, so the
            # state read here is exactly this step's output.
            if st.adapt is not None and st.fires % st.adapt.every == 0:
                thr = st.head.streaming_threshold(
                    np.asarray(self._calibs[gi])[:st.n_streams],
                    np.asarray(self._counts[gi])[:st.n_streams],
                    min_count=st.adapt.min_count)
                if thr is not None:
                    st.live_threshold = thr
            # Host epilogue via the head: classifier -> argmax/softmax,
            # score heads -> score vs the unit's LIVE threshold (the
            # offline cutoff unless adaptation has moved it).
            pred, prob, score, thr = st.head.host_verdicts(
                out, threshold=st.live_threshold)
            for i in range(st.n_streams):
                verdicts.append(Verdict(
                    stream=st.offset + i, cycle=flight.cycle,
                    pred=int(pred[i]),
                    prob=None if prob is None else float(prob[i]),
                    latency_s=latency, deadline_miss=miss,
                    score=None if score is None else float(score[i]),
                    threshold=thr, group=st.name))
            st.windows += st.n_streams
            self.stats.windows += st.n_streams
            self.stats.deadline_misses += int(miss) * st.n_streams
        self.stats.latencies_s.append(latency)
        return verdicts

    def flush(self) -> List[Verdict]:
        """Drain the in-flight verdict step (``async_depth=1``); returns
        ``[]`` when nothing is in flight (always, in sync mode).  Call at
        end of stream — ``run()`` deliberately does not auto-flush, because
        a live fleet may keep streaming."""
        t0 = time.perf_counter()
        verdicts = self._harvest()
        self.stats.wall_s += time.perf_counter() - t0
        return verdicts

    def run(self, streams: Sequence[Any], n_cycles: int,
            on_verdict: Optional[Callable[[Verdict], None]] = None,
            ) -> List[Verdict]:
        """Drive a fleet of ``PlantStream``-likes for ``n_cycles`` cycles.

        Each stream's ``step()`` must yield an object with ``tb0_meas`` /
        ``wd_meas`` attributes (simulation cost is *not* counted into the
        engine's serve stats — only ingest time is).  Under ``async_depth=1``
        the returned verdicts trail one ready boundary and the final step
        stays in flight until :meth:`flush`.
        """
        if len(streams) != self.n_streams:
            raise ValueError(
                f"fleet size {len(streams)} != engine streams "
                f"{self.n_streams}")
        if self.n_features != 2:
            raise ValueError("run() reads the MSF (tb0_meas, wd_meas) "
                             "layout; use ingest() directly for other "
                             "feature sets")
        out: List[Verdict] = []
        readings = np.zeros((self.n_streams, self.n_features), np.float32)
        for _ in range(n_cycles):
            for i, s in enumerate(streams):
                r = s.step()
                readings[i, 0] = r.tb0_meas
                readings[i, 1] = r.wd_meas
            for v in self.ingest(readings):
                out.append(v)
                if on_verdict is not None:
                    on_verdict(v)
        return out
