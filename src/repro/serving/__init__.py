from repro.serving.continuous import ContinuousEngine, ServeStats
from repro.serving.cyclic import CyclicDecoder
from repro.serving.engine import Completion, Engine, Request

__all__ = ["ContinuousEngine", "CyclicDecoder", "Completion", "Engine",
           "Request", "ServeStats"]
