from repro.serving.cyclic import CyclicDecoder
from repro.serving.engine import Completion, Engine, Request

__all__ = ["CyclicDecoder", "Completion", "Engine", "Request"]
