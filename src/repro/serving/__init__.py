from repro.serving.continuous import ContinuousEngine, ServeStats
from repro.serving.cyclic import CyclicDecoder
from repro.serving.engine import Completion, Engine, Request
from repro.serving.streams import StreamEngine, StreamStats, Verdict

__all__ = ["ContinuousEngine", "CyclicDecoder", "Completion", "Engine",
           "Request", "ServeStats", "StreamEngine", "StreamStats", "Verdict"]
