from repro.serving.continuous import ContinuousEngine, ServeStats
from repro.serving.core import ServingCore, ServingUnit
from repro.serving.cyclic import CyclicDecoder
from repro.serving.engine import Completion, Engine, Request
from repro.serving.grouped import GroupedStreamEngine, ModelGroup
from repro.serving.streams import (AdaptConfig, LatencyReservoir, StreamEngine,
                                   StreamStats, Verdict)

__all__ = ["AdaptConfig", "ContinuousEngine", "CyclicDecoder", "Completion",
           "Engine", "GroupedStreamEngine", "LatencyReservoir", "ModelGroup",
           "Request", "ServeStats", "ServingCore", "ServingUnit",
           "StreamEngine", "StreamStats", "Verdict"]
