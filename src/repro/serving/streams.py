"""Fleet-scale streaming anomaly detection: many plants, one detector step.

The §7 case study runs the 400-64-32-16-2 detector on *one* plant, offline,
in float.  :class:`StreamEngine` serves a **fleet**: it ingests one sensor
reading per plant per scan cycle, maintains a per-stream ring-buffer sliding
window (the paper's 2 features x 10 Hz x 20 s = 400-input window), and when
windows complete it batches **all ready streams into one jitted, donated
detector step** — ring-buffer scatter write, modular window unroll, and the
batched MLP forward fused into a single XLA computation, with the ring arena
donated across steps (the ICSML dataMem discipline).

``StreamEngine`` is the one-model façade over the shared
:class:`~repro.serving.core.ServingCore` pipeline (``GroupedStreamEngine``
is the many-model one): ring-arena geometry, the pad-stream contract,
warmup schedules, serve accounting, async double-buffering and the
adapt-recalibration loop all live in ``serving/core.py`` — this module
adds only the single-model constructor vocabulary and its historical
introspection surface (``last_logits``, ``_ring``, ``_step``, ...).

**Detector heads.** What a verdict *is* comes from a
:class:`repro.sim.heads.DetectorHead`: the default :class:`ClassifierHead`
reproduces the §7 classifier (argmax class + softmax probability), while a
calibrated :class:`ReconstructionHead` serves the unsupervised autoencoder
workload — its device epilogue reduces the (S, 400) reconstructions to an
(S, 1) anomaly score *inside* the jitted step (sharded and unsharded), so
the host receives one float per stream and compares it against the
FPR-calibrated threshold.  Heads are row-local, so they compose with fleet
sharding without new collectives.

**Online drift adaptation.**  A threshold calibrated once, offline, floods
with false alarms when the plant drifts (sensor recalibration, seasonal
load, wear creep the benign score distribution).  With ``adapt=`` the
engine maintains the head's rolling benign-score calibration state *inside*
the donated jitted step (``ScoreHead.calib_update`` — a per-stream score
ring, row-local, so it shards with the arena with zero new collectives) and
periodically re-hosts the offline score-then-quantile calibration sequence
on it (``ScoreHead.streaming_threshold`` — ``conservative_quantile`` of the
trailing admitted scores at the head's recorded ``target_fpr``).  The
engine's ``live_threshold`` starts at the offline-calibrated cutoff and
tracks the streaming quantile; every ``Verdict.threshold`` reports the live
value.  Scores beyond ``AdaptConfig.headroom`` times the live threshold are
treated as attacks and never enter the calibration state, so an attacked
stream cannot drag the fleet threshold up after itself.

Quantized serving (§6.1) runs the same step with SINT/INT/DINT params from
``repro.core.quantize``: SINT (int8) layers go through the Pallas
``qmatmul`` int8 MXU path via ``repro.kernels.ops.quantized_matmul``
(oracle math on CPU, kernel on TPU); INT/DINT layers use the f32-emulated
integer arithmetic, exactly like ``layers._quantized_matvec``.

For all-Dense models (the detector) the per-layer loop is replaced by the
fused whole-MLP kernel (``repro.kernels.fused_mlp``): every verdict step is
ONE Pallas dispatch with all weights VMEM-resident and, under SINT, in-kernel
requantization between layers — the §6 fused-quantized-arithmetic
optimization re-hosted on TPU.  (Heterogeneous *multi-model* fleets get the
same guarantee from the grouped megakernel — see
:class:`~repro.serving.grouped.GroupedStreamEngine` and the ``serving/core``
docstring; a single-model engine's step is already single-dispatch.)

Between verdict cycles the engine touches no device state at all: readings
accumulate host-side and are scattered into the ring inside the next detector
step, so a stride-10 fleet pays one dispatch per verdict cadence rather than
one per scan cycle.  Per-window latency/deadline accounting follows the
``ServeStats`` conventions of ``serving/continuous.py``; with
``async_depth=1`` the engine double-buffers — ``ingest()`` dispatches step
N and returns, harvesting step N-1's in-flight verdicts at the next ready
boundary (see the ``serving/core.py`` docstring for the accounting
semantics and ``flush()``).

**Fleet sharding.** On a multi-device process the engine partitions the
stream axis over the ``"data"`` axis of a fleet mesh
(``launch.mesh.make_fleet_mesh``): the ring arena, the pending-reading
block and the verdict logits are all ``NamedSharding(mesh, P("data", ...))``,
and the donated step runs under ``shard_map`` so each device executes the
detector step — including the single fused Pallas dispatch — on its own
contiguous shard of streams, with no cross-device traffic on the hot path.
A 2-D ``("data", "model")`` mesh (``make_fleet_mesh(..., model_shards=m)``)
additionally column-shards wide Dense layers over the model axis — one
tiled ``all_gather`` per wide layer recombines the activations (see
``serving/core.py``).  Fleet sizes not divisible by the data-axis device
count are padded with silent zero streams (the *pad-stream contract*):
pad rows ride through scatter/unroll/forward like real streams, their logits
are sliced off before any verdict is emitted, and they never enter the
serve accounting.  Sharding is off by default on a single-device process;
``shard=True`` / an explicit ``mesh`` forces it, ``shard=False`` pins the
classic unsharded step.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import msf_detector as spec
from repro.core.model import Model, ParamTree
from repro.serving.core import (  # noqa: F401  (historical import surface)
    AdaptConfig, LatencyReservoir, ServingCore, ServingUnit, StreamStats,
    Verdict, _dense_batched, _layer_stack, _resolve_adapt)
from repro.sim.heads import DetectorHead


class StreamEngine(ServingCore):
    """Batched sliding-window detector service over ``n_streams`` plants.

    Per scan cycle, call :meth:`ingest` with one ``(n_streams, n_features)``
    reading block.  The first verdict batch fires once every stream has seen
    ``window`` readings, then every ``stride`` cycles.  All device work —
    scattering the pending readings into the per-stream ring buffers,
    unrolling the windows oldest-first, and the batched (quantized) MLP —
    happens in one jitted step with the ring donated.

    ``backend`` is forwarded to the Pallas paths: 'auto' (Pallas on TPU,
    oracle math on CPU), 'pallas' (interpret mode off-TPU), or 'ref'.

    When the model is an all-Dense stack with pad-safe activations (the
    detector's case), the batched MLP runs through
    ``ops.fused_forward`` — ONE Pallas dispatch for the whole network,
    weights VMEM-resident, activations never round-tripping to HBM, SINT
    requantizing in-kernel between layers.  ``fused=None`` (default)
    auto-selects; ``fused=False`` forces the per-layer loop (one
    qmatmul/matmul dispatch per layer); ``fused=True`` raises if the model
    cannot fuse (or if the mesh model-shards the layers — the kernel cannot
    span the model-axis gather).

    ``head`` selects the verdict semantics (module docstring): default
    :class:`~repro.sim.heads.ClassifierHead`; pass a calibrated
    :class:`~repro.sim.heads.ReconstructionHead` to serve an autoencoder
    (``last_logits`` then holds the per-stream anomaly scores, shape
    ``(n_streams, 1)``).

    ``shard``/``mesh`` control stream-axis fleet sharding (module docstring):
    ``shard=None`` auto-enables it when the process has more than one device,
    ``shard=True`` forces it (a 1-device mesh still runs the shard_map path),
    ``shard=False`` pins the classic unsharded step.  ``mesh`` supplies the
    device mesh (any mesh whose ``"data"`` axis carries the streams; a
    ``"model"`` axis of any size column-shards wide layers, and other axes
    must have size 1); it defaults to ``make_fleet_mesh()`` over every
    visible device.

    ``adapt`` turns on streaming threshold recalibration (module docstring):
    ``True`` uses the default :class:`AdaptConfig`, an explicit config tunes
    the rolling-state geometry and cadence.  Requires a calibrated
    :class:`~repro.sim.heads.ScoreHead` with a recorded ``target_fpr``; the
    engine's ``live_threshold`` then tracks the sliding benign-score
    quantile and every verdict reports it.  Constructor-only knob like
    ``fused``/``head``.

    ``async_depth=1`` opts into the double-buffered pipeline (module
    docstring): verdicts bit-match sync mode, delivered one ready boundary
    later; drain with :meth:`flush`.
    """

    def __init__(self, model: Model, params: ParamTree, *,
                 n_streams: int,
                 n_features: int = spec.N_FEATURES,
                 window: Optional[int] = None,
                 stride: int = spec.STRIDE,
                 deadline_s: float = spec.DEADLINE_S,
                 norm_mean: Sequence[float] = spec.NORM_MEAN,
                 norm_std: Sequence[float] = spec.NORM_STD,
                 backend: str = "auto",
                 fused: Optional[bool] = None,
                 head: Optional[DetectorHead] = None,
                 shard: Optional[bool] = None,
                 mesh: Optional[Mesh] = None,
                 adapt: Union[bool, AdaptConfig, None] = None,
                 async_depth: int = 0):
        super().__init__(
            [ServingUnit(name=None, model=model, params=params,
                         n_streams=n_streams, head=head, fused=fused,
                         adapt=adapt, window=window)],
            n_features=n_features, stride=stride, deadline_s=deadline_s,
            norm_mean=norm_mean, norm_std=norm_std, backend=backend,
            shard=shard, mesh=mesh, async_depth=async_depth)
        unit = self._units[0]
        self.model = model
        self.window = unit.window
        # Resolved constructor-only knobs, surfaced for introspection (the
        # step bodies captured their own copies — reassigning these changes
        # nothing, by design).
        self.head = unit.head
        self.fused = unit.use_fused
        self.adapt = unit.adapt
        self.shard_streams = unit.s_pad // self.n_shards
        self._legacy_step = None

    # -- single-model introspection over the shared core -------------------

    @property
    def last_logits(self) -> Optional[np.ndarray]:
        """The last verdict step's (real-stream) outputs."""
        return self.last_outputs.get(self._units[0].name)

    @property
    def live_threshold(self) -> Optional[float]:
        return self._units[0].live_threshold

    @live_threshold.setter
    def live_threshold(self, value: Optional[float]) -> None:
        self._units[0].live_threshold = value

    @property
    def _s_pad(self) -> int:
        return self._units[0].s_pad

    @property
    def _ring(self) -> jax.Array:
        return self._rings[0]

    @property
    def _calib_ring(self) -> jax.Array:
        return self._calibs[0]

    @property
    def _calib_counts(self) -> jax.Array:
        return self._counts[0]

    @property
    def _step(self):
        """The classic single-model step — ``(ring, block, pos)`` without
        adaptation, ``(ring, calib, counts, block, pos, thr)`` with — built
        from the exact unit body the serving steps run (the dispatch-count
        and out-shape suites trace this)."""
        if self._legacy_step is None:
            self._legacy_step = self._single_step_view()
        return self._legacy_step
