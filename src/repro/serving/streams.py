"""Fleet-scale streaming anomaly detection: many plants, one detector step.

The §7 case study runs the 400-64-32-16-2 detector on *one* plant, offline,
in float.  :class:`StreamEngine` serves a **fleet**: it ingests one sensor
reading per plant per scan cycle, maintains a per-stream ring-buffer sliding
window (the paper's 2 features x 10 Hz x 20 s = 400-input window), and when
windows complete it batches **all ready streams into one jitted, donated
detector step** — ring-buffer scatter write, modular window unroll, and the
batched MLP forward fused into a single XLA computation, with the ring arena
donated across steps (the ICSML dataMem discipline).

**Detector heads.** What a verdict *is* comes from a
:class:`repro.sim.heads.DetectorHead`: the default :class:`ClassifierHead`
reproduces the §7 classifier (argmax class + softmax probability), while a
calibrated :class:`ReconstructionHead` serves the unsupervised autoencoder
workload — its device epilogue reduces the (S, 400) reconstructions to an
(S, 1) anomaly score *inside* the jitted step (sharded and unsharded), so
the host receives one float per stream and compares it against the
FPR-calibrated threshold.  Heads are row-local, so they compose with fleet
sharding without new collectives.

**Online drift adaptation.**  A threshold calibrated once, offline, floods
with false alarms when the plant drifts (sensor recalibration, seasonal
load, wear creep the benign score distribution).  With ``adapt=`` the
engine maintains the head's rolling benign-score calibration state *inside*
the donated jitted step (``ScoreHead.calib_update`` — a per-stream score
ring, row-local, so it shards with the arena with zero new collectives) and
periodically re-hosts the offline score-then-quantile calibration sequence
on it (``ScoreHead.streaming_threshold`` — ``conservative_quantile`` of the
trailing admitted scores at the head's recorded ``target_fpr``).  The
engine's ``live_threshold`` starts at the offline-calibrated cutoff and
tracks the streaming quantile; every ``Verdict.threshold`` reports the live
value.  Scores beyond ``AdaptConfig.headroom`` times the live threshold are
treated as attacks and never enter the calibration state, so an attacked
stream cannot drag the fleet threshold up after itself.

Quantized serving (§6.1) runs the same step with SINT/INT/DINT params from
``repro.core.quantize``: SINT (int8) layers go through the Pallas
``qmatmul`` int8 MXU path via ``repro.kernels.ops.quantized_matmul``
(oracle math on CPU, kernel on TPU); INT/DINT layers use the f32-emulated
integer arithmetic, exactly like ``layers._quantized_matvec``.

For all-Dense models (the detector) the per-layer loop is replaced by the
fused whole-MLP kernel (``repro.kernels.fused_mlp``): every verdict step is
ONE Pallas dispatch with all weights VMEM-resident and, under SINT, in-kernel
requantization between layers — the §6 fused-quantized-arithmetic
optimization re-hosted on TPU.

Between verdict cycles the engine touches no device state at all: readings
accumulate host-side and are scattered into the ring inside the next detector
step, so a stride-10 fleet pays one dispatch per verdict cadence rather than
one per scan cycle.  Per-window latency/deadline accounting follows the
``ServeStats`` conventions of ``serving/continuous.py``.

**Fleet sharding.** On a multi-device process the engine partitions the
stream axis over a 1-D ``("data",)`` mesh (``launch.mesh.make_fleet_mesh``):
the ring arena, the pending-reading block and the verdict logits are all
``NamedSharding(mesh, P("data", ...))``, and the donated step runs under
``shard_map`` so each device executes the detector step — including the
single fused Pallas dispatch — on its own contiguous shard of streams, with
no cross-device traffic on the hot path.  Fleet sizes not divisible by the
device count are padded with silent zero streams (the *pad-stream contract*):
pad rows ride through scatter/unroll/forward like real streams, their logits
are sliced off before any verdict is emitted, and they never enter the
serve accounting.  Sharding is off by default on a single-device process;
``shard=True`` / an explicit ``mesh`` forces it, ``shard=False`` pins the
classic unsharded step.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import msf_detector as spec
from repro.core.layers import ACTIVATIONS
from repro.core.model import Model, ParamTree
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.sim.heads import ClassifierHead, DetectorHead, ScoreHead


@dataclasses.dataclass
class Verdict:
    """One per-stream verdict on a completed window.

    The payload depends on the engine's :class:`~repro.sim.heads.DetectorHead`:
    a classifier head fills ``pred``/``prob`` (argmax class + its softmax
    probability, ``score``/``threshold`` None); a reconstruction head fills
    ``pred``/``score``/``threshold`` (pred = score over threshold, ``prob``
    None).  ``pred != 0`` always means "anomalous".
    """

    stream: int               # stream index in the fleet
    cycle: int                # scan cycle at which the window completed
    pred: int                 # verdict class (0 = normal)
    prob: Optional[float]     # classifier: softmax prob of the predicted class
    latency_s: float          # window-completion -> verdict-on-host wall time
    deadline_miss: bool       # latency_s > deadline_s
    score: Optional[float] = None       # score heads: anomaly score
    threshold: Optional[float] = None   # score heads: calibrated cutoff
    group: Optional[str] = None         # model-group name (grouped fleets)


# Default reservoir seeds come from a process-global counter, so every
# engine's reservoir draws a distinct replacement sequence: with a shared
# fixed seed, split engines (the grouped-vs-split bench) replaced the SAME
# retained indices in lockstep, correlating their percentile estimates.
_reservoir_seeds = itertools.count()


class LatencyReservoir:
    """Bounded uniform sample of verdict latencies (Vitter's Algorithm R).

    A long-lived fleet engine emits one latency per verdict step forever; an
    unbounded list leaks O(steps) host memory at millions of cycles.  The
    reservoir retains the first ``capacity`` samples verbatim (append order
    preserved, so short runs — tests, bench passes — see an exact list) and
    thereafter replaces a uniformly random retained sample with probability
    ``capacity / seen``, keeping the retained set a uniform draw from the
    whole history — percentile estimates stay statistically valid while
    memory stays O(capacity).

    List-like where it matters: ``len`` / truthiness / iteration / indexing
    and slicing cover every pre-reservoir consumer.  Slicing is only
    meaningful while the retained items are the exact append-ordered list,
    so once ``seen`` exceeds ``capacity`` (Algorithm R has replaced random
    retained indices) slice access **raises** instead of silently returning
    a uniform jumble — per-pass latency tails should come from
    :meth:`StreamStats.reset_latencies` instead.

    ``seed=None`` (the default) draws an engine-unique seed from a process
    counter; pass an explicit seed for reproducible replacement sequences.
    """

    __slots__ = ("capacity", "seen", "seed", "_items", "_rng")

    def __init__(self, capacity: int = 4096, seed: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.seen = 0                 # total appends ever observed
        self.seed = next(_reservoir_seeds) if seed is None else seed
        self._items: List[float] = []
        self._rng = np.random.default_rng(self.seed)

    def append(self, value: float) -> None:
        self.seen += 1
        if len(self._items) < self.capacity:
            self._items.append(float(value))
        else:
            j = int(self._rng.integers(self.seen))
            if j < self.capacity:
                self._items[j] = float(value)

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, idx):
        if isinstance(idx, slice) and self.seen > self.capacity:
            raise ValueError(
                f"latency tail slices are only exact below the reservoir "
                f"capacity ({self.capacity}); after {self.seen} appends "
                "Algorithm R has replaced random retained indices, so a "
                "slice is a uniform jumble, not a pass tail — take "
                "per-pass tails via StreamStats.reset_latencies()")
        return self._items[idx]

    def percentile(self, q: float) -> float:
        return float(np.percentile(self._items, q)) if self._items else 0.0


@dataclasses.dataclass
class StreamStats:
    """Aggregate serve accounting (ServeStats conventions).

    ``latencies_s`` is a bounded :class:`LatencyReservoir`, not a list: the
    engine appends one latency per verdict step for the life of the process,
    and the reservoir keeps ``latency_p`` statistically valid at O(1)
    memory (exact below its capacity)."""

    steps: int                       # jitted detector steps executed
    cycles: int                      # scan cycles ingested
    windows: int                     # verdicts emitted (streams x steps)
    deadline_misses: int
    wall_s: float                    # total time spent inside ingest()
    latencies_s: LatencyReservoir = dataclasses.field(
        default_factory=LatencyReservoir)

    def latency_p(self, q: float) -> float:
        return self.latencies_s.percentile(q)

    def reset_latencies(self) -> LatencyReservoir:
        """Swap in a fresh (same-capacity, fresh-seed) reservoir and return
        the retired one — the sanctioned way to take per-pass latency tails
        (benchmark passes): tail *slices* of a reservoir past its capacity
        are silently wrong, because Algorithm R replaces random retained
        indices, and therefore raise."""
        old = self.latencies_s
        self.latencies_s = LatencyReservoir(capacity=old.capacity)
        return old

    def windows_per_s(self) -> float:
        return self.windows / self.wall_s if self.wall_s > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Streaming threshold-recalibration policy (online drift adaptation).

    ``capacity`` is the per-stream rolling score-ring length (the sketch
    window: the live threshold is the conservative quantile of the trailing
    ``<= capacity`` admitted scores per stream, pooled fleet-wide).
    ``every`` recalibrates once per that many fired verdict steps; the
    device-side state update runs every step regardless.  ``min_count``
    holds the threshold at its offline-calibrated seed until that many
    scores have been admitted fleet-wide (early tiny pools make noisy
    quantiles).  ``headroom`` is the admission gate: scores at most
    ``headroom`` times the live threshold enter the calibration state —
    wide enough that gradual benign drift passes through the gate even when
    it crosses the threshold, tight enough that attack scores (orders of
    magnitude out) never poison the state.
    """

    capacity: int = 32
    every: int = 1
    min_count: int = 16
    headroom: float = 4.0

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        if self.min_count < 1:
            raise ValueError(f"min_count must be >= 1, got {self.min_count}")
        if self.headroom < 1.0:
            raise ValueError(
                f"headroom must be >= 1 (the gate must at least admit "
                f"sub-threshold scores), got {self.headroom}")


def _resolve_adapt(adapt: Union[bool, AdaptConfig, None],
                   head: DetectorHead, what: str = "") -> Optional[AdaptConfig]:
    """Validate and normalize an ``adapt=`` knob: None/False off, True the
    default policy, an :class:`AdaptConfig` verbatim.  Adaptation requires a
    calibrated :class:`ScoreHead` with a recorded ``target_fpr`` (the
    streaming quantile chases the same operating point the offline
    calibration chose)."""
    if adapt is None or adapt is False:
        return None
    cfg = AdaptConfig() if adapt is True else adapt
    if not isinstance(cfg, AdaptConfig):
        raise ValueError(f"{what}adapt must be None/bool/AdaptConfig, "
                         f"got {cfg!r}")
    if not isinstance(head, ScoreHead):
        raise ValueError(
            f"{what}adapt=True needs a score-vs-threshold head (ScoreHead); "
            f"the {head.name!r} head has no score distribution to "
            "recalibrate on")
    if head.threshold is None or head.target_fpr is None:
        raise ValueError(
            f"{what}adapt=True needs a calibrated head with a recorded "
            "target_fpr to seed and steer the live threshold "
            "(head.calibrate / the sim.detector trainers set both)")
    return cfg


def _layer_stack(model: Model, params: ParamTree) -> List[Tuple[Dict, str]]:
    """(params, activation) per Dense node in schedule order."""
    stack = ops.dense_stack(model, params)
    if not stack:
        raise ValueError("model has no Dense layers to serve")
    return stack


def _dense_batched(x: jax.Array, p: Dict, act: str, backend: str) -> jax.Array:
    """One Dense layer over a (M, K) batch, float or quantized (§6.1)."""
    if "qw" in p:
        qw = p["qw"]
        # Symmetric activation clip, matching quantize.quantize_tensor and
        # layers._quantized_matvec (the scale decodes [-qmax, qmax]).
        qmax = jnp.iinfo(qw.dtype).max
        xq = jnp.clip(jnp.round(x / p["x_scale"]), -qmax, qmax)
        scale = p["x_scale"] * p["w_scale"]
        if qw.dtype == jnp.int8:
            # SINT: native int8 dot product — the Pallas qmatmul MXU path.
            y = ops.quantized_matmul(xq.astype(qw.dtype), qw, scale,
                                     p.get("b"), backend=backend)
        else:
            # INT/DINT: int16/int32 products overflow int32 accumulation on
            # TPU, so the integer arithmetic is emulated in f32 (storage
            # compression is what these schemes buy — see layers.py).  No
            # round-trip through the int dtype: int32's qmax is not f32-
            # representable, so the cast would overflow at the clip rail.
            y = xq @ qw.astype(jnp.float32) * scale
            if p.get("b") is not None:
                y = y + p["b"]
    else:
        y = x @ p["w"]
        if "b" in p:
            y = y + p["b"]
    return ACTIVATIONS[act](y)


class StreamEngine:
    """Batched sliding-window detector service over ``n_streams`` plants.

    Per scan cycle, call :meth:`ingest` with one ``(n_streams, n_features)``
    reading block.  The first verdict batch fires once every stream has seen
    ``window`` readings, then every ``stride`` cycles.  All device work —
    scattering the pending readings into the per-stream ring buffers,
    unrolling the windows oldest-first, and the batched (quantized) MLP —
    happens in one jitted step with the ring donated.

    ``backend`` is forwarded to the Pallas paths: 'auto' (Pallas on TPU,
    oracle math on CPU), 'pallas' (interpret mode off-TPU), or 'ref'.

    When the model is an all-Dense stack with pad-safe activations (the
    detector's case), the batched MLP runs through
    ``ops.fused_forward`` — ONE Pallas dispatch for the whole network,
    weights VMEM-resident, activations never round-tripping to HBM, SINT
    requantizing in-kernel between layers.  ``fused=None`` (default)
    auto-selects; ``fused=False`` forces the per-layer loop (one
    qmatmul/matmul dispatch per layer); ``fused=True`` raises if the model
    cannot fuse.

    ``head`` selects the verdict semantics (module docstring): default
    :class:`~repro.sim.heads.ClassifierHead`; pass a calibrated
    :class:`~repro.sim.heads.ReconstructionHead` to serve an autoencoder
    (``last_logits`` then holds the per-stream anomaly scores, shape
    ``(n_streams, 1)``).

    ``shard``/``mesh`` control stream-axis fleet sharding (module docstring):
    ``shard=None`` auto-enables it when the process has more than one device,
    ``shard=True`` forces it (a 1-device mesh still runs the shard_map path),
    ``shard=False`` pins the classic unsharded step.  ``mesh`` supplies the
    device mesh (any mesh whose ``"data"`` axis carries the streams and whose
    other axes, if present, have size 1); it defaults to
    ``make_fleet_mesh()`` over every visible device.

    ``adapt`` turns on streaming threshold recalibration (module docstring):
    ``True`` uses the default :class:`AdaptConfig`, an explicit config tunes
    the rolling-state geometry and cadence.  Requires a calibrated
    :class:`~repro.sim.heads.ScoreHead` with a recorded ``target_fpr``; the
    engine's ``live_threshold`` then tracks the sliding benign-score
    quantile and every verdict reports it.  Constructor-only knob like
    ``fused``/``head``.
    """

    def __init__(self, model: Model, params: ParamTree, *,
                 n_streams: int,
                 n_features: int = spec.N_FEATURES,
                 window: Optional[int] = None,
                 stride: int = spec.STRIDE,
                 deadline_s: float = spec.DEADLINE_S,
                 norm_mean: Sequence[float] = spec.NORM_MEAN,
                 norm_std: Sequence[float] = spec.NORM_STD,
                 backend: str = "auto",
                 fused: Optional[bool] = None,
                 head: Optional[DetectorHead] = None,
                 shard: Optional[bool] = None,
                 mesh: Optional[Mesh] = None,
                 adapt: Union[bool, AdaptConfig, None] = None):
        (input_size,) = model.input_shape
        # Verdict-head routing: the head's device epilogue is traced into the
        # jitted step below (sharded and unsharded) and its host epilogue
        # turns step outputs into Verdict fields — the engine itself no
        # longer assumes a softmax/argmax classifier.  Constructor-only knob
        # (like ``fused``): both paths read the captured value, so a
        # post-construction reassignment of ``.head`` changes neither — the
        # already-traced step and the host epilogue can never desynchronize.
        self.head = self._verdict_head = \
            ClassifierHead() if head is None else head
        # Window geometry is the head's contract: for every head but
        # forecast the window IS the model input; the forecast head asks the
        # ring for one extra reading (its prediction target) and slices the
        # model input out of the window on device (head.prepare).
        if window is None:
            window = self._verdict_head.ring_window(input_size, n_features)
        if self._verdict_head.model_input_size(window, n_features) \
                != input_size:
            raise ValueError(
                f"window {window} x features {n_features} (head "
                f"{self._verdict_head.name!r}) != model input {input_size}")
        if not 1 <= stride:
            raise ValueError("stride must be >= 1")
        self.model = model
        self.n_streams = n_streams
        self.n_features = n_features
        self.window = window
        self.stride = stride
        self.deadline_s = deadline_s
        self._mean = np.asarray(norm_mean, np.float32)
        self._std = np.asarray(norm_std, np.float32)
        if self._mean.shape != (n_features,) or self._std.shape != (n_features,):
            raise ValueError("norm_mean/norm_std must have one entry per feature")
        self._stack = _layer_stack(model, params)
        self._backend = backend
        last = self._stack[-1][0]
        n_out = (last["qw"] if "qw" in last else last["w"]).shape[1]
        self._verdict_head.validate(input_size, n_out)
        fusable = ops.model_fusable(model, self._stack)
        if fused and not fusable:
            reason = ops.fuse_reason(self._stack) or \
                "the model graph has non-Dense nodes"
            raise ValueError(f"fused=True but the model cannot fuse: {reason}")
        # Constructor-only knob: captured as a local so a post-compile
        # mutation of the attribute can't leave already-traced step shapes
        # on a different path than freshly-traced ones.
        self.fused = use_fused = fusable if fused is None else fused

        if shard is False and mesh is not None:
            raise ValueError("shard=False contradicts an explicit mesh")
        if mesh is None and (shard or (shard is None
                                       and len(jax.devices()) > 1)):
            # Never mesh wider than the fleet: pure-pad shards would burn a
            # dispatch per device on zero streams every verdict cadence.
            mesh = make_fleet_mesh(min(len(jax.devices()), n_streams))
        if mesh is not None:
            if "data" not in mesh.axis_names:
                raise ValueError(f"fleet mesh needs a 'data' axis, got "
                                 f"{mesh.axis_names}")
            extra = [a for a in mesh.axis_names
                     if a != "data" and mesh.shape[a] != 1]
            if extra:
                raise ValueError(
                    f"non-'data' mesh axes must have size 1, got {extra}")
        self.mesh = mesh
        self.n_shards = 1 if mesh is None else mesh.shape["data"]
        # Pad-stream contract: the arena is padded so every device owns an
        # equal contiguous shard; pad rows are zero streams whose logits are
        # sliced off before verdicts and never enter the accounting.
        self._s_pad = -(-n_streams // self.n_shards) * self.n_shards
        self.shard_streams = self._s_pad // self.n_shards
        if mesh is not None:
            self._arena_sharding = NamedSharding(mesh, P("data", None, None))
            self._calib_sharding = NamedSharding(mesh, P("data", None))
            self._counts_sharding = NamedSharding(mesh, P("data"))
        else:
            self._arena_sharding = None
            self._calib_sharding = None
            self._counts_sharding = None

        # Streaming recalibration (constructor-only, like fused/head): the
        # live threshold starts at the offline-calibrated cutoff; score
        # heads without adaptation keep it pinned there forever.
        self.adapt = adapt_cfg = _resolve_adapt(adapt, self._verdict_head)
        self.live_threshold = (
            self._verdict_head.threshold
            if isinstance(self._verdict_head, ScoreHead) else None)

        w = window
        verdict_head = self._verdict_head

        def _forward(win: jax.Array) -> jax.Array:
            if use_fused:
                return ops.fused_forward(win, self._stack, backend=backend)
            x = win
            for p, act in self._stack:
                x = _dense_batched(x, p, act, backend)
            return x

        def _body(ring, block, pos):
            # block: (S, L, F) pending readings; L static per compile (the
            # warmup block is `window` long, steady-state blocks
            # `min(stride, window)` — ingest() trims longer spans host-side).
            # The device trim below is defense in depth for direct callers:
            # only the last `window` readings can ever land, and trimming
            # before scattering keeps the indices provably unique
            # (duplicate-index scatter-set order is undefined off-CPU).
            length = block.shape[1]
            offset = max(length - w, 0)
            idx = (pos + offset + jnp.arange(length - offset)) % w
            ring = ring.at[:, idx, :].set(block[:, offset:])
            # window unroll, oldest reading first: the ring holds exactly the
            # last `window` readings, ending at (pos + L - 1) mod window.
            end = (pos + length) % w
            widx = (end + jnp.arange(w)) % w
            win = jnp.take(ring, widx, axis=1).reshape(ring.shape[0], -1)
            # The head's device hooks run inside the jitted step: prepare is
            # the model-input view of the window (identity except forecast,
            # which slices off its target reading), and the epilogue reduces
            # score-head outputs to an (S, 1) score HERE, on device — under
            # sharding the host then gathers one float per stream, never
            # fleet x 400 payloads.  (Row-local, so shard_map needs no new
            # collectives.)
            return ring, verdict_head.epilogue(
                win, _forward(verdict_head.prepare(win)))

        if adapt_cfg is None:
            _step = _body
        else:
            headroom = adapt_cfg.headroom

            def _step(ring, calib, counts, block, pos, thr):
                # The rolling benign-score state advances INSIDE the donated
                # step: one row-local ring write per stream, gated on the
                # live threshold — no extra dispatch, no new collectives.
                ring, out = _body(ring, block, pos)
                calib, counts = verdict_head.calib_update(
                    calib, counts, out, thr, headroom)
                return ring, calib, counts, out

        if mesh is not None:
            # Each device runs the *whole* step body on its shard — ring
            # scatter, window unroll, the (fused Pallas) forward and the
            # calibration-state write are all stream-local, so the mesh
            # introduces zero collectives.  check_rep=False: pallas_call
            # carries no replication rule.
            if adapt_cfg is None:
                in_specs = (P("data"), P("data"), P())
                out_specs = (P("data"), P("data"))
            else:
                in_specs = (P("data"), P("data"), P("data"),
                            P("data"), P(), P())
                out_specs = (P("data"), P("data"), P("data"), P("data"))
            _step = shard_map(_step, mesh=mesh,
                              in_specs=in_specs, out_specs=out_specs,
                              check_rep=False)
        self._step = jax.jit(
            _step, donate_argnums=0 if adapt_cfg is None else (0, 1, 2))

        self._ring = self._place(
            jnp.zeros((self._s_pad, window, n_features), jnp.float32))
        if adapt_cfg is not None:
            calib0, counts0 = self._verdict_head.calib_state(
                self._s_pad, adapt_cfg.capacity)
            self._calib_ring = self._place(calib0, self._calib_sharding)
            self._calib_counts = self._place(counts0, self._counts_sharding)
        self._pos = 0                 # next ring write index (host-tracked)
        self._count = 0               # scan cycles ingested
        self._consumed = 0            # scan count at the last fired step
        self._pending: List[np.ndarray] = []
        self.last_logits: Optional[np.ndarray] = None
        self.stats = StreamStats(steps=0, cycles=0, windows=0,
                                 deadline_misses=0, wall_s=0.0)

    def _place(self, arr, sharding=None) -> jax.Array:
        """Commit an array to the fleet mesh (no-op unsharded); ``sharding``
        defaults to the 3-D arena sharding."""
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(
            arr, self._arena_sharding if sharding is None else sharding)

    def warmup(self) -> None:
        """Compile both detector-step shapes (the warmup block is one full
        window long, steady-state blocks are ``min(stride, window)`` long —
        ingest() trims longer strides host-side) outside the serve clock, so
        deadline accounting measures serving, not XLA.  Warmup arenas carry
        the serve-time sharding, so the compiled executables are exactly the
        sharded ones the steps will reuse."""
        for length in sorted({self.window, min(self.stride, self.window)}):
            ring = self._place(
                jnp.zeros((self._s_pad, self.window, self.n_features),
                          jnp.float32))
            block = self._place(
                jnp.zeros((self._s_pad, length, self.n_features), jnp.float32))
            if self.adapt is None:
                _, logits = self._step(ring, block, jnp.int32(0))
            else:
                calib0, counts0 = self._verdict_head.calib_state(
                    self._s_pad, self.adapt.capacity)
                *_, logits = self._step(
                    ring, self._place(calib0, self._calib_sharding),
                    self._place(counts0, self._counts_sharding),
                    block, jnp.int32(0), jnp.float32(self.live_threshold))
            jax.block_until_ready(logits)

    # -- ingestion ---------------------------------------------------------

    def _ready(self) -> bool:
        return (self._count >= self.window
                and (self._count - self.window) % self.stride == 0)

    def ingest(self, readings: np.ndarray) -> List[Verdict]:
        """One scan cycle of fleet readings -> verdicts (usually empty).

        ``readings`` is ``(n_streams, n_features)`` raw sensor values; the
        engine applies the PLC-side normalization itself.
        """
        t0 = time.perf_counter()
        readings = np.asarray(readings, np.float32)
        if readings.shape != (self.n_streams, self.n_features):
            raise ValueError(
                f"expected ({self.n_streams}, {self.n_features}) readings, "
                f"got {readings.shape}")
        self._pending.append((readings - self._mean) / self._std)
        self._count += 1
        self.stats.cycles += 1
        # stride > window: readings older than the last `window` can never
        # land in the ring, so drop them HERE — host memory, host->device
        # transfer and the compiled block shapes all stay capped at `window`
        # (mirrors GroupedStreamEngine's _pending pruning).
        if len(self._pending) > self.window:
            del self._pending[:len(self._pending) - self.window]

        verdicts: List[Verdict] = []
        if self._ready():
            # span = cycles elapsed since the last fired step; the pruned
            # pending list holds exactly the last min(span, window) readings.
            span = self._count - self._consumed
            block = np.stack(self._pending, axis=1)        # (S, L<=W, F)
            self._pending.clear()
            # The trimmed block starts (span - L) cycles after the untrimmed
            # one would have: advance the write position past the dropped
            # readings so ring geometry matches the untrimmed schedule.
            eff_pos = (self._pos + (span - block.shape[1])) % self.window
            if self._s_pad != self.n_streams:
                block = np.pad(
                    block, ((0, self._s_pad - self.n_streams), (0, 0), (0, 0)))
            if self.adapt is None:
                self._ring, logits = self._step(
                    self._ring, self._place(block), jnp.int32(eff_pos))
            else:
                self._ring, self._calib_ring, self._calib_counts, logits = \
                    self._step(self._ring, self._calib_ring,
                               self._calib_counts, self._place(block),
                               jnp.int32(eff_pos),
                               jnp.float32(self.live_threshold))
            self._pos = (self._pos + span) % self.window
            self._consumed = self._count
            self.stats.steps += 1
            # Gathers each device's shard of logits to the host; pad-stream
            # rows are dropped here and never surface as verdicts.
            logits = np.asarray(jax.block_until_ready(logits))
            logits = logits[:self.n_streams]
            self.last_logits = logits
            # Streaming recalibration: re-host the offline score-then-
            # quantile sequence on the rolling state (pad rows sliced off —
            # zero streams still score, so they must stay out of the pool).
            if self.adapt is not None \
                    and self.stats.steps % self.adapt.every == 0:
                thr = self._verdict_head.streaming_threshold(
                    np.asarray(self._calib_ring)[:self.n_streams],
                    np.asarray(self._calib_counts)[:self.n_streams],
                    min_count=self.adapt.min_count)
                if thr is not None:
                    self.live_threshold = thr
            latency = time.perf_counter() - t0
            miss = latency > self.deadline_s
            # Host epilogue via the head: classifier -> argmax/softmax,
            # score heads -> score vs the engine's LIVE threshold (the
            # offline cutoff unless adaptation has moved it).
            pred, prob, score, thr = self._verdict_head.host_verdicts(
                logits, threshold=self.live_threshold)
            cycle = self._count - 1
            for i in range(self.n_streams):
                verdicts.append(Verdict(
                    stream=i, cycle=cycle, pred=int(pred[i]),
                    prob=None if prob is None else float(prob[i]),
                    latency_s=latency, deadline_miss=miss,
                    score=None if score is None else float(score[i]),
                    threshold=thr))
            self.stats.windows += self.n_streams
            self.stats.deadline_misses += int(miss) * self.n_streams
            self.stats.latencies_s.append(latency)

        self.stats.wall_s += time.perf_counter() - t0
        return verdicts

    def run(self, streams: Sequence[Any], n_cycles: int,
            on_verdict: Optional[Callable[[Verdict], None]] = None,
            ) -> List[Verdict]:
        """Drive a fleet of ``PlantStream``-likes for ``n_cycles`` cycles.

        Each stream's ``step()`` must yield an object with ``tb0_meas`` /
        ``wd_meas`` attributes (simulation cost is *not* counted into the
        engine's serve stats — only ingest time is).
        """
        if len(streams) != self.n_streams:
            raise ValueError(
                f"fleet size {len(streams)} != engine streams {self.n_streams}")
        if self.n_features != 2:
            raise ValueError("run() reads the MSF (tb0_meas, wd_meas) layout; "
                             "use ingest() directly for other feature sets")
        out: List[Verdict] = []
        readings = np.zeros((self.n_streams, self.n_features), np.float32)
        for _ in range(n_cycles):
            for i, s in enumerate(streams):
                r = s.step()
                readings[i, 0] = r.tb0_meas
                readings[i, 1] = r.wd_meas
            for v in self.ingest(readings):
                out.append(v)
                if on_verdict is not None:
                    on_verdict(v)
        return out
