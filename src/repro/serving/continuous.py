"""Continuous-batching serving engine: per-slot state inside one jitted step.

The wave engine (serving/engine.py) shares one position counter across the
batch, so every slot stalls until the wave's longest request finishes.  This
engine keeps the same ICSML discipline — one statically preallocated KV arena
(dataMem), donated across steps, no dynamic allocation after construction —
but tracks **per-slot positions, temperatures, PRNG keys and done-masks**, so
a slot is re-admitted the moment its occupant retires (EOS or max tokens).

Admission writes a new request's prompt into its slot of the shared cache:

* the dense family prefills ``prompt[:-1]`` right-padded to a fixed bucket
  length, so admission compiles **once**.  Pad positions land beyond the
  slot's live region and each decode step overwrites its own position before
  attending to it, so pads are never observed.
* ssm/hybrid (recurrent state absorbs pads) and moe (pad tokens would compete
  for expert capacity) prefill at the exact prompt length instead.

The prefilled single-request cache is inserted along the slot axis, which is
discovered generically by diffing ``cache_specs`` at two batch sizes — no
per-family layout knowledge in the engine.

Decode is one fixed-shape jitted step over all slots: ``decode_multi`` (per
-slot positions) → per-slot temperature sampling → done-masked outputs, with
the cache and the per-slot state arrays donated.  Optionally the step runs
through a :class:`~repro.serving.cyclic.CyclicDecoder` so the paper's
multipart inference (§6.3) composes with continuous slots — each scan cycle
advances one layer segment for *all* in-flight requests.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI
from repro.serving.engine import Completion, Request, _truncate_eos, sample_batched

# families whose decode is a pure function of the attention cache: right-
# padded bucket prefill is safe (pads are overwritten before ever being
# attended to).  moe is excluded — pad tokens would compete for expert
# capacity with real tokens during prefill — and uses exact-length prefill.
# (During *decode*, capacity-grouped MoE routing couples co-scheduled rows;
# that holds for any batched engine here, wave or continuous.)
_BUCKET_FAMILIES = ("dense",)


@dataclasses.dataclass
class _Slot:
    req: Request
    out: List[int]
    admitted_s: float         # serve-clock time admission finished
    prefill_s: float          # wall time of the admission prefill


@dataclasses.dataclass
class ServeStats:
    steps: int                # jitted decode steps executed
    admitted: int             # requests admitted into slots
    wall_s: float             # total serve() wall time


def _batch_axes(api: ModelAPI, cache_len: int) -> List[int]:
    """Per-leaf batch axis of the cache, found by diffing two batch sizes."""
    s1 = jax.tree.leaves(api.cache_specs(1, cache_len))
    s2 = jax.tree.leaves(api.cache_specs(2, cache_len))
    axes = []
    for a, b in zip(s1, s2):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        assert len(diff) == 1, f"ambiguous batch axis for {a.shape} vs {b.shape}"
        axes.append(diff[0])
    return axes


class ContinuousEngine:
    """Slot-scheduled serving over a ModelAPI (continuous batching).

    ``prefill_bucket`` fixes the admission-prefill length for attention-cache
    families (defaults to cache_len // 2); prompts longer than the bucket fall
    back to exact-length prefill.  ``cyclic_segments > 0`` routes the decode
    step through a CyclicDecoder with that many layer segments per cycle.
    """

    def __init__(self, api: ModelAPI, params: Any, *, batch_slots: int,
                 cache_len: int, prefill_bucket: Optional[int] = None,
                 seed: int = 0, cyclic_segments: int = 0):
        if api.cfg.family in ("vlm", "audio"):
            raise NotImplementedError(
                "ContinuousEngine serves token-only families; vlm/audio "
                "admission needs per-request extras (image_emb/frames) — "
                "use the wave Engine with `extras` for those.")
        if cyclic_segments > 0 and api.cfg.kv_quant:
            raise NotImplementedError(
                "cyclic_segments does not compose with kv_quant: the "
                "CyclicDecoder segment cache carries only (k, v), not the "
                "int8 scales.")
        self.api = api
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self.seed = seed
        self._serves = 0          # advances the PRNG stream across serve()s
        self._bucket = (min(prefill_bucket or max(cache_len // 2, 1), cache_len)
                        if api.cfg.family in _BUCKET_FAMILIES else None)
        self._axes = _batch_axes(api, cache_len)
        self._treedef = jax.tree.structure(api.cache_specs(batch_slots, cache_len))
        self._zero_slot = api.init_cache(1, cache_len)
        self.last_stats: Optional[ServeStats] = None

        self._cyclic = None
        if cyclic_segments > 0:
            from repro.serving.cyclic import CyclicDecoder
            self._cyclic = CyclicDecoder(api.cfg, params,
                                         n_segments=cyclic_segments,
                                         batch=batch_slots, cache_len=cache_len)

        def _advance(logits, pos, temps, keys, active):
            """Sample per slot and advance per-slot state (done-masked)."""
            split = jax.vmap(jax.random.split)(keys)       # (B, 2, 2)
            new_keys, sub = split[:, 0], split[:, 1]
            nxt = sample_batched(logits[:, -1], temps, sub)
            nxt = jnp.where(active, nxt, 0)
            new_pos = jnp.where(active, pos + 1, pos)
            return nxt, new_pos, new_keys

        if self._cyclic is None:
            def _step(params, cache, tokens, pos, temps, keys, active):
                cache, logits = api.decode_multi(params, cache,
                                                 {"tokens": tokens}, pos)
                nxt, new_pos, new_keys = _advance(logits, pos, temps, keys,
                                                  active)
                return cache, nxt, new_pos, new_keys

            self._step = jax.jit(_step, donate_argnums=1)
        else:
            # multipart: segments are separate jits by design (one bounded
            # cycle each); only the sample/advance epilogue is fused here.
            self._advance = jax.jit(_advance)
            self._step = self._cyclic_step

        def _insert(cache, part, slot):
            flat_c = jax.tree.leaves(cache)
            flat_p = jax.tree.leaves(part)
            out = []
            for c, p, ax in zip(flat_c, flat_p, self._axes):
                idx = [jnp.int32(0)] * c.ndim
                idx[ax] = slot
                out.append(jax.lax.dynamic_update_slice(c, p.astype(c.dtype),
                                                        tuple(idx)))
            return jax.tree.unflatten(self._treedef, out)

        self._insert = jax.jit(_insert, donate_argnums=0)
        # jitted admission prefill; one compile with a bucket, one per
        # distinct prompt length on the exact-length fallback.
        self._prefill = jax.jit(
            lambda p, t: api.prefill(p, {"tokens": t}, cache_len))

    # -- admission ---------------------------------------------------------

    def _slot_prefill(self, prompt: np.ndarray) -> Any:
        """Single-request cache for ``prompt[:-1]`` (the last prompt token is
        fed through the first decode step, which yields the true first-token
        logits even when the prefill window is right-padded)."""
        body = prompt[:-1]
        if len(body) == 0:
            return self._zero_slot
        if self._bucket is not None and len(body) <= self._bucket:
            padded = np.zeros((self._bucket,), np.int32)
            padded[:len(body)] = body
            body = padded
        cache, _ = self._prefill(self.params, jnp.asarray(body[None]))
        return cache

    def _cyclic_step(self, params, cache, tokens, pos, temps, keys, active):
        cache, logits = self._cyclic.decode_step_multi(cache, tokens, pos)
        nxt, new_pos, new_keys = self._advance(logits, pos, temps, keys, active)
        return cache, nxt, new_pos, new_keys

    # -- serve -------------------------------------------------------------

    def serve(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve all requests, admitting into slots as they free up.

        Completions are returned in retirement order; ``finished_s`` is the
        per-request latency from serve() start (all requests are treated as
        submitted at t0)."""
        b = self.batch_slots
        pending = collections.deque(requests)
        slots: List[Optional[_Slot]] = [None] * b
        done: List[Completion] = []
        # fresh sampling stream per serve() call (uid alone would replay)
        self._serves += 1
        serve_key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                       self._serves)

        cache = self.api.init_cache(b, self.cache_len)
        tokens = np.zeros((b, 1), np.int32)
        pos = jnp.zeros((b,), jnp.int32)
        temps = np.zeros((b,), np.float32)
        keys = jnp.tile(jax.random.PRNGKey(self.seed)[None], (b, 1))
        active = np.zeros((b,), bool)
        steps = admitted = 0
        t0 = time.perf_counter()

        while pending or any(s is not None for s in slots):
            # admit into every free slot
            pos_h = None
            for i in range(b):
                if slots[i] is not None or not pending:
                    continue
                r = pending.popleft()
                plen = len(r.prompt)
                assert 1 <= plen < self.cache_len, \
                    f"prompt length {plen} must fit the cache ({self.cache_len})"
                assert r.max_new_tokens >= 1, \
                    "max_new_tokens must be >= 1 (every admitted slot decodes)"
                tp = time.perf_counter()
                cache = self._insert(cache, self._slot_prefill(r.prompt),
                                     jnp.int32(i))
                prefill_s = time.perf_counter() - tp
                if pos_h is None:
                    pos_h = np.array(pos)   # mutable host copy
                pos_h[i] = plen - 1
                tokens[i, 0] = r.prompt[-1]
                temps[i] = r.temperature
                # fold in the admission ordinal too: duplicate uids in one
                # serve() must not replay the same sample stream
                keys = keys.at[i].set(jax.random.fold_in(
                    jax.random.fold_in(serve_key, admitted),
                    r.uid & 0xFFFFFFFF))
                active[i] = True
                admitted += 1
                slots[i] = _Slot(req=r, out=[],
                                 admitted_s=time.perf_counter() - t0,
                                 prefill_s=prefill_s)
            if pos_h is not None:
                pos = jnp.asarray(pos_h)

            # one fixed-shape step for every slot
            cache, nxt, pos, keys = self._step(
                self.params, cache, jnp.asarray(tokens), pos,
                jnp.asarray(temps), keys, jnp.asarray(active))
            steps += 1
            nxt_h = np.asarray(nxt)
            pos_after = np.asarray(pos)

            # retire finished occupants, keep the rest decoding
            for i in range(b):
                s = slots[i]
                if s is None:
                    continue
                tok = int(nxt_h[i])
                s.out.append(tok)
                hit_eos = (s.req.eos_token is not None
                           and tok == s.req.eos_token)
                full = len(s.out) >= s.req.max_new_tokens
                # pos_after is the *next* write index; the last valid cache
                # position is cache_len - 1
                wall = int(pos_after[i]) >= self.cache_len
                if hit_eos or full or wall:
                    t_done = time.perf_counter() - t0
                    done.append(Completion(
                        uid=s.req.uid,
                        tokens=_truncate_eos(
                            np.asarray(s.out, np.int32), s.req.eos_token),
                        prefill_s=s.prefill_s,
                        decode_s=t_done - s.admitted_s,
                        finished_s=t_done,
                    ))
                    slots[i] = None
                    active[i] = False
                    temps[i] = 0.0
                    tokens[i, 0] = 0
                else:
                    tokens[i, 0] = tok

        self.last_stats = ServeStats(steps=steps, admitted=admitted,
                                     wall_s=time.perf_counter() - t0)
        return done
