"""Heterogeneous model-group fleet serving: many detectors, one engine.

:class:`~repro.serving.streams.StreamEngine` serves a fleet of plants that
all share ONE model and ONE verdict head.  Real OT estates are heterogeneous
— different plant types, per-site models, classifier vs autoencoder vs
margin vs forecast heads, per-device quantization — so the production
question is not "batch N clones of one detector" but "batch N *groups* of
different detectors".  :class:`GroupedStreamEngine` does that, as the
many-model façade over the shared :class:`~repro.serving.core.ServingCore`
pipeline (one group = one :class:`~repro.serving.core.ServingUnit`):

* The fleet's stream axis is partitioned into contiguous **model groups**
  (:class:`ModelGroup`): each group carries its own model, its own
  :class:`~repro.sim.heads.DetectorHead` (and therefore its own calibrated
  threshold), its own §6.1 quantization scales, and its own fused/per-layer
  step flavor.
* Per verdict cadence the engine runs **one jitted, donated step** over the
  tuple of per-group ring arenas.  When the fleet packs (all-Dense stacks,
  one MXU mode per layer position, packed-arena VMEM in budget, every head
  with an in-kernel epilogue) the step lowers to the **grouped megakernel**:
  ONE ``pallas_call`` whose grid spans ``(group, stream-blocks)``, all
  groups' weight/bias/scale slabs in a single padded arena, per-group
  quantization scales and head epilogues (final-layer softmax masked to
  each group's true class count) in-kernel — a G-group fleet is ONE
  dispatch per step, never G (and never G x layers).  ``megakernel=False``
  pins the classic per-group path (each all-Dense group its own fused
  ``pallas_call`` inside the step — G dispatches); ``megakernel=True``
  forces the megakernel — sharded steps included — and raises with the
  packing reason when the fleet cannot lower; over-budget / mixed-dtype
  fleets fall back to per-group automatically (``ops.grouped_fuse_reason``
  is the diagnosable form).  The default (``None``) auto-packs only
  unsharded fleets — see the ``serving/core.py`` docstring for the 1-ulp
  REAL rationale.
* Group ring geometry is per-group: heads may disagree about window extent
  (the forecast head rings one extra reading) and the engine keeps a ring
  arena, write position and readiness schedule per group.  Groups whose
  windows differ become ready at different cycles during fill-in; each
  distinct ready-combination compiles once and steady state (every group
  ready every ``stride``) reuses a single compiled step.
* **Sharding** composes per group: under the ``"data"`` axis of a fleet
  mesh each group's arena is padded to the mesh (its own pad-stream
  contract) and the whole multi-group step body runs under one
  ``shard_map`` — every device serves its contiguous shard of every group.
  A ``("data", "model")`` mesh additionally column-shards each group's
  wide layers over the model axis (see ``serving/core.py``); on a 1-D mesh
  the hot path stays collective-free exactly like the single-model step.
* ``async_depth=1`` double-buffers the whole multi-group step (the
  ``serving/core.py`` contract): verdicts bit-match sync mode one ready
  boundary later; drain with ``flush()``.

Verdict semantics per group come from its head; ``Verdict.group`` carries
the group name so fleet-level consumers can attribute mixed-head verdicts.
Groups cannot cross-contaminate by construction: thresholds, quantization
scales and models live in per-group closures traced into disjoint stream
slices of the step.

**Per-group drift adaptation.**  ``ModelGroup.adapt`` turns on streaming
threshold recalibration for that group alone (the
:class:`~repro.serving.streams.AdaptConfig` policy of ``StreamEngine``):
the group's rolling benign-score state advances inside the shared donated
step — row-local, so it shards exactly like the group's ring arena — and
the group's live threshold tracks the sliding ``conservative_quantile`` of
its own admitted scores.  Adaptive and fixed-threshold groups mix freely in
one engine; each group's verdicts report its own live threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from jax.sharding import Mesh

from repro.configs import msf_detector as spec
from repro.core.model import Model, ParamTree
from repro.serving.core import (  # noqa: F401  (historical import surface)
    AdaptConfig, LatencyReservoir, ServingCore, ServingUnit, StreamStats,
    Verdict)
from repro.sim.heads import DetectorHead


@dataclasses.dataclass
class ModelGroup:
    """One detector population inside a grouped fleet.

    ``head`` defaults to the §7 classifier; ``fused`` follows the
    :class:`~repro.serving.streams.StreamEngine` contract (None = auto,
    True = require the fused single-dispatch step, False = per-layer loop).
    """

    name: str
    model: Model
    params: ParamTree
    n_streams: int
    head: Optional[DetectorHead] = None
    fused: Optional[bool] = None
    adapt: Union[bool, "AdaptConfig", None] = None


class GroupedStreamEngine(ServingCore):
    """Batched sliding-window serving over a heterogeneous detector fleet.

    ``groups`` partitions the global stream axis contiguously: group ``i``
    owns streams ``[sum(n_j for j < i), ...)``.  Call :meth:`ingest` with
    one ``(n_streams, n_features)`` reading block per scan cycle, exactly
    like ``StreamEngine`` — the engine normalizes, accumulates pending
    readings host-side, and when any group's window cadence completes it
    runs one jitted donated step over every ready group's ring arena.

    ``backend`` / ``shard`` / ``mesh`` / ``async_depth`` follow the
    ``StreamEngine`` contract (``shard=None`` auto-shards on multi-device
    processes; the auto mesh is never wider than the *smallest* group so no
    group degenerates to pure-pad shards; an explicit wider mesh still
    serves correctly through each group's pad-stream contract).

    ``megakernel`` controls the single-dispatch multi-group lowering
    (module docstring): ``None`` auto-packs when the fleet can *and* the
    engine is unsharded, ``False`` pins the per-group path, ``True``
    forces it (sharded steps included; REAL verdicts then agree with the
    per-group sharded step at epsilon, not bitwise) and raises when the
    fleet cannot pack (mixed weight dtypes at a layer position, a packed
    arena over the VMEM budget, a head without an in-kernel epilogue,
    ``fused=False`` groups, a model-sharded mesh).
    """

    def __init__(self, groups: Sequence[ModelGroup], *,
                 n_features: int = spec.N_FEATURES,
                 stride: int = spec.STRIDE,
                 deadline_s: float = spec.DEADLINE_S,
                 norm_mean: Sequence[float] = spec.NORM_MEAN,
                 norm_std: Sequence[float] = spec.NORM_STD,
                 backend: str = "auto",
                 shard: Optional[bool] = None,
                 mesh: Optional[Mesh] = None,
                 async_depth: int = 0,
                 megakernel: Optional[bool] = None):
        if not groups:
            raise ValueError("need at least one ModelGroup")
        names = [g.name for g in groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names: {names}")
        super().__init__(
            [ServingUnit(name=g.name, model=g.model, params=g.params,
                         n_streams=g.n_streams, head=g.head, fused=g.fused,
                         adapt=g.adapt, what=f"group {g.name!r}: ")
             for g in groups],
            n_features=n_features, stride=stride, deadline_s=deadline_s,
            norm_mean=norm_mean, norm_std=norm_std, backend=backend,
            shard=shard, mesh=mesh, async_depth=async_depth,
            megakernel=megakernel)

    # -- introspection -----------------------------------------------------

    @property
    def _groups(self):
        """The per-group serving states (the core's unit list)."""
        return self._units

    @property
    def groups(self) -> List[Tuple[str, int, int]]:
        """(name, first_stream, n_streams) per group, in stream order."""
        return [(st.name, st.offset, st.n_streams) for st in self._units]

    def group_windows(self) -> Dict[str, int]:
        """Verdicts emitted per group."""
        return {st.name: st.windows for st in self._units}

    def live_thresholds(self) -> Dict[str, Optional[float]]:
        """Each group's live threshold (None for threshold-free heads;
        equals the offline-calibrated cutoff until adaptation moves it)."""
        return {st.name: st.live_threshold for st in self._units}
