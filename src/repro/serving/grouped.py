"""Heterogeneous model-group fleet serving: many detectors, one engine.

:class:`~repro.serving.streams.StreamEngine` serves a fleet of plants that
all share ONE model and ONE verdict head.  Real OT estates are heterogeneous
— different plant types, per-site models, classifier vs autoencoder vs
margin vs forecast heads, per-device quantization — so the production
question is not "batch N clones of one detector" but "batch N *groups* of
different detectors".  :class:`GroupedStreamEngine` does that:

* The fleet's stream axis is partitioned into contiguous **model groups**
  (:class:`ModelGroup`): each group carries its own model, its own
  :class:`~repro.sim.heads.DetectorHead` (and therefore its own calibrated
  threshold), its own §6.1 quantization scales, and its own fused/per-layer
  step flavor.
* Per verdict cadence the engine runs **one jitted, donated step** over the
  tuple of per-group ring arenas: inside it, every group's body — ring
  scatter write, modular window unroll, the head's ``prepare`` view, the
  (fused Pallas) forward and the head's device epilogue — executes on that
  group's streams only.  An all-Dense group is exactly ONE fused
  ``pallas_call`` inside the step, so a G-group fleet is G dispatches per
  step, never G x layers.
* Group ring geometry is per-group: heads may disagree about window extent
  (the forecast head rings one extra reading) and the engine keeps a ring
  arena, write position and readiness schedule per group.  Groups whose
  windows differ become ready at different cycles during fill-in; each
  distinct ready-combination compiles once and steady state (every group
  ready every ``stride``) reuses a single compiled step.
* **Sharding** composes per group: under a ``("data",)`` fleet mesh each
  group's arena is padded to the mesh (its own pad-stream contract) and the
  whole multi-group step body runs under one ``shard_map`` — every device
  serves its contiguous shard of every group, still with zero hot-path
  collectives, because group bodies are stream-local exactly like the
  single-model step.

Verdict semantics per group come from its head; ``Verdict.group`` carries
the group name so fleet-level consumers can attribute mixed-head verdicts.
Groups cannot cross-contaminate by construction: thresholds, quantization
scales and models live in per-group closures traced into disjoint stream
slices of the step.

**Per-group drift adaptation.**  ``ModelGroup.adapt`` turns on streaming
threshold recalibration for that group alone (the
:class:`~repro.serving.streams.AdaptConfig` policy of ``StreamEngine``):
the group's rolling benign-score state advances inside the shared donated
step — row-local, so it shards exactly like the group's ring arena — and
the group's live threshold tracks the sliding ``conservative_quantile`` of
its own admitted scores.  Adaptive and fixed-threshold groups mix freely in
one engine; each group's verdicts report its own live threshold.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import msf_detector as spec
from repro.core.model import Model, ParamTree
from repro.kernels import ops
from repro.launch.mesh import make_fleet_mesh
from repro.serving.streams import (AdaptConfig, LatencyReservoir, StreamStats,
                                   Verdict, _dense_batched, _layer_stack,
                                   _resolve_adapt)
from repro.sim.heads import ClassifierHead, DetectorHead, ScoreHead


@dataclasses.dataclass
class ModelGroup:
    """One detector population inside a grouped fleet.

    ``head`` defaults to the §7 classifier; ``fused`` follows the
    :class:`~repro.serving.streams.StreamEngine` contract (None = auto,
    True = require the fused single-dispatch step, False = per-layer loop).
    """

    name: str
    model: Model
    params: ParamTree
    n_streams: int
    head: Optional[DetectorHead] = None
    fused: Optional[bool] = None
    adapt: Union[bool, "AdaptConfig", None] = None


class _GroupState:
    """Per-group serving state: geometry, compiled-body closure, ring."""

    __slots__ = ("name", "head", "window", "offset", "n_streams", "s_pad",
                 "body", "pos", "consumed", "use_fused", "windows",
                 "adapt", "live_threshold", "fires")

    def __init__(self, name, head, window, offset, n_streams):
        self.name = name
        self.head = head
        self.window = window
        self.offset = offset          # first global stream index
        self.n_streams = n_streams
        self.pos = 0                  # next ring write index (host-tracked)
        self.consumed = 0             # scan count at the last fired step
        self.windows = 0              # verdicts emitted for this group
        self.fires = 0                # steps this group participated in


class GroupedStreamEngine:
    """Batched sliding-window serving over a heterogeneous detector fleet.

    ``groups`` partitions the global stream axis contiguously: group ``i``
    owns streams ``[sum(n_j for j < i), ...)``.  Call :meth:`ingest` with
    one ``(n_streams, n_features)`` reading block per scan cycle, exactly
    like ``StreamEngine`` — the engine normalizes, accumulates pending
    readings host-side, and when any group's window cadence completes it
    runs one jitted donated step over every ready group's ring arena.

    ``backend`` / ``shard`` / ``mesh`` follow the ``StreamEngine`` contract
    (``shard=None`` auto-shards on multi-device processes; the auto mesh is
    never wider than the *smallest* group so no group degenerates to
    pure-pad shards; an explicit wider mesh still serves correctly through
    each group's pad-stream contract).
    """

    def __init__(self, groups: Sequence[ModelGroup], *,
                 n_features: int = spec.N_FEATURES,
                 stride: int = spec.STRIDE,
                 deadline_s: float = spec.DEADLINE_S,
                 norm_mean: Sequence[float] = spec.NORM_MEAN,
                 norm_std: Sequence[float] = spec.NORM_STD,
                 backend: str = "auto",
                 shard: Optional[bool] = None,
                 mesh: Optional[Mesh] = None):
        if not groups:
            raise ValueError("need at least one ModelGroup")
        names = [g.name for g in groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names: {names}")
        if any(g.n_streams < 1 for g in groups):
            raise ValueError("every group needs n_streams >= 1")
        if not 1 <= stride:
            raise ValueError("stride must be >= 1")
        self.n_features = n_features
        self.stride = stride
        self.deadline_s = deadline_s
        self._mean = np.asarray(norm_mean, np.float32)
        self._std = np.asarray(norm_std, np.float32)
        if self._mean.shape != (n_features,) or \
                self._std.shape != (n_features,):
            raise ValueError("norm_mean/norm_std must have one entry per "
                             "feature")
        self._backend = backend
        self.n_streams = sum(g.n_streams for g in groups)

        # -- mesh (StreamEngine contract, min-group width cap) -------------
        if shard is False and mesh is not None:
            raise ValueError("shard=False contradicts an explicit mesh")
        if mesh is None and (shard or (shard is None
                                       and len(jax.devices()) > 1)):
            mesh = make_fleet_mesh(min(len(jax.devices()),
                                       *(g.n_streams for g in groups)))
        if mesh is not None:
            if "data" not in mesh.axis_names:
                raise ValueError(f"fleet mesh needs a 'data' axis, got "
                                 f"{mesh.axis_names}")
            extra = [a for a in mesh.axis_names
                     if a != "data" and mesh.shape[a] != 1]
            if extra:
                raise ValueError(
                    f"non-'data' mesh axes must have size 1, got {extra}")
        self.mesh = mesh
        self.n_shards = 1 if mesh is None else mesh.shape["data"]
        if mesh is None:
            self._arena_sharding = None
            self._calib_sharding = None
            self._counts_sharding = None
        else:
            self._arena_sharding = NamedSharding(mesh, P("data", None, None))
            self._calib_sharding = NamedSharding(mesh, P("data", None))
            self._counts_sharding = NamedSharding(mesh, P("data"))

        # -- per-group geometry, bodies, rings -----------------------------
        self._groups: List[_GroupState] = []
        self._bodies: List[Callable] = []
        self._rings: List[jax.Array] = []
        self._calibs: List[jax.Array] = []
        self._counts: List[jax.Array] = []
        offset = 0
        for g in groups:
            head = ClassifierHead() if g.head is None else g.head
            (input_size,) = g.model.input_shape
            window = head.ring_window(input_size, n_features)
            stack = _layer_stack(g.model, g.params)
            last = stack[-1][0]
            n_out = (last["qw"] if "qw" in last else last["w"]).shape[1]
            head.validate(input_size, n_out)
            fusable = ops.model_fusable(g.model, stack)
            if g.fused and not fusable:
                reason = ops.fuse_reason(stack) or \
                    "the model graph has non-Dense nodes"
                raise ValueError(
                    f"group {g.name!r}: fused=True but the model cannot "
                    f"fuse: {reason}")
            use_fused = fusable if g.fused is None else g.fused
            st = _GroupState(g.name, head, window, offset, g.n_streams)
            # Pad-stream contract per group: every device owns an equal
            # contiguous shard of each group's arena; pad rows are zero
            # streams sliced off before verdicts.
            st.s_pad = -(-g.n_streams // self.n_shards) * self.n_shards
            st.use_fused = use_fused
            st.adapt = _resolve_adapt(g.adapt, head,
                                      what=f"group {g.name!r}: ")
            st.live_threshold = (head.threshold
                                 if isinstance(head, ScoreHead) else None)
            st.body = self._make_body(stack, head, use_fused, window,
                                      st.adapt)
            self._groups.append(st)
            self._bodies.append(st.body)
            self._rings.append(self._place(
                jnp.zeros((st.s_pad, window, n_features), jnp.float32)))
            calib, counts = self._calib_state(st)
            self._calibs.append(calib)
            self._counts.append(counts)
            offset += g.n_streams
        self.max_window = max(st.window for st in self._groups)

        # Compiled steps keyed by the ready-combination signature
        # ((group_idx, block_len), ...): steady state — every group ready
        # with a stride-long block — is one key reused forever; window
        # fill-in transitions each compile once.
        self._steps: Dict[Tuple, Callable] = {}

        self._count = 0
        self._pending: List[np.ndarray] = []
        self.last_outputs: Dict[str, np.ndarray] = {}
        self.stats = StreamStats(steps=0, cycles=0, windows=0,
                                 deadline_misses=0, wall_s=0.0)

    # -- construction helpers ----------------------------------------------

    def _place(self, arr, sharding=None) -> jax.Array:
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(
            arr, self._arena_sharding if sharding is None else sharding)

    def _calib_state(self, st: _GroupState) -> Tuple[jax.Array, jax.Array]:
        """A group's (placed) rolling calibration state.  Non-adaptive
        groups carry a minimal dummy so every step has one uniform
        ``(ring, calib, counts, block, pos, thr)`` signature per group —
        the dummy rides through the donated step untouched."""
        if st.adapt is not None:
            calib, counts = st.head.calib_state(st.s_pad, st.adapt.capacity)
        else:
            calib = jnp.zeros((st.s_pad, 1), jnp.float32)
            counts = jnp.zeros((st.s_pad,), jnp.int32)
        return (self._place(calib, self._calib_sharding),
                self._place(counts, self._counts_sharding))

    @staticmethod
    def _thr(st: _GroupState) -> jnp.float32:
        """The group's live threshold as the step's scalar operand (0.0 for
        heads with no threshold — the body never reads it then)."""
        return jnp.float32(0.0 if st.live_threshold is None
                           else st.live_threshold)

    def _make_body(self, stack, head, use_fused, window, adapt_cfg):
        """One group's device step body — identical math to StreamEngine's
        step (ring scatter, oldest-first unroll, forward, head hooks, and,
        when the group adapts, the rolling calibration-state write), so
        grouped serving bit-matches an independent per-model engine."""
        backend = self._backend
        w = window

        def _forward(x):
            if use_fused:
                return ops.fused_forward(x, stack, backend=backend)
            for p, act in stack:
                x = _dense_batched(x, p, act, backend)
            return x

        def body(ring, calib, counts, block, pos, thr):
            length = block.shape[1]
            offset = max(length - w, 0)
            idx = (pos + offset + jnp.arange(length - offset)) % w
            ring = ring.at[:, idx, :].set(block[:, offset:])
            end = (pos + length) % w
            widx = (end + jnp.arange(w)) % w
            win = jnp.take(ring, widx, axis=1).reshape(ring.shape[0], -1)
            out = head.epilogue(win, _forward(head.prepare(win)))
            if adapt_cfg is not None:
                calib, counts = head.calib_update(
                    calib, counts, out, thr, adapt_cfg.headroom)
            return ring, calib, counts, out

        return body

    def _get_step(self, key: Tuple) -> Callable:
        """The jitted donated step for one ready-combination."""
        step = self._steps.get(key)
        if step is not None:
            return step
        bodies = [self._bodies[gi] for gi, _ in key]

        def _step(rings, calibs, countss, blocks, poss, thrs):
            outs = [body(ring, calib, counts, block, pos, thr)
                    for body, ring, calib, counts, block, pos, thr
                    in zip(bodies, rings, calibs, countss, blocks, poss,
                           thrs)]
            return (tuple(o[0] for o in outs), tuple(o[1] for o in outs),
                    tuple(o[2] for o in outs), tuple(o[3] for o in outs))

        if self.mesh is not None:
            # One shard_map over the whole multi-group body: every group
            # body is stream-local (the calibration-state write included),
            # so each device serves its contiguous shard of every ready
            # group with zero collectives — G fused dispatches per device
            # per step.  check_rep=False: pallas_call carries no
            # replication rule.
            n = len(key)
            _step = shard_map(
                _step, mesh=self.mesh,
                in_specs=((P("data", None, None),) * n,
                          (P("data", None),) * n, (P("data"),) * n,
                          (P("data", None, None),) * n,
                          (P(),) * n, (P(),) * n),
                out_specs=((P("data", None, None),) * n,
                           (P("data", None),) * n, (P("data"),) * n,
                           (P("data", None),) * n),
                check_rep=False)
        step = self._steps[key] = jax.jit(_step, donate_argnums=(0, 1, 2))
        return step

    # -- readiness schedule ------------------------------------------------

    def _ready(self, st: _GroupState, count: int) -> bool:
        return (count >= st.window
                and (count - st.window) % self.stride == 0)

    def _schedule_keys(self) -> List[Tuple]:
        """Every distinct ready-combination key the serve loop will hit, by
        simulating the (deterministic) readiness schedule through window
        fill-in plus one full steady-state stride period."""
        keys: List[Tuple] = []
        consumed = {i: 0 for i in range(len(self._groups))}
        for count in range(1, self.max_window + self.stride + 1):
            key = []
            for gi, st in enumerate(self._groups):
                if self._ready(st, count):
                    span = count - consumed[gi]
                    key.append((gi, min(span, st.window)))
                    consumed[gi] = count
            if key and tuple(key) not in keys:
                keys.append(tuple(key))
        return keys

    def warmup(self) -> None:
        """Compile every step shape the readiness schedule can produce —
        each group's window-fill firing and the steady-state all-ready step
        — outside the serve clock, with the serve-time arena sharding."""
        for key in self._schedule_keys():
            rings = tuple(self._place(jnp.zeros(
                (self._groups[gi].s_pad, self._groups[gi].window,
                 self.n_features), jnp.float32)) for gi, _ in key)
            states = [self._calib_state(self._groups[gi]) for gi, _ in key]
            blocks = tuple(self._place(jnp.zeros(
                (self._groups[gi].s_pad, length, self.n_features),
                jnp.float32)) for gi, length in key)
            poss = tuple(jnp.int32(0) for _ in key)
            thrs = tuple(self._thr(self._groups[gi]) for gi, _ in key)
            *_, outs = self._get_step(key)(
                rings, tuple(c for c, _ in states),
                tuple(n for _, n in states), blocks, poss, thrs)
            jax.block_until_ready(outs)

    # -- ingestion ---------------------------------------------------------

    def ingest(self, readings: np.ndarray) -> List[Verdict]:
        """One scan cycle of fleet readings -> verdicts (usually empty).

        ``readings`` is ``(n_streams, n_features)`` raw sensor values over
        the whole fleet, group slices concatenated in group order.
        """
        t0 = time.perf_counter()
        readings = np.asarray(readings, np.float32)
        if readings.shape != (self.n_streams, self.n_features):
            raise ValueError(
                f"expected ({self.n_streams}, {self.n_features}) readings, "
                f"got {readings.shape}")
        self._pending.append((readings - self._mean) / self._std)
        # The pending tail only ever feeds blocks of at most max_window
        # readings (longer spans are trimmed to the window) — prune so a
        # stalled cadence can't grow host memory.
        if len(self._pending) > self.max_window:
            del self._pending[:len(self._pending) - self.max_window]
        self._count += 1
        self.stats.cycles += 1

        ready = [(gi, st) for gi, st in enumerate(self._groups)
                 if self._ready(st, self._count)]
        if not ready:
            self.stats.wall_s += time.perf_counter() - t0
            return []

        key, rings, calibs, countss, blocks, poss, thrs = \
            [], [], [], [], [], [], []
        for gi, st in ready:
            span = self._count - st.consumed
            length = min(span, st.window)
            block = np.stack(self._pending[-length:], axis=1)  # (S, L, F)
            block = block[st.offset:st.offset + st.n_streams]
            if st.s_pad != st.n_streams:
                block = np.pad(
                    block, ((0, st.s_pad - st.n_streams), (0, 0), (0, 0)))
            # The ring write always ends at (pos + span - 1) mod window;
            # host-side trimming of long spans shifts the start to match.
            eff_pos = (st.pos + (span - length)) % st.window
            key.append((gi, length))
            rings.append(self._rings[gi])
            calibs.append(self._calibs[gi])
            countss.append(self._counts[gi])
            blocks.append(self._place(block))
            poss.append(jnp.int32(eff_pos))
            thrs.append(self._thr(st))
            st.pos = (st.pos + span) % st.window
            st.consumed = self._count
            st.fires += 1

        new_rings, new_calibs, new_counts, outs = self._get_step(tuple(key))(
            tuple(rings), tuple(calibs), tuple(countss), tuple(blocks),
            tuple(poss), tuple(thrs))
        outs = jax.block_until_ready(outs)
        for (gi, _), ring, calib, counts in zip(key, new_rings, new_calibs,
                                                new_counts):
            self._rings[gi] = ring
            self._calibs[gi] = calib
            self._counts[gi] = counts

        latency = time.perf_counter() - t0
        miss = latency > self.deadline_s
        cycle = self._count - 1
        verdicts: List[Verdict] = []
        for (gi, _), out in zip(key, outs):
            st = self._groups[gi]
            # Pad-stream rows are dropped here and never surface.
            out = np.asarray(out)[:st.n_streams]
            self.last_outputs[st.name] = out
            # Per-group streaming recalibration (StreamEngine contract: pad
            # rows sliced off before the pooled quantile).
            if st.adapt is not None and st.fires % st.adapt.every == 0:
                thr = st.head.streaming_threshold(
                    np.asarray(self._calibs[gi])[:st.n_streams],
                    np.asarray(self._counts[gi])[:st.n_streams],
                    min_count=st.adapt.min_count)
                if thr is not None:
                    st.live_threshold = thr
            pred, prob, score, thr = st.head.host_verdicts(
                out, threshold=st.live_threshold)
            for i in range(st.n_streams):
                verdicts.append(Verdict(
                    stream=st.offset + i, cycle=cycle, pred=int(pred[i]),
                    prob=None if prob is None else float(prob[i]),
                    latency_s=latency, deadline_miss=miss,
                    score=None if score is None else float(score[i]),
                    threshold=thr, group=st.name))
            st.windows += st.n_streams
            self.stats.windows += st.n_streams
            self.stats.deadline_misses += int(miss) * st.n_streams
        self.stats.steps += 1
        self.stats.latencies_s.append(latency)
        self.stats.wall_s += time.perf_counter() - t0
        return verdicts

    def run(self, streams: Sequence[Any], n_cycles: int,
            on_verdict: Optional[Callable[[Verdict], None]] = None,
            ) -> List[Verdict]:
        """Drive a fleet of ``PlantStream``-likes for ``n_cycles`` cycles
        (the :meth:`StreamEngine.run` contract: MSF reading layout,
        simulation cost excluded from serve stats)."""
        if len(streams) != self.n_streams:
            raise ValueError(
                f"fleet size {len(streams)} != engine streams "
                f"{self.n_streams}")
        if self.n_features != 2:
            raise ValueError("run() reads the MSF (tb0_meas, wd_meas) "
                             "layout; use ingest() directly for other "
                             "feature sets")
        out: List[Verdict] = []
        readings = np.zeros((self.n_streams, self.n_features), np.float32)
        for _ in range(n_cycles):
            for i, s in enumerate(streams):
                r = s.step()
                readings[i, 0] = r.tb0_meas
                readings[i, 1] = r.wd_meas
            for v in self.ingest(readings):
                out.append(v)
                if on_verdict is not None:
                    on_verdict(v)
        return out

    # -- introspection -----------------------------------------------------

    @property
    def groups(self) -> List[Tuple[str, int, int]]:
        """(name, first_stream, n_streams) per group, in stream order."""
        return [(st.name, st.offset, st.n_streams) for st in self._groups]

    def group_windows(self) -> Dict[str, int]:
        """Verdicts emitted per group."""
        return {st.name: st.windows for st in self._groups}

    def live_thresholds(self) -> Dict[str, Optional[float]]:
        """Each group's live threshold (None for threshold-free heads;
        equals the offline-calibrated cutoff until adaptation moves it)."""
        return {st.name: st.live_threshold for st in self._groups}
