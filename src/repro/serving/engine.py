"""Batched serving engine: static-batch prefill + synchronized decode.

The ICSML discipline applied to serving (DESIGN.md §2):

* the KV cache is **statically preallocated** at (batch_slots, cache_len) and
  donated across decode steps (dataMem: one arena, updated in place);
* decode is a fixed-shape jitted step — no dynamic allocation ever happens
  after engine construction;
* requests are admitted in waves (static batching): all slots share the
  position counter, exactly like the PLC scan cycle shares one clock.

`CyclicEngine` (serving/cyclic.py) additionally splits each decode step into
per-cycle layer segments — the paper's multipart inference (§6.3) for big
models.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    temperature: float = 0.0      # 0 => greedy


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        n = len(self.tokens)
        return n / self.decode_s if self.decode_s > 0 else float("inf")


def sample(logits: jax.Array, temperature: float, key: jax.Array) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


class Engine:
    """Wave-batched serving over a ModelAPI."""

    def __init__(self, api: ModelAPI, params: Any, *, batch_slots: int,
                 cache_len: int, extras: Optional[Dict[str, jax.Array]] = None):
        self.api = api
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self.extras = extras or {}

        def _decode(params, cache, tokens, pos, key, temperature):
            batch = {"tokens": tokens, **self.extras}
            cache, logits = api.decode(params, cache, batch, pos)
            nxt = sample(logits[:, -1], temperature, key)
            return cache, nxt

        # cache donated: the static arena is updated in place step to step
        self._decode = jax.jit(_decode, donate_argnums=1,
                               static_argnames=("temperature",))

    def run_wave(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve one wave of ≤ batch_slots requests (right-padded prompts)."""
        assert len(requests) <= self.batch_slots
        reqs = list(requests)
        b = self.batch_slots
        plen = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, :len(r.prompt)] = r.prompt  # noqa: E203

        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompts), **self.extras}
        cache, logits = self.api.prefill(self.params, batch, self.cache_len)
        first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        t_prefill = time.perf_counter() - t0

        max_new = max(r.max_new_tokens for r in reqs)
        out = np.zeros((b, max_new), np.int32)
        out[:, 0] = first
        cur = jnp.asarray(first[:, None])
        key = jax.random.PRNGKey(0)
        temperature = reqs[0].temperature

        t1 = time.perf_counter()
        for step in range(1, max_new):
            pos = jnp.int32(plen + step - 1)
            key, sub = jax.random.split(key)
            cache, nxt = self._decode(self.params, cache, cur, pos, sub, temperature)
            out[:, step] = np.asarray(nxt)
            cur = nxt[:, None]
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t1

        return [
            Completion(uid=r.uid, tokens=out[i, :r.max_new_tokens],
                       prefill_s=t_prefill, decode_s=t_decode)
            for i, r in enumerate(reqs)
        ]

    def serve(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve an arbitrary number of requests in waves."""
        done: List[Completion] = []
        for i in range(0, len(requests), self.batch_slots):
            done.extend(self.run_wave(requests[i:i + self.batch_slots]))
        return done
