"""Batched serving engine: static-batch prefill + synchronized decode.

The ICSML discipline applied to serving (DESIGN.md §2):

* the KV cache is **statically preallocated** at (batch_slots, cache_len) and
  donated across decode steps (dataMem: one arena, updated in place);
* decode is a fixed-shape jitted step — no dynamic allocation ever happens
  after engine construction;
* requests are admitted in waves (static batching): all slots share the
  position counter, exactly like the PLC scan cycle shares one clock.

`CyclicDecoder` (serving/cyclic.py) additionally splits each decode step into
per-cycle layer segments — the paper's multipart inference (§6.3) for big
models.  `ContinuousEngine` (serving/continuous.py) replaces the shared wave
clock with per-slot positions so slots retire and re-admit independently.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    temperature: float = 0.0      # 0 => greedy
    eos_token: Optional[int] = None   # retire early when sampled


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float
    finished_s: float = 0.0       # wall time from serve() start to retirement

    @property
    def tokens_per_s(self) -> float:
        n = len(self.tokens)
        return n / self.decode_s if self.decode_s > 0 else float("inf")


def sample_batched(logits: jax.Array, temperatures: jax.Array,
                   keys: jax.Array) -> jax.Array:
    """Per-row sampling: logits (B, V), temperatures (B,), keys (B, 2).

    Rows with temperature <= 0 take the argmax; others sample from their own
    temperature-scaled distribution with their own PRNG key."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperatures, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperatures > 0.0, sampled, greedy)


def _truncate_eos(tokens: np.ndarray, eos: Optional[int]) -> np.ndarray:
    if eos is None:
        return tokens
    hits = np.flatnonzero(tokens == eos)
    return tokens[: hits[0] + 1] if hits.size else tokens


class Engine:
    """Wave-batched serving over a ModelAPI."""

    def __init__(self, api: ModelAPI, params: Any, *, batch_slots: int,
                 cache_len: int, extras: Optional[Dict[str, jax.Array]] = None,
                 seed: int = 0):
        self.api = api
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self.extras = extras or {}
        self._key = jax.random.PRNGKey(seed)

        def _decode(params, cache, tokens, pos, keys, temperatures):
            batch = {"tokens": tokens, **self.extras}
            cache, logits = api.decode(params, cache, batch, pos)
            nxt = sample_batched(logits[:, -1], temperatures, keys)
            return cache, nxt

        # cache donated: the static arena is updated in place step to step
        self._decode = jax.jit(_decode, donate_argnums=1)

    def run_wave(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve one wave of ≤ batch_slots requests (right-padded prompts)."""
        assert len(requests) <= self.batch_slots
        reqs = list(requests)
        b = self.batch_slots
        plen = max(len(r.prompt) for r in reqs)
        max_new = max(r.max_new_tokens for r in reqs)
        # decode writes at positions plen .. plen+max_new-2; past cache_len
        # dynamic_update_slice would clamp and silently corrupt the arena
        assert plen + max_new - 1 <= self.cache_len, (
            f"prompt ({plen}) + max_new_tokens ({max_new}) overflow the "
            f"cache ({self.cache_len})")
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, :len(r.prompt)] = r.prompt  # noqa: E203

        # per-request temperatures: slot i samples at reqs[i].temperature
        # (empty slots run greedy); each wave advances the engine's PRNG.
        temps = np.zeros((b,), np.float32)
        for i, r in enumerate(reqs):
            temps[i] = r.temperature
        temps = jnp.asarray(temps)

        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompts), **self.extras}
        cache, logits = self.api.prefill(self.params, batch, self.cache_len)
        self._key, sub = jax.random.split(self._key)
        first = np.asarray(sample_batched(
            logits[:, -1], temps, jax.random.split(sub, b)))
        t_prefill = time.perf_counter() - t0

        out = np.zeros((b, max_new), np.int32)
        out[:, 0] = first
        cur = jnp.asarray(first[:, None])

        t1 = time.perf_counter()
        for step in range(1, max_new):
            pos = jnp.int32(plen + step - 1)
            self._key, sub = jax.random.split(self._key)
            keys = jax.random.split(sub, b)
            cache, nxt = self._decode(self.params, cache, cur, pos, keys, temps)
            out[:, step] = np.asarray(nxt)
            cur = nxt[:, None]
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t1

        return [
            Completion(uid=r.uid,
                       tokens=_truncate_eos(out[i, :r.max_new_tokens],
                                            r.eos_token),
                       prefill_s=t_prefill, decode_s=t_decode)
            for i, r in enumerate(reqs)
        ]

    def serve(self, requests: Sequence[Request]) -> List[Completion]:
        """Serve an arbitrary number of requests in waves.

        ``finished_s`` on each completion is the wall time from serve() start
        to the end of the request's wave — every request in a wave waits for
        the wave's longest request."""
        done: List[Completion] = []
        t0 = time.perf_counter()
        for i in range(0, len(requests), self.batch_slots):
            wave = self.run_wave(requests[i:i + self.batch_slots])
            t_wave = time.perf_counter() - t0
            for c in wave:
                c.finished_s = t_wave
            done.extend(wave)
        return done
