"""Scan-cycle serving: the paper's multipart inference (§6.3) applied to
big-model decode.

On the PLC, one inference is sliced into segments so each scan cycle pays a
bounded, predictable cost and the control task always meets its deadline.
For a large decoder the natural segment is a **layer block**: each cycle
embeds/advances one contiguous block of layers for the in-flight token while
the primary task (whatever shares the host/TPU) keeps its budget.  The carry
between cycles is the hidden state + the updated cache slices — the exact
analogue of the ICSML arena crossing scan cycles.

Supported families: dense/moe/vlm (transformer block stacks) and ssm.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models import moe as moelib
from repro.models import transformer as tf


def _slice_tree(tree: Any, start: int, stop: int) -> Any:
    return jax.tree.map(lambda a: a[start:stop], tree)


def _update_tree(tree: Any, part: Any, start: int) -> Any:
    return jax.tree.map(
        lambda full, p: jax.lax.dynamic_update_slice_in_dim(full, p, start, axis=0)
        if hasattr(full, "shape") else full,
        tree, part)


@dataclasses.dataclass
class CycleStats:
    cycle_times_s: List[float]
    tokens: List[int]
    cycles_per_token: int


class CyclicDecoder:
    """Multipart decode: one layer-segment per scan cycle."""

    def __init__(self, cfg: ArchConfig, params: Any, *, n_segments: int,
                 batch: int, cache_len: int):
        if cfg.family not in ("dense", "moe", "vlm", "ssm"):
            raise NotImplementedError(cfg.family)
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        n_layers = cfg.n_layers
        n_segments = max(1, min(n_segments, n_layers))
        bounds = np.linspace(0, n_layers, n_segments + 1).astype(int)
        self.bounds = [(int(a), int(b)) for a, b in zip(bounds[:-1], bounds[1:])]
        self.n_segments = len(self.bounds)

        ffn_apply = (moelib.make_ffn_apply(cfg) if cfg.family == "moe" else None)

        if cfg.family == "ssm":
            def seg_fn(blocks, conv_c, ssm_c, h, pos):
                def body(hh, inputs):
                    blk, cc, sc = inputs
                    out, nc = mb.mamba_decode(blk["mixer"], cfg,
                                              cm.rmsnorm(blk["ln"], hh),
                                              {"conv": cc, "ssm": sc})
                    return hh + out, (nc["conv"], nc["ssm"])
                h, (conv, ssm) = jax.lax.scan(body, h, (blocks, conv_c, ssm_c))
                return h, (conv, ssm)

            # SSM state is position-free: one segment fn serves both the
            # shared-position and the per-slot-position (continuous) paths.
            seg_fn_multi = seg_fn
        else:
            fa = ffn_apply or (lambda p, hh: cm.mlp_forward(
                p, tf._mlp_cfg(cfg), hh))

            def seg_fn(blocks, k_c, v_c, h, pos):
                def body(hh, inputs):
                    blk, kc, vc = inputs
                    hh, kv = tf.block_decode(blk, cfg, hh, pos, (kc, vc), fa)
                    return hh, kv
                h, (k, v) = jax.lax.scan(body, h, (blocks, k_c, v_c))
                return h, (k, v)

            def seg_fn_multi(blocks, k_c, v_c, h, pos):
                def body(hh, inputs):
                    blk, kc, vc = inputs
                    hh, kv = tf.block_decode_multi(blk, cfg, hh, pos,
                                                   (kc, vc), fa)
                    return hh, kv
                h, (k, v) = jax.lax.scan(body, h, (blocks, k_c, v_c))
                return h, (k, v)

        self._seg = jax.jit(seg_fn)
        self._seg_multi = jax.jit(seg_fn_multi)

        def head(params, h):
            h = cm.rmsnorm(params["final_norm"], h)
            return jnp.argmax(cm.unembed(params["embed"], h)[:, -1], -1).astype(jnp.int32)

        def logits_head(params, h):
            h = cm.rmsnorm(params["final_norm"], h)
            return cm.unembed(params["embed"], h)

        self._embed = jax.jit(lambda params, tok: cm.embed(params["embed"], tok)
                              .astype(cfg.dtype))
        self._head = jax.jit(head)
        self._logits_head = jax.jit(logits_head)

    def _cache_parts(self, cache):
        if self.cfg.family == "ssm":
            return (cache["conv"], cache["ssm"])
        return (cache["k"], cache["v"])

    def _rebuild_cache(self, cache, parts):
        if self.cfg.family == "ssm":
            return {"conv": parts[0], "ssm": parts[1]}
        return dict(cache, k=parts[0], v=parts[1])

    def decode_step_multi(self, cache: Any, tokens: jax.Array, pos: jax.Array
                          ) -> Tuple[Any, jax.Array]:
        """One multipart decode step with per-slot positions.

        tokens (B, 1), pos (B,) int32 — the continuous engine's step executed
        as ``n_segments`` bounded scan cycles, each advancing one layer block
        for **all** in-flight slots.  Returns (cache, logits (B, 1, V)) —
        the same contract as ``ModelAPI.decode_multi``."""
        h = self._embed(self.params, tokens)
        parts = self._cache_parts(cache)
        pos = jnp.asarray(pos, jnp.int32)
        for (a, b) in self.bounds:
            seg_blocks = _slice_tree(self.params["blocks"], a, b)
            seg_parts = tuple(_slice_tree(p, a, b) for p in parts)
            h, new_parts = self._seg_multi(seg_blocks, *seg_parts, h, pos)
            parts = tuple(
                _update_tree(full, new, a)
                for full, new in zip(parts, new_parts)
            )
        return self._rebuild_cache(cache, parts), self._logits_head(self.params, h)

    def decode_tokens(
        self, cache: Any, first_token: jax.Array, start_pos: int, n_tokens: int,
        control_task: Optional[Callable[[], None]] = None,
    ) -> Tuple[List[int], Any, CycleStats]:
        """Generate n_tokens, advancing one segment per scan cycle.

        `control_task` is invoked once per cycle before the segment — the
        PLC's primary workload in the §7.2 non-intrusiveness sense.
        """
        tokens: List[int] = []
        cycle_times: List[float] = []
        cur = first_token.reshape(self.batch, 1)
        pos = start_pos
        parts = self._cache_parts(cache)

        for _ in range(n_tokens):
            h = self._embed(self.params, cur)
            for (a, b) in self.bounds:
                t0 = time.perf_counter()
                if control_task is not None:
                    control_task()
                seg_blocks = _slice_tree(self.params["blocks"], a, b)
                seg_parts = tuple(_slice_tree(p, a, b) for p in parts)
                h, new_parts = self._seg(seg_blocks, *seg_parts, h,
                                         jnp.int32(pos))
                parts = tuple(
                    _update_tree(full, new, a)
                    for full, new in zip(parts, new_parts)
                )
                cycle_times.append(time.perf_counter() - t0)
            nxt = self._head(self.params, h)
            tokens.append(int(nxt[0]))
            cur = nxt[:, None]
            pos += 1

        return tokens, self._rebuild_cache(cache, parts), CycleStats(
            cycle_times_s=cycle_times, tokens=tokens,
            cycles_per_token=self.n_segments,
        )
