"""Scan-cycle runtime and multipart inference (§3.3, §6.3).

PLCs run a hard-periodic *scan cycle*: read inputs → control logic → write
outputs.  Inference must fit in the slack left after the control task, so
ICSML supports **multipart inference**: the linear layer schedule is split
into segments and one segment executes per cycle; the model output appears
after ``n_segments`` cycles (the paper runs a MobileNet at a 90 ms cycle with
1.17 s output latency this way).

JAX re-host:

* segments are jit-compiled functions ``(arena, x) -> arena`` with the arena
  donated (the buffer is updated in place, like dataMem on the PLC);
* segment boundaries are chosen ahead of time to balance per-segment FLOPs,
  so each cycle's inference cost is predictable — the property the scan cycle
  needs;
* :class:`ScanCycleRuntime` simulates the PLC loop: control task + at most one
  inference segment per cycle, with per-cycle wall-time accounting used by the
  non-intrusiveness study (§7.2).

The same segment machinery generalizes to big-model serving: a segment is a
layer block, and the scan-cycle server (`repro.serving.cyclic`) decodes large
models under a per-cycle budget.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import memory as memlib
from repro.core.model import Model, ParamTree


def segment_boundaries(model: Model, n_segments: int) -> List[Tuple[int, int]]:
    """Split the linear schedule into ``n_segments`` contiguous FLOP-balanced
    segments.  Returned as [start, stop) node-index ranges."""
    n_nodes = len(model.graph.nodes)
    n_segments = max(1, min(n_segments, n_nodes))
    flops = list(model.node_flops().values())
    total = sum(flops) or 1
    target = total / n_segments
    bounds: List[Tuple[int, int]] = []
    start, acc = 0, 0.0
    for i, f in enumerate(flops):
        acc += f
        remaining_nodes = n_nodes - (i + 1)
        remaining_segs = n_segments - len(bounds) - 1
        if (acc >= target and remaining_segs > 0) or remaining_nodes == remaining_segs:
            if remaining_segs > 0:
                bounds.append((start, i + 1))
                start, acc = i + 1, 0.0
    bounds.append((start, n_nodes))
    assert len(bounds) == n_segments, (bounds, n_segments)
    return bounds


@dataclasses.dataclass
class MultipartState:
    """In-flight inference: the arena plus progress bookkeeping."""

    arena: jax.Array
    x: jax.Array
    next_segment: int

    def finished(self, n_segments: int) -> bool:
        return self.next_segment >= n_segments


class MultipartInference:
    """Pre-compiled multipart inference executor (§6.3)."""

    def __init__(self, model: Model, params: ParamTree, n_segments: int):
        self.model = model
        self.params = params
        self.plan = model.memory_plan()
        self.bounds = segment_boundaries(model, n_segments)
        self.n_segments = len(self.bounds)

        def make_segment(start: int, stop: int):
            def seg(arena: jax.Array, x: jax.Array) -> jax.Array:
                return model.apply_segment(params, arena, x, start, stop, self.plan)
            return jax.jit(seg, donate_argnums=0)

        self._segments = [make_segment(a, b) for a, b in self.bounds]

    # -- lifecycle -----------------------------------------------------------
    def start(self, x: jax.Array) -> MultipartState:
        arena = jnp.zeros((self.plan.arena_size,), jnp.float32)
        return MultipartState(arena=arena, x=jnp.asarray(x), next_segment=0)

    def step(self, state: MultipartState) -> MultipartState:
        """Run exactly one segment (one scan cycle's worth of inference)."""
        if state.finished(self.n_segments):
            raise RuntimeError("inference already complete; call start() again")
        seg = self._segments[state.next_segment]
        arena = seg(state.arena, state.x)
        return MultipartState(arena=arena, x=state.x, next_segment=state.next_segment + 1)

    def output(self, state: MultipartState) -> jax.Array:
        if not state.finished(self.n_segments):
            raise RuntimeError("inference not complete")
        return self.model.read_output(state.arena, self.plan)

    def run_all(self, x: jax.Array) -> jax.Array:
        state = self.start(x)
        while not state.finished(self.n_segments):
            state = self.step(state)
        return self.output(state)

    def segment_flops(self) -> List[int]:
        flops = list(self.model.node_flops().values())
        return [sum(flops[a:b]) for a, b in self.bounds]


# ---------------------------------------------------------------------------
# Scan-cycle simulation
# ---------------------------------------------------------------------------

ControlTask = Callable[[np.ndarray, Any], Tuple[np.ndarray, Any]]


@dataclasses.dataclass
class CycleLog:
    """Per-cycle record produced by the runtime (→ §7.2 non-intrusiveness)."""

    cycle_times_s: List[float] = dataclasses.field(default_factory=list)
    control_outputs: List[np.ndarray] = dataclasses.field(default_factory=list)
    detections: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # (cycle index when inference finished, predicted class)
    inference_latency_cycles: List[int] = dataclasses.field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        ct = np.asarray(self.cycle_times_s)
        out = np.asarray(self.control_outputs)
        return {
            "cycles": len(ct),
            "cycle_time_mean_s": float(ct.mean()) if ct.size else 0.0,
            "cycle_time_p99_s": float(np.percentile(ct, 99)) if ct.size else 0.0,
            "control_output_mean": float(out.mean()) if out.size else 0.0,
            "control_output_std": float(out.std()) if out.size else 0.0,
            "n_inferences": len(self.inference_latency_cycles),
        }


class SlidingWindowDetector:
    """The case-study defense: a classifier over the last W sensor readings,
    evaluated multipart so at most one segment runs per scan cycle (§7)."""

    def __init__(
        self,
        model: Model,
        params: ParamTree,
        window: int,
        n_features: int,
        n_segments: int = 1,
    ):
        self.window = window
        self.n_features = n_features
        self.engine = MultipartInference(model, params, n_segments)
        self._buffer = np.zeros((window, n_features), np.float32)
        self._filled = 0
        self._state: Optional[MultipartState] = None
        self._started_at_cycle = -1

    def push(self, reading: np.ndarray) -> None:
        self._buffer = np.roll(self._buffer, -1, axis=0)
        self._buffer[-1] = reading
        self._filled = min(self._filled + 1, self.window)

    @property
    def ready(self) -> bool:
        return self._filled >= self.window

    def tick(self, cycle: int) -> Optional[Tuple[int, int, int]]:
        """Advance inference by one segment.  Returns (cycle, prediction,
        latency_cycles) when an inference completes, else None."""
        if self._state is None:
            if not self.ready:
                return None
            # Feature layout matches §7: ordered readings, features interleaved.
            x = jnp.asarray(self._buffer.reshape(-1))
            self._state = self.engine.start(x)
            self._started_at_cycle = cycle
        self._state = self.engine.step(self._state)
        if self._state.finished(self.engine.n_segments):
            logits = np.asarray(self.engine.output(self._state))
            pred = int(logits.argmax())
            latency = cycle - self._started_at_cycle + 1
            self._state = None
            return (cycle, pred, latency)
        return None


class ScanCycleRuntime:
    """Simulated PLC scan-cycle loop: sense → control → (defense) → actuate."""

    def __init__(
        self,
        control_task: ControlTask,
        detector: Optional[SlidingWindowDetector] = None,
        cycle_budget_s: float = 0.1,
    ):
        self.control_task = control_task
        self.detector = detector
        self.cycle_budget_s = cycle_budget_s

    def run(
        self,
        sensor_stream: Sequence[np.ndarray],
        control_state: Any = None,
    ) -> CycleLog:
        log = CycleLog()
        for cycle, reading in enumerate(sensor_stream):
            t0 = time.perf_counter()
            # 1. control logic (the PLC's primary task — must never be starved)
            output, control_state = self.control_task(reading, control_state)
            # 2. defense: push reading, advance inference by one segment
            if self.detector is not None:
                self.detector.push(np.asarray(reading, np.float32))
                result = self.detector.tick(cycle)
                if result is not None:
                    done_cycle, pred, latency = result
                    log.inference_latency_cycles.append(latency)
                    if pred != 0:
                        log.detections.append((done_cycle, pred))
            log.cycle_times_s.append(time.perf_counter() - t0)
            log.control_outputs.append(np.asarray(output))
        return log
