"""Weight pruning and operation skipping (§6.2), adapted to TPU.

The paper prunes weights to zero and then investigates whether the runtime can
*skip* the corresponding arithmetic.  Findings on the PLC:

* zeroing all weights barely helps (52.13 → 47.62 ms): no automatic skipping;
* a manual per-element IF-skip *loses* in float (50.84 ms: the check costs
  more than the FLOP) and *wins* under SINT quantization (36.39 → 20.87 ms);
* checking inputs AND weights wins further (34.19 ms).

TPU adaptation (documented in DESIGN.md): a systolic MXU cannot predicate
per-MAC, so the paper's insight — *sparsity only pays when skipping is made
structural* — maps to **block sparsity**: the weight matrix is tiled into
MXU-aligned blocks, zero blocks are dropped from the kernel grid entirely
(``repro.kernels.sparse_matmul``), and the per-element IF becomes a gather of
nonzero block indices computed at plan time.  The paper's element-wise
economics are reproduced analytically by :func:`skip_op_counts`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import Dense
from repro.core.model import Model, ParamTree


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero out the smallest-magnitude ``sparsity`` fraction of weights."""
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1), got {sparsity}")
    if sparsity == 0.0:
        return w
    k = int(math.ceil(sparsity * w.size))  # at least `sparsity` achieved
    if k == 0:
        return w
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[k - 1]
    return jnp.where(jnp.abs(w) <= thresh, 0.0, w)


def block_magnitude_prune(
    w: jax.Array, sparsity: float, block: Tuple[int, int] = (128, 128)
) -> jax.Array:
    """Structured pruning: zero whole MXU-aligned blocks by L1 block norm."""
    bi, bj = block
    n, m = w.shape
    if n % bi or m % bj:
        raise ValueError(f"weight shape {w.shape} not divisible by block {block}")
    blocks = w.reshape(n // bi, bi, m // bj, bj)
    norms = jnp.abs(blocks).sum(axis=(1, 3))
    k = int(round(sparsity * norms.size))
    if k == 0:
        return w
    thresh = jnp.sort(norms.reshape(-1))[k - 1]
    mask = (norms > thresh)[:, None, :, None]
    return (blocks * mask).reshape(n, m)


@dataclasses.dataclass(frozen=True)
class BlockSparseWeight:
    """Plan-time representation consumed by the block-sparse kernel.

    ``indices[k] = (bi, bj)`` lists the nonzero blocks; ``values[k]`` holds the
    corresponding (block_n, block_m) tile.  This is the 'precompiled model'
    the paper proposes in §8.1 ('automatically precompiling models to fully
    exploit weight pruning inference latency benefits').
    """

    values: jax.Array          # (nnz_blocks, bn, bm)
    indices: np.ndarray        # (nnz_blocks, 2) static int32 block coordinates
    shape: Tuple[int, int]
    block: Tuple[int, int]

    @property
    def nnz_blocks(self) -> int:
        return int(self.indices.shape[0])

    @property
    def density(self) -> float:
        bn, bm = self.block
        total = (self.shape[0] // bn) * (self.shape[1] // bm)
        return self.nnz_blocks / max(total, 1)

    def to_dense(self) -> jax.Array:
        bn, bm = self.block
        out = jnp.zeros(self.shape, self.values.dtype)
        for k, (bi, bj) in enumerate(self.indices):
            out = out.at[bi * bn : (bi + 1) * bn, bj * bm : (bj + 1) * bm].set(
                self.values[k]
            )
        return out


def compress_blocks(
    w: jax.Array, block: Tuple[int, int] = (128, 128), tol: float = 0.0
) -> BlockSparseWeight:
    """Extract the nonzero-block structure of a (pruned) weight matrix."""
    bn, bm = block
    n, m = w.shape
    if n % bn or m % bm:
        raise ValueError(f"shape {w.shape} not divisible by block {block}")
    w_host = np.asarray(w)
    tiles = w_host.reshape(n // bn, bn, m // bm, bm).transpose(0, 2, 1, 3)
    nz = np.argwhere(np.abs(tiles).max(axis=(2, 3)) > tol).astype(np.int32)
    if nz.size == 0:
        nz = np.zeros((1, 2), np.int32)  # keep at least one block (static shape)
    values = jnp.asarray(tiles[nz[:, 0], nz[:, 1]])
    return BlockSparseWeight(values=values, indices=nz, shape=(n, m), block=block)


def prune_model(
    model: Model, params: ParamTree, sparsity: float, *, block: Tuple[int, int] | None = None
) -> ParamTree:
    """Magnitude-prune every Dense weight in a model."""
    out: ParamTree = {}
    for node in model.graph.nodes:
        p = dict(params[node.uid])
        if isinstance(node.layer, Dense) and "w" in p:
            if block is not None:
                p["w"] = block_magnitude_prune(p["w"], sparsity, block)
            else:
                p["w"] = magnitude_prune(p["w"], sparsity)
        out[node.uid] = p
    return out


def sparsity_of(w: jax.Array) -> float:
    return float(jnp.mean(w == 0.0))


# ---------------------------------------------------------------------------
# §6.2 economics, reproduced analytically.  cost(check) vs cost(mac) ratios are
# taken from the paper's WAGO measurements and let us reproduce its qualitative
# conclusions without PLC hardware.
# ---------------------------------------------------------------------------


def skip_op_counts(
    in_features: int,
    units: int,
    sparsity: float,
    *,
    quantized: bool,
    check_inputs: bool = False,
    input_sparsity: float = 0.0,
) -> Dict[str, float]:
    """Expected operation counts for IF-based skipping (§6.2).

    Returns float ops, int ops and comparison ops; the benchmark converts
    these to time with measured per-op costs to reproduce Fig-6.2's ordering
    (skip hurts in float, helps under quantization, helps more with the
    two-operand check).
    """
    n = in_features * units
    checks = float(n)
    executed = 1.0 - sparsity
    if check_inputs:
        checks += n * (1.0 - sparsity)  # second check short-circuits
        executed *= 1.0 - input_sparsity
    macs = n * executed
    return {
        "compare": checks,
        "mac": macs,
        "mac_dtype": "int" if quantized else "float",
        "rescale_float_mul": in_features + units if quantized else 0,
    }
