"""ICSML Models: an array of layers wired together + an inference method (§4.1).

Two execution modes are provided and tested for bit-equality:

* :meth:`Model.apply` — reference execution over a per-node value table
  (how a conventional framework would do it; our "TFLite stand-in" path uses
  this, unplanned and unquantized).
* :meth:`Model.apply_planned` — ICSML execution: every activation lives at
  its statically-planned offset inside one flat arena (see
  :mod:`repro.core.memory`), and layers are evaluated strictly in the linear
  schedule.  This is the faithful re-host of §4.2.1 + §4.2.3.

Both modes are pure functions of (params, input) and jit-compatible.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import memory as memlib
from repro.core.graph import Graph, chain
from repro.core.layers import Layer, Params

ParamTree = Dict[int, Params]


@dataclasses.dataclass(frozen=True)
class Model:
    """A statically-planned ICSML model."""

    graph: Graph
    input_shape: Tuple[int, ...]

    # ------------------------------------------------------------------ setup
    def init_params(self, key: jax.Array) -> ParamTree:
        shapes = self.graph.infer_shapes(self.input_shape)
        params: ParamTree = {}
        for node in self.graph.nodes:
            key, sub = jax.random.split(key)
            in_shapes = [shapes[r] for r in node.inputs] or [self.input_shape]
            params[node.uid] = node.layer.init_params(sub, in_shapes)
        return params

    def memory_plan(self, *, reuse: bool = True) -> memlib.MemoryPlan:
        return memlib.plan_memory(self.graph, self.input_shape, reuse=reuse)

    # -------------------------------------------------------------- accounting
    def node_in_shapes(self) -> Dict[int, List[Tuple[int, ...]]]:
        shapes = self.graph.infer_shapes(self.input_shape)
        return {
            n.uid: ([shapes[r] for r in n.inputs] or [self.input_shape])
            for n in self.graph.nodes
        }

    def param_bytes(self) -> int:
        in_shapes = self.node_in_shapes()
        return sum(
            n.layer.param_bytes(in_shapes[n.uid]) for n in self.graph.nodes
        )

    def flops(self) -> int:
        in_shapes = self.node_in_shapes()
        return sum(n.layer.flops(in_shapes[n.uid]) for n in self.graph.nodes)

    def node_flops(self) -> Dict[int, int]:
        in_shapes = self.node_in_shapes()
        return {n.uid: n.layer.flops(in_shapes[n.uid]) for n in self.graph.nodes}

    # -------------------------------------------------------------- execution
    def apply(self, params: ParamTree, x: jax.Array) -> jax.Array:
        """Reference (value-table) execution in linear-schedule order."""
        values: Dict[int, jax.Array] = {}
        for node in self.graph.nodes:
            inputs = [values[r] for r in node.inputs] or [x]
            values[node.uid] = node.layer.apply(params[node.uid], inputs)
        return values[self.graph.output_uid]

    def apply_planned(self, params: ParamTree, x: jax.Array) -> jax.Array:
        """Planned (arena) execution — activations live in one flat buffer."""
        arena, plan = self._run_arena(params, x)
        return memlib.arena_read(arena, plan.buffers[self.graph.output_uid])

    def _run_arena(
        self, params: ParamTree, x: jax.Array, upto: Optional[int] = None
    ) -> Tuple[jax.Array, memlib.MemoryPlan]:
        plan = self.memory_plan()
        arena = jnp.zeros((plan.arena_size,), jnp.float32)
        nodes = self.graph.nodes if upto is None else self.graph.nodes[:upto]
        for node in nodes:
            if node.inputs:
                inputs = [memlib.arena_read(arena, plan.buffers[r]) for r in node.inputs]
            else:
                inputs = [x]
            out = node.layer.apply(params[node.uid], inputs)
            arena = memlib.arena_write(arena, plan.buffers[node.uid], out)
        return arena, plan

    # Segment execution used by multipart inference (§6.3): evaluate schedule
    # positions [start, stop) over an existing arena.
    def apply_segment(
        self,
        params: ParamTree,
        arena: jax.Array,
        x: jax.Array,
        start: int,
        stop: int,
        plan: Optional[memlib.MemoryPlan] = None,
    ) -> jax.Array:
        plan = plan or self.memory_plan()
        for node in self.graph.nodes[start:stop]:
            if node.inputs:
                inputs = [memlib.arena_read(arena, plan.buffers[r]) for r in node.inputs]
            else:
                inputs = [x]
            out = node.layer.apply(params[node.uid], inputs)
            arena = memlib.arena_write(arena, plan.buffers[node.uid], out)
        return arena

    def read_output(self, arena: jax.Array, plan: Optional[memlib.MemoryPlan] = None) -> jax.Array:
        plan = plan or self.memory_plan()
        return memlib.arena_read(arena, plan.buffers[self.graph.output_uid])

    # ------------------------------------------------------------------- misc
    def summary(self) -> str:
        shapes = self.graph.infer_shapes(self.input_shape)
        in_shapes = self.node_in_shapes()
        lines = ["uid  layer                     out_shape        params(B)   flops"]
        for n in self.graph.nodes:
            lines.append(
                f"{n.uid:<4d} {type(n.layer).__name__:<25s} "
                f"{str(shapes[n.uid]):<16s} "
                f"{n.layer.param_bytes(in_shapes[n.uid]):<11d} "
                f"{n.layer.flops(in_shapes[n.uid])}"
            )
        plan = self.memory_plan()
        lines.append(f"arena: {plan.arena_bytes} B, params: {self.param_bytes()} B")
        return "\n".join(lines)


def sequential(layers: Sequence[Layer], input_shape: Sequence[int]) -> Model:
    """Convenience: build the common sequential model."""
    return Model(graph=chain(layers), input_shape=tuple(input_shape))
