"""Integer quantization for ICSML models (§6.1, Table 2).

The paper quantizes REAL (f32) weights to the IEC 61131-3 integer types
SINT (int8), INT (int16) and DINT (int32), keeping biases and scaling factors
REAL.  Table 2 accounts one REAL scaling factor *per output neuron* plus one
for the input activations (512 + 1 = 513 scales → 2052 bytes for the 512-wide
layer), i.e. the paper's scheme is symmetric **per-channel** weight
quantization with a single per-tensor activation scale.  We implement exactly
that (and a per-tensor variant for ablation).

Quantized evaluation (performed by ``layers._quantized_matvec``) reproduces the
paper's §6.1 operation analysis for an N-in/M-out dense layer:

  float multiplications : N (activation quantization) + M (rescale)  = N+M
  float additions       : M (bias)
  integer mult/add      : N*M each (the dot product)

The hot integer matmul has a Pallas TPU kernel (``repro.kernels.qmatmul``)
targeting the MXU int8 path; the jnp path here doubles as its oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import IEC_INT_TYPES, Dense
from repro.core.model import Model, ParamTree

SCHEMES = ("SINT", "INT", "DINT")  # REAL == unquantized


def _int_dtype(scheme: str) -> jnp.dtype:
    try:
        return jnp.dtype(IEC_INT_TYPES[scheme])
    except KeyError:
        raise ValueError(f"unknown quantization scheme {scheme!r}; pick from {SCHEMES}")


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    q: jax.Array          # integer representation
    scale: jax.Array      # REAL scaling factor(s): () or (out_channels,)

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def quantize_tensor(
    w: jax.Array, scheme: str, *, per_channel: bool = True, axis: int = -1
) -> QuantizedTensor:
    """Symmetric integer quantization with REAL scaling factors."""
    dtype = _int_dtype(scheme)
    qmax = float(jnp.iinfo(dtype).max)
    if per_channel and w.ndim >= 2:
        reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
        absmax = jnp.max(jnp.abs(w), axis=reduce_axes)
    else:
        absmax = jnp.max(jnp.abs(w))
    scale = jnp.maximum(absmax, 1e-12) / qmax
    # Clip symmetrically to [-qmax, qmax]: scale is derived from qmax, so
    # admitting the extra negative code (-qmax - 1, e.g. -128 for SINT) lets
    # a weight at -absmax dequantize to -absmax - scale, outside the
    # symmetric range and past quantization_error_bound(scale).
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(dtype)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32))


def calibrate_activation_scales(
    model: Model, params: ParamTree, samples: Iterable[jax.Array], scheme: str
) -> Dict[int, jax.Array]:
    """Per-node activation scales from representative data (the porting step's
    calibration pass; the paper collects such data on the PLC via ARRBIN)."""
    qmax = float(jnp.iinfo(_int_dtype(scheme)).max)
    absmax: Dict[int, jax.Array] = {}
    for x in samples:
        values: Dict[int, jax.Array] = {}
        for node in model.graph.nodes:
            inputs = [values[r] for r in node.inputs] or [x]
            if isinstance(node.layer, Dense):
                m = jnp.max(jnp.abs(inputs[0]))
                absmax[node.uid] = jnp.maximum(absmax.get(node.uid, 0.0), m)
            values[node.uid] = node.layer.apply(params[node.uid], inputs)
    return {
        uid: (jnp.maximum(m, 1e-12) / qmax).astype(jnp.float32)
        for uid, m in absmax.items()
    }


def calibration_samples(
    x, labels=None, *, k: int = 32
) -> List[jax.Array]:
    """Representative-input samples for :func:`calibrate_activation_scales`,
    drawn evenly from the *benign* rows of a dataset.

    Activation scales must come from the activation ranges the layer will
    actually see: the autoencoder's decoder output layer reproduces the
    ±several-sigma normalized window, and its 64-wide input activations
    range far outside the ``[-1, 1]`` the uncalibrated default
    (``x_scale = 1/qmax``) assumes — weight-absmax scales alone leave SINT
    reconstruction error orders of magnitude off REAL.  Benign windows are
    exactly what the detector serves pre-onset, so they bound the scales the
    §6.1 arithmetic runs under (``labels`` drops attack windows when given).
    """
    x = np.asarray(x)
    if labels is not None:
        x = x[np.asarray(labels) == 0]
    if len(x) == 0:
        raise ValueError("no benign rows to calibrate on")
    idx = np.linspace(0, len(x) - 1, min(k, len(x))).astype(int)
    return [jnp.asarray(x[i]) for i in idx]


def quantize_params(
    model: Model,
    params: ParamTree,
    scheme: str,
    *,
    per_channel: bool = True,
    calibration: Optional[Sequence[jax.Array]] = None,
    only_nodes: Optional[Sequence[int]] = None,
) -> ParamTree:
    """Quantize the Dense weights of a trained model (the §4.3 porting step).

    ``only_nodes`` restricts quantization to a subset — the paper isolates and
    quantizes a single hidden layer in §6.1.
    """
    x_scales = (
        calibrate_activation_scales(model, params, calibration, scheme)
        if calibration is not None
        else {}
    )
    qmax = float(jnp.iinfo(_int_dtype(scheme)).max)
    out: ParamTree = {}
    for node in model.graph.nodes:
        p = dict(params[node.uid])
        quantizable = isinstance(node.layer, Dense) and "w" in p
        selected = only_nodes is None or node.uid in only_nodes
        if quantizable and selected:
            qt = quantize_tensor(p.pop("w"), scheme, per_channel=per_channel)
            p["qw"] = qt.q
            p["w_scale"] = qt.scale
            # Default activation scale assumes inputs in [-1, 1] (sensor
            # readings are normalized on the PLC before inference).
            p["x_scale"] = x_scales.get(
                node.uid, jnp.asarray(1.0 / qmax, jnp.float32)
            )
        out[node.uid] = p
    return out


# ---------------------------------------------------------------------------
# Memory accounting (Table 2) and operation analysis (§6.1) — analytic,
# byte-exact reproductions of the paper's numbers.
# ---------------------------------------------------------------------------


def memory_report(in_features: int, units: int, scheme: str) -> Dict[str, int]:
    """Bytes for one dense layer under a quantization scheme (Table 2)."""
    if scheme == "REAL":
        return {
            "weights": in_features * units * 4,
            "biases": units * 4,
            "scaling_factors": 0,
            "total": in_features * units * 4 + units * 4,
        }
    itemsize = IEC_INT_TYPES[scheme].itemsize
    weights = in_features * units * itemsize
    biases = units * 4
    scales = (units + 1) * 4  # per-channel weight scales + activation scale
    return {
        "weights": weights,
        "biases": biases,
        "scaling_factors": scales,
        "total": weights + biases + scales,
    }


def op_counts(in_features: int, units: int, quantized: bool) -> Dict[str, int]:
    """§6.1 arithmetic-operation analysis for one dense layer evaluation."""
    if not quantized:
        return {
            "float_mul": in_features * units,
            "float_add": in_features * units + units,  # accumulate + bias
            "int_mul": 0,
            "int_add": 0,
        }
    return {
        "float_mul": in_features + units,  # activation quant + rescale
        "float_add": units,                # bias
        "int_mul": in_features * units,
        "int_add": in_features * units,
    }


def quantization_error_bound(scale: jax.Array) -> jax.Array:
    """Symmetric rounding error bound: |w - deq(q(w))| <= scale / 2."""
    return scale / 2.0
