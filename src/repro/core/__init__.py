"""repro.core — the ICSML framework re-hosted on JAX.

Public API:

* layers: :mod:`repro.core.layers` (Dense, Activation, Concat, Conv2D, ...)
* graphs/models: :func:`repro.core.model.sequential`, :class:`Model`, :class:`Graph`
* static memory planning: :func:`repro.core.memory.plan_memory`
* quantization (§6.1): :func:`repro.core.quantize.quantize_params`
* pruning (§6.2): :mod:`repro.core.prune`
* multipart inference + scan-cycle runtime (§6.3): :mod:`repro.core.runtime`
* porting methodology (§4.3): :mod:`repro.core.porting`
"""

from repro.core import graph, layers, memory, model, porting, prune, quantize, runtime
from repro.core.graph import Graph, Node, chain
from repro.core.model import Model, sequential
from repro.core.runtime import (
    MultipartInference,
    ScanCycleRuntime,
    SlidingWindowDetector,
)

__all__ = [
    "graph", "layers", "memory", "model", "porting", "prune", "quantize",
    "runtime", "Graph", "Node", "chain", "Model", "sequential",
    "MultipartInference", "ScanCycleRuntime", "SlidingWindowDetector",
]
