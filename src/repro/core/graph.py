"""Layer graph and linear (non-chained) execution schedule.

ICSML (§4.2.3) evaluates models by *linearly* calling layer evaluation
functions over shared memory areas, because IEC 61131-3 forbids recursion and
chained function-block calls.  The JAX analogue is an explicit, ahead-of-time
topological schedule over a DAG of layer nodes: no Python recursion appears in
traced code, and every layer reads/writes buffers assigned by the static
memory planner (see :mod:`repro.core.memory`).

A :class:`Graph` is a list of :class:`Node` objects.  Each node names its
input nodes by id; node 0 conventionally is the model input.  The linear
schedule is just a validated topological order — for ICSML models the authoring
order *is* the schedule (models are "an array of layers wired together").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.layers import Layer


class GraphError(ValueError):
    """Raised for malformed layer graphs (cycles, dangling refs, ...)."""


@dataclasses.dataclass(frozen=True)
class Node:
    """One entry of the model's layer array.

    Attributes:
      uid:    integer id, unique within the graph.
      layer:  the :class:`~repro.core.layers.Layer` evaluated at this node.
      inputs: uids of producer nodes (empty for the input node).
    """

    uid: int
    layer: Layer
    inputs: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class Graph:
    """A DAG of layers with a validated linear schedule."""

    nodes: Tuple[Node, ...]

    def __post_init__(self) -> None:
        seen: set = set()
        for node in self.nodes:
            if node.uid in seen:
                raise GraphError(f"duplicate node uid {node.uid}")
            for ref in node.inputs:
                if ref not in seen:
                    raise GraphError(
                        f"node {node.uid} reads {ref} before it is produced; "
                        "the layer array must be a valid linear schedule "
                        "(ICSML forbids forward/recursive references)"
                    )
            seen.add(node.uid)

    @property
    def schedule(self) -> Tuple[int, ...]:
        """The linear evaluation order (authoring order, validated acyclic)."""
        return tuple(n.uid for n in self.nodes)

    @property
    def output_uid(self) -> int:
        return self.nodes[-1].uid

    def node(self, uid: int) -> Node:
        for n in self.nodes:
            if n.uid == uid:
                return n
        raise GraphError(f"no node with uid {uid}")

    def consumers(self) -> Dict[int, List[int]]:
        """Map producer uid -> list of consumer uids (for liveness analysis)."""
        out: Dict[int, List[int]] = {n.uid: [] for n in self.nodes}
        for n in self.nodes:
            for ref in n.inputs:
                out[ref].append(n.uid)
        return out

    def last_use(self) -> Dict[int, int]:
        """Map uid -> schedule position of its last consumer.

        The model output is considered live until the end of the schedule.
        Used by the static memory planner to compute liveness intervals.
        """
        pos = {uid: i for i, uid in enumerate(self.schedule)}
        last = {n.uid: pos[n.uid] for n in self.nodes}
        for n in self.nodes:
            for ref in n.inputs:
                last[ref] = max(last[ref], pos[n.uid])
        last[self.output_uid] = len(self.nodes) - 1
        return last

    def infer_shapes(self, input_shape: Sequence[int]) -> Dict[int, Tuple[int, ...]]:
        """Propagate static shapes through the schedule.

        Mirrors ICSML's structured declaration of layer sizes via constants:
        every buffer size is known before anything executes.
        """
        shapes: Dict[int, Tuple[int, ...]] = {}
        for node in self.nodes:
            in_shapes = [shapes[r] for r in node.inputs]
            if not in_shapes:
                in_shapes = [tuple(int(d) for d in input_shape)]
            shapes[node.uid] = node.layer.out_shape(in_shapes)
        return shapes


def chain(layers: Sequence[Layer]) -> Graph:
    """Build the common case: a purely sequential model (array of layers)."""
    nodes = []
    for i, layer in enumerate(layers):
        nodes.append(Node(uid=i, layer=layer, inputs=() if i == 0 else (i - 1,)))
    return Graph(nodes=tuple(nodes))
