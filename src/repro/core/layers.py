"""ICSML layer set, re-hosted in JAX.

The paper (§4.1) provides Dense, Activation, Concatenation layers plus the
components needed for CNNs/ResNets/RNNs, and eight parameterizable activation
functions.  Layers here follow the same contract as ICSML POUs:

* all shapes are static and known ahead of time (``out_shape``),
* evaluation is a pure function over explicitly-passed buffers (``apply``),
* every layer reports its parameter memory and arithmetic cost so that the
  static memory planner and the multipart-inference scheduler (§6.3) can plan
  without executing anything.

Layers operate on a *single sample* (PLCs process one scan-cycle's reading at
a time); batching is applied externally with ``jax.vmap``.

Quantized evaluation (§6.1) follows the paper's arithmetic exactly: weights are
stored as int8/int16/int32 with a REAL (f32) scale; the input vector is
quantized on the fly (N float mults), accumulation is integer, and the result
is rescaled and biased in float (N float mults + N float adds) — reproducing
the op-count analysis of §6.1.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]
Shape = Tuple[int, ...]

# ---------------------------------------------------------------------------
# Activation functions (§4.1: Binary Step, ELU, ReLU, Leaky ReLU, Sigmoid,
# Softmax, Swish, Tanh).
# ---------------------------------------------------------------------------


def binary_step(x: jax.Array) -> jax.Array:
    return jnp.where(x >= 0, 1.0, 0.0).astype(x.dtype)


def elu(x: jax.Array, alpha: float = 1.0) -> jax.Array:
    return jnp.where(x > 0, x, alpha * jnp.expm1(x))


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0)


def leaky_relu(x: jax.Array, alpha: float = 0.01) -> jax.Array:
    return jnp.where(x > 0, x, alpha * x)


def sigmoid(x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(x)


def softmax(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x, axis=-1)


def swish(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def tanh(x: jax.Array) -> jax.Array:
    return jnp.tanh(x)


ACTIVATIONS: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "binary_step": binary_step,
    "elu": elu,
    "relu": relu,
    "leaky_relu": leaky_relu,
    "sigmoid": sigmoid,
    "softmax": softmax,
    "swish": swish,
    "tanh": tanh,
    "linear": lambda x: x,
}

# IEC 61131-3 integer types used for quantization (§6.1 / Table 2).
IEC_INT_TYPES: Dict[str, np.dtype] = {
    "SINT": np.dtype(np.int8),    # 8-bit
    "INT": np.dtype(np.int16),    # 16-bit
    "DINT": np.dtype(np.int32),   # 32-bit
}


def _prod(xs: Sequence[int]) -> int:
    return int(math.prod(xs)) if xs else 1


# ---------------------------------------------------------------------------
# Layer base
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Layer:
    """Base class for ICSML layers."""

    name: str = dataclasses.field(default="", kw_only=True)

    # -- static planning interface -------------------------------------------------
    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        raise NotImplementedError

    def init_params(self, key: jax.Array, in_shapes: List[Shape]) -> Params:
        return {}

    def param_bytes(self, in_shapes: List[Shape]) -> int:
        return 0

    def flops(self, in_shapes: List[Shape]) -> int:
        """Approximate arithmetic ops for one evaluation (multipart planning)."""
        return _prod(self.out_shape(in_shapes))

    # -- execution -----------------------------------------------------------------
    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Input(Layer):
    """Input copy layer — ICSML's input layer 'performs a simple copy' (§5.2)."""

    features: Tuple[int, ...] = ()

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return tuple(self.features) if self.features else in_shapes[0]

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        return inputs[0]


@dataclasses.dataclass(frozen=True)
class Dense(Layer):
    """Fully connected layer: ``y = act(x @ W + b)``.

    Supports the paper's quantized evaluation when params were produced by
    :func:`repro.core.quantize.quantize_params`: params then hold ``qw``
    (integer weights), ``w_scale`` (REAL scaling factor(s)), and ``b``.
    """

    units: int = 0
    activation: str = "linear"
    use_bias: bool = True

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return (self.units,)

    def init_params(self, key: jax.Array, in_shapes: List[Shape]) -> Params:
        (in_features,) = in_shapes[0]
        kw, _ = jax.random.split(key)
        limit = math.sqrt(6.0 / (in_features + self.units))  # Glorot uniform
        w = jax.random.uniform(
            kw, (in_features, self.units), jnp.float32, -limit, limit
        )
        params = {"w": w}
        if self.use_bias:
            params["b"] = jnp.zeros((self.units,), jnp.float32)
        return params

    def param_bytes(self, in_shapes: List[Shape]) -> int:
        (in_features,) = in_shapes[0]
        total = in_features * self.units * 4
        if self.use_bias:
            total += self.units * 4
        return total

    def flops(self, in_shapes: List[Shape]) -> int:
        (in_features,) = in_shapes[0]
        return 2 * in_features * self.units

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        x = inputs[0]
        if "qw" in params:
            y = _quantized_matvec(x, params)
        else:
            y = x @ params["w"]
            if self.use_bias:
                y = y + params["b"]
        return ACTIVATIONS[self.activation](y)


def _quantized_matvec(x: jax.Array, params: Params) -> jax.Array:
    """Paper-faithful quantized dense evaluation (§6.1).

    For an N-in/M-out layer this performs:
      * N float multiplications to quantize the activations,
      * N*M integer multiplications + N*M integer additions (the dot product),
      * M float multiplications (rescale) + M float additions (bias),
    matching the §6.1 operation analysis (with per-channel scales — the
    beyond-paper variant — the rescale stays M float mults).
    """
    qw = params["qw"]                      # (N, M) integer
    w_scale = params["w_scale"]            # () per-tensor or (M,) per-channel
    x_scale = params["x_scale"]            # () REAL scaling factor for inputs
    qmax = jnp.iinfo(qw.dtype).max
    # Quantize activations on the fly (N float mults + round).  The clip is
    # symmetric ([-qmax, qmax], matching quantize.quantize_tensor): x_scale
    # is derived from qmax, so the extra negative code would decode outside
    # the calibrated range.
    xq = jnp.clip(jnp.round(x / x_scale), -qmax, qmax)
    if qw.dtype == jnp.int8:
        # Native integer dot product with a wide accumulator — the TPU MXU
        # int8 path (and the PLC's INT→DINT accumulate).
        acc = jax.lax.dot_general(
            xq.astype(qw.dtype),
            qw,
            (((xq.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
    else:
        # INT/DINT: int16/int32 products overflow an int32 accumulator (and
        # TPUs have no int16/int32 MXU mode), so the arithmetic is emulated
        # in f32 — the storage compression (Table 2) is what these schemes
        # buy on TPU; DESIGN.md §2 records the adaptation.  The clipped
        # values stay f32 (no int round-trip): int32's qmax is not f32-
        # representable, so the cast would overflow at the clip rail.
        acc = jax.lax.dot_general(
            xq,
            qw.astype(jnp.float32),
            (((xq.ndim - 1,), (0,)), ((), ())),
        )
    y = acc * (x_scale * w_scale)
    if "b" in params:
        y = y + params["b"]
    return y


@dataclasses.dataclass(frozen=True)
class Activation(Layer):
    """Standalone activation layer (§4.1)."""

    fn: str = "relu"

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return in_shapes[0]

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        return ACTIVATIONS[self.fn](inputs[0])


@dataclasses.dataclass(frozen=True)
class Concat(Layer):
    """Concatenation layer — enables branching models and RNNs (§4.1, §8.2)."""

    axis: int = -1

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        axis = self.axis % len(in_shapes[0])
        out = list(in_shapes[0])
        out[axis] = sum(s[axis] for s in in_shapes)
        for s in in_shapes:
            for d, (a, b) in enumerate(zip(s, in_shapes[0])):
                if d != axis and a != b:
                    raise ValueError(f"concat shape mismatch: {in_shapes}")
        return tuple(out)

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        return jnp.concatenate(inputs, axis=self.axis)


@dataclasses.dataclass(frozen=True)
class Add(Layer):
    """Elementwise residual add — building block for ResNets (§4.1)."""

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return in_shapes[0]

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        out = inputs[0]
        for x in inputs[1:]:
            out = out + x
        return out


@dataclasses.dataclass(frozen=True)
class Flatten(Layer):
    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return (_prod(in_shapes[0]),)

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        return inputs[0].reshape(-1)


@dataclasses.dataclass(frozen=True)
class Conv2D(Layer):
    """2-D convolution over a single (H, W, C) sample."""

    filters: int = 0
    kernel_size: Tuple[int, int] = (3, 3)
    strides: Tuple[int, int] = (1, 1)
    padding: str = "SAME"
    activation: str = "linear"
    use_bias: bool = True

    def _spatial_out(self, size: int, k: int, s: int) -> int:
        if self.padding == "SAME":
            return -(-size // s)
        return (size - k) // s + 1

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        h, w, _ = in_shapes[0]
        kh, kw = self.kernel_size
        sh, sw = self.strides
        return (self._spatial_out(h, kh, sh), self._spatial_out(w, kw, sw), self.filters)

    def init_params(self, key: jax.Array, in_shapes: List[Shape]) -> Params:
        _, _, cin = in_shapes[0]
        kh, kw = self.kernel_size
        fan_in = kh * kw * cin
        limit = math.sqrt(6.0 / (fan_in + self.filters))
        w = jax.random.uniform(
            key, (kh, kw, cin, self.filters), jnp.float32, -limit, limit
        )
        params = {"w": w}
        if self.use_bias:
            params["b"] = jnp.zeros((self.filters,), jnp.float32)
        return params

    def param_bytes(self, in_shapes: List[Shape]) -> int:
        _, _, cin = in_shapes[0]
        kh, kw = self.kernel_size
        return (kh * kw * cin * self.filters + (self.filters if self.use_bias else 0)) * 4

    def flops(self, in_shapes: List[Shape]) -> int:
        _, _, cin = in_shapes[0]
        oh, ow, _ = self.out_shape(in_shapes)
        kh, kw = self.kernel_size
        return 2 * oh * ow * kh * kw * cin * self.filters

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        x = inputs[0][None]  # add batch dim
        y = jax.lax.conv_general_dilated(
            x,
            params["w"],
            window_strides=self.strides,
            padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )[0]
        if self.use_bias:
            y = y + params["b"]
        return ACTIVATIONS[self.activation](y)


@dataclasses.dataclass(frozen=True)
class DepthwiseConv2D(Layer):
    """Depthwise convolution (MobileNet ConvDW blocks — §6.3 multipart demo)."""

    kernel_size: Tuple[int, int] = (3, 3)
    strides: Tuple[int, int] = (1, 1)
    padding: str = "SAME"
    activation: str = "linear"
    use_bias: bool = True

    def _spatial_out(self, size: int, k: int, s: int) -> int:
        if self.padding == "SAME":
            return -(-size // s)
        return (size - k) // s + 1

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        h, w, c = in_shapes[0]
        kh, kw = self.kernel_size
        sh, sw = self.strides
        return (self._spatial_out(h, kh, sh), self._spatial_out(w, kw, sw), c)

    def init_params(self, key: jax.Array, in_shapes: List[Shape]) -> Params:
        _, _, cin = in_shapes[0]
        kh, kw = self.kernel_size
        limit = math.sqrt(6.0 / (kh * kw + 1))
        w = jax.random.uniform(key, (kh, kw, 1, cin), jnp.float32, -limit, limit)
        params = {"w": w}
        if self.use_bias:
            params["b"] = jnp.zeros((cin,), jnp.float32)
        return params

    def param_bytes(self, in_shapes: List[Shape]) -> int:
        _, _, cin = in_shapes[0]
        kh, kw = self.kernel_size
        return (kh * kw * cin + (cin if self.use_bias else 0)) * 4

    def flops(self, in_shapes: List[Shape]) -> int:
        oh, ow, c = self.out_shape(in_shapes)
        kh, kw = self.kernel_size
        return 2 * oh * ow * kh * kw * c

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        x = inputs[0][None]
        cin = x.shape[-1]
        y = jax.lax.conv_general_dilated(
            x,
            params["w"],
            window_strides=self.strides,
            padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=cin,
        )[0]
        if self.use_bias:
            y = y + params["b"]
        return ACTIVATIONS[self.activation](y)


@dataclasses.dataclass(frozen=True)
class BatchNorm(Layer):
    """Inference-mode batch norm: a static scale/shift (folded statistics)."""

    epsilon: float = 1e-3
    activation: str = "linear"

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return in_shapes[0]

    def init_params(self, key: jax.Array, in_shapes: List[Shape]) -> Params:
        c = in_shapes[0][-1]
        return {
            "gamma": jnp.ones((c,), jnp.float32),
            "beta": jnp.zeros((c,), jnp.float32),
            "mean": jnp.zeros((c,), jnp.float32),
            "var": jnp.ones((c,), jnp.float32),
        }

    def param_bytes(self, in_shapes: List[Shape]) -> int:
        return in_shapes[0][-1] * 4 * 4

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        x = inputs[0]
        inv = jax.lax.rsqrt(params["var"] + self.epsilon) * params["gamma"]
        return ACTIVATIONS[self.activation]((x - params["mean"]) * inv + params["beta"])


@dataclasses.dataclass(frozen=True)
class GlobalAvgPool(Layer):
    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return (in_shapes[0][-1],)

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        return inputs[0].mean(axis=(0, 1))


@dataclasses.dataclass(frozen=True)
class Lambda(Layer):
    """Custom-functionality layer — ICSML's interface-template answer to the
    Keras lambda layer (§4.2.2).  ``fn`` must be a pure, shape-preserving-or-
    declared JAX function; ``out`` declares the output shape (static planning
    requires it, exactly like implementing the ST interface template)."""

    fn: Optional[Callable[..., jax.Array]] = None
    out: Tuple[int, ...] = ()

    def out_shape(self, in_shapes: List[Shape]) -> Shape:
        return tuple(self.out) if self.out else in_shapes[0]

    def apply(self, params: Params, inputs: List[jax.Array]) -> jax.Array:
        assert self.fn is not None, "Lambda layer requires fn"
        return self.fn(*inputs)
