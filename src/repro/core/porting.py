"""Model porting methodology (§4.3) + BINARR/ARRBIN binary I/O.

The paper's end-to-end flow: collect data on the PLC (ARRBIN), train in an
established framework, extract weights/biases to binary files, statically
reconstruct the model in ICSML, load the binaries (BINARR), infer.

Here the 'established framework' is the repo's own training stack
(`repro.optim` + `repro.models`), and the ICSML target is `repro.core`.
``arrbin``/``binarr`` write/read raw little-endian binary exactly like the ST
functions, and are also used to move datasets and inference logs.
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import Dense, Input
from repro.core.model import Model, ParamTree, sequential


def arrbin(path: str, arr: np.ndarray | jax.Array) -> int:
    """ICSML.ARRBIN: dump an array's raw bytes to a binary file.

    Returns the number of bytes written (the ST function takes the byte count
    and ADR(...); we derive both from the array)."""
    data = np.ascontiguousarray(np.asarray(arr))
    with open(path, "wb") as f:
        f.write(data.tobytes())
    return data.nbytes


def binarr(path: str, dtype: np.dtype | str, shape: Sequence[int]) -> np.ndarray:
    """ICSML.BINARR: load raw binary data back into a (statically shaped) array."""
    dtype = np.dtype(dtype)
    expected = int(np.prod(shape)) * dtype.itemsize
    with open(path, "rb") as f:
        raw = f.read()
    if len(raw) != expected:
        raise ValueError(
            f"{path}: expected {expected} bytes for {tuple(shape)} {dtype}, "
            f"found {len(raw)}"
        )
    return np.frombuffer(raw, dtype=dtype).reshape(tuple(shape)).copy()


# ---------------------------------------------------------------------------
# Weight extraction + static reconstruction
# ---------------------------------------------------------------------------


def extract_mlp_weights(
    params: ParamTree, model: Model
) -> List[Tuple[np.ndarray, Optional[np.ndarray]]]:
    """Extract (W, b) pairs from a trained sequential model in schedule order."""
    out = []
    for node in model.graph.nodes:
        p = params[node.uid]
        if isinstance(node.layer, Dense):
            out.append((np.asarray(p["w"]), np.asarray(p.get("b"))))
    return out


def export_weights(
    weights: Sequence[Tuple[np.ndarray, Optional[np.ndarray]]], directory: str
) -> List[str]:
    """Write each layer's weights/biases to binary files (porting step 3)."""
    os.makedirs(directory, exist_ok=True)
    paths = []
    for i, (w, b) in enumerate(weights):
        wp = os.path.join(directory, f"L{i}_weights.bin")
        arrbin(wp, w.astype(np.float32))
        paths.append(wp)
        if b is not None:
            bp = os.path.join(directory, f"L{i}_biases.bin")
            arrbin(bp, b.astype(np.float32))
            paths.append(bp)
    return paths


def build_mlp(
    layer_sizes: Sequence[int],
    input_size: int,
    activations: Sequence[str],
) -> Model:
    """Static reconstruction (porting step 4): declare layer sizes as
    constants, then build the array of layers.  Mirrors the paper's listing
    (L1_size, L1_weights[0..L1_size*input_size-1], dataMem construction)."""
    if len(activations) != len(layer_sizes):
        raise ValueError("need one activation per layer")
    layers = [Input()]
    for units, act in zip(layer_sizes, activations):
        layers.append(Dense(units=units, activation=act))
    return sequential(layers, (input_size,))


def load_mlp_params(
    model: Model, directory: str
) -> ParamTree:
    """Porting step 5: BINARR the weights/biases into the reconstructed model."""
    shapes = model.graph.infer_shapes(model.input_shape)
    params: ParamTree = {}
    dense_idx = 0
    for node in model.graph.nodes:
        if isinstance(node.layer, Dense):
            in_shape = (
                shapes[node.inputs[0]] if node.inputs else model.input_shape
            )
            w = binarr(
                os.path.join(directory, f"L{dense_idx}_weights.bin"),
                np.float32,
                (in_shape[0], node.layer.units),
            )
            p = {"w": jnp.asarray(w)}
            bpath = os.path.join(directory, f"L{dense_idx}_biases.bin")
            if os.path.exists(bpath):
                b = binarr(bpath, np.float32, (node.layer.units,))
                p["b"] = jnp.asarray(b)
            params[node.uid] = p
            dense_idx += 1
        else:
            params[node.uid] = {}
    return params


def port_mlp(
    trained_model: Model,
    trained_params: ParamTree,
    directory: str,
) -> Tuple[Model, ParamTree]:
    """The full §4.3 round trip: extract → export → reconstruct → load.

    Returns a *new* Model + params whose inference is bit-identical to the
    trained one (verified in tests) — the paper's 'no sacrifice in inference
    accuracy' claim."""
    weights = extract_mlp_weights(trained_params, trained_model)
    export_weights(weights, directory)
    sizes, acts = [], []
    for node in trained_model.graph.nodes:
        if isinstance(node.layer, Dense):
            sizes.append(node.layer.units)
            acts.append(node.layer.activation)
    ported = build_mlp(sizes, trained_model.input_shape[0], acts)
    return ported, load_mlp_params(ported, directory)
