"""Static memory planner — the JAX re-host of ICSML's ``dataMem`` (§4.2.1).

IEC 61131-3 has no dynamic memory management, so ICSML statically declares
every weight matrix, bias vector and activation buffer, and wraps the raw
memory areas in ``dataMem`` structures carrying address + dimensionality
metadata.  Layers share these areas by reference, which both avoids
call-by-value duplication and lets one flat region back many logical buffers.

Here the same discipline is made explicit and *checkable*:

* :func:`plan_memory` computes, ahead of time, a liveness interval for every
  activation buffer in the linear schedule and packs them into a single flat
  arena with first-fit offset assignment (buffers whose lifetimes do not
  overlap share memory — the dataMem reuse trick, automated).
* :class:`MemoryPlan` is the dataMem table: per-buffer offset, size, shape and
  live interval, plus the arena size.  ``validate()`` proves the no-overlap
  invariant; property tests fuzz it.
* :func:`arena_read` / :func:`arena_write` are the traced accessors used by
  planned execution (`Model.apply_planned`) — activations genuinely live in
  one donated f32 buffer, as on the PLC.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

Shape = Tuple[int, ...]

# TPU lane width; aligning buffer offsets to 128 f32 elements keeps
# dynamic-slice reads layout-friendly.  (The PLC analogue is word alignment.)
DEFAULT_ALIGN = 128


@dataclasses.dataclass(frozen=True)
class BufferInfo:
    """One dataMem entry: a buffer's address + metadata (§4.2.1)."""

    uid: int                 # producing node
    offset: int              # element offset into the arena
    size: int                # number of elements
    shape: Shape             # logical dimensionality ("dimensions" metadata)
    live: Tuple[int, int]    # [first, last] schedule positions (inclusive)

    @property
    def end(self) -> int:
        return self.offset + self.size


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    """The static activation-memory plan for one model."""

    arena_size: int                      # elements (f32)
    buffers: Dict[int, BufferInfo]

    @property
    def arena_bytes(self) -> int:
        return self.arena_size * 4

    def validate(self) -> None:
        """No two *concurrently live* buffers may overlap, and every buffer
        must fit in the arena.  Raises ``ValueError`` on violation."""
        infos = list(self.buffers.values())
        for b in infos:
            if b.offset < 0 or b.end > self.arena_size:
                raise ValueError(f"buffer {b.uid} [{b.offset},{b.end}) outside arena")
            if b.live[0] > b.live[1]:
                raise ValueError(f"buffer {b.uid} has empty liveness {b.live}")
        for i, a in enumerate(infos):
            for b in infos[i + 1:]:
                lives_overlap = not (a.live[1] < b.live[0] or b.live[1] < a.live[0])
                mem_overlap = not (a.end <= b.offset or b.end <= a.offset)
                if lives_overlap and mem_overlap:
                    raise ValueError(
                        f"live buffers overlap: {a.uid}@[{a.offset},{a.end}) "
                        f"live{a.live} vs {b.uid}@[{b.offset},{b.end}) live{b.live}"
                    )


def _align(x: int, align: int) -> int:
    return ((x + align - 1) // align) * align


def plan_memory(
    graph: Graph,
    input_shape: Sequence[int],
    *,
    align: int = DEFAULT_ALIGN,
    reuse: bool = True,
) -> MemoryPlan:
    """First-fit static packing of activation buffers.

    With ``reuse=False`` every buffer gets a private region (the naive layout a
    programmer would write by hand, and what ICSML models declare explicitly);
    with ``reuse=True`` dead buffers' space is recycled — the paper's dataMem
    sharing, automated.  Both layouts satisfy ``validate()``.
    """
    shapes = graph.infer_shapes(input_shape)
    last_use = graph.last_use()
    pos = {uid: i for i, uid in enumerate(graph.schedule)}

    buffers: Dict[int, BufferInfo] = {}
    # Free-list of (offset, size) holes, plus a bump pointer at the end.
    allocated: List[BufferInfo] = []
    arena_end = 0

    for node in graph.nodes:
        uid = node.uid
        size = _align(max(1, math.prod(shapes[uid]) if shapes[uid] else 1), align)
        first = pos[uid]
        last = last_use[uid]

        offset = None
        if reuse:
            # First-fit: scan candidate offsets in increasing order, taking the
            # first gap not overlapping any buffer live during [first, last].
            live_now = sorted(
                (b for b in allocated if b.live[1] >= first),
                key=lambda b: b.offset,
            )
            cursor = 0
            for b in live_now:
                if b.offset - cursor >= size:
                    break
                cursor = max(cursor, b.end)
            offset = cursor
        else:
            offset = arena_end

        info = BufferInfo(uid=uid, offset=offset, size=size,
                          shape=shapes[uid], live=(first, last))
        buffers[uid] = info
        allocated.append(info)
        arena_end = max(arena_end, info.end)

    plan = MemoryPlan(arena_size=max(arena_end, align), buffers=buffers)
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# Traced arena accessors (planned execution)
# ---------------------------------------------------------------------------


def arena_write(arena: jax.Array, info: BufferInfo, value: jax.Array) -> jax.Array:
    """Store ``value`` (any shape) into its dataMem region of the flat arena."""
    flat = value.reshape(-1).astype(arena.dtype)
    padded = jnp.zeros((info.size,), arena.dtype).at[: flat.shape[0]].set(flat)
    return jax.lax.dynamic_update_slice(arena, padded, (info.offset,))


def arena_read(arena: jax.Array, info: BufferInfo) -> jax.Array:
    """Load a logical tensor back out of the arena using its metadata."""
    n = math.prod(info.shape) if info.shape else 1
    flat = jax.lax.dynamic_slice(arena, (info.offset,), (info.size,))
    return flat[:n].reshape(info.shape)


def activation_bytes(graph: Graph, input_shape: Sequence[int]) -> Dict[str, int]:
    """Memory accounting used by the §5.1 benchmark: naive vs planned arena."""
    naive = plan_memory(graph, input_shape, reuse=False)
    packed = plan_memory(graph, input_shape, reuse=True)
    return {"naive": naive.arena_bytes, "planned": packed.arena_bytes}
