"""Production mesh construction.

Target hardware: TPU v5e, 256 chips per pod (16×16), optionally 2 pods.
Axes: ``data`` (batch / ZeRO), ``model`` (tensor/expert/context parallel),
``pod`` (multi-pod data parallel outer axis).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    import numpy as np

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run launcher sets xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh() -> jax.sharding.Mesh:
    """A 1×1 mesh over the local device — smoke tests of the pjit path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None, *,
                    model_shards: int = 1) -> jax.sharding.Mesh:
    """The fleet-serving mesh: 1-D ``("data",)``, or 2-D ``("data",
    "model")`` when ``model_shards > 1``.

    ``StreamEngine`` partitions its per-stream ring arena over the ``data``
    axis so each device owns a contiguous shard of plants and runs the
    detector step on it locally (no cross-device traffic on the hot path).
    With ``model_shards=m`` the serving core additionally column-shards
    wide Dense layers over the ``model`` axis — each of the ``m`` ranks per
    data shard computes its own slice of the layer's output columns and one
    tiled ``all_gather`` recombines them (``serving/core.py``).

    ``n_devices`` is the **data-axis** width; it defaults to every visible
    device (divided by ``model_shards`` for a 2-D mesh).  The mesh takes a
    prefix of the device list, so 1/2/4-way meshes can coexist in one
    multi-device process (the sharded-parity tests rely on this).
    """
    devices = jax.devices()
    if model_shards < 1:
        raise RuntimeError(f"model_shards must be >= 1, got {model_shards}")
    if model_shards == 1:
        n = len(devices) if n_devices is None else n_devices
        if not 1 <= n <= len(devices):
            raise RuntimeError(
                f"fleet mesh needs 1..{len(devices)} devices, asked for {n}; "
                "set XLA_FLAGS=--xla_force_host_platform_device_count=<n> to "
                "fan out host devices")
        return jax.make_mesh((n,), ("data",), devices=devices[:n])
    n_data = (len(devices) // model_shards if n_devices is None
              else n_devices)
    need = n_data * model_shards
    if n_data < 1 or need > len(devices):
        raise RuntimeError(
            f"fleet mesh ({n_data}, {model_shards}) needs {need} devices "
            f"but only {len(devices)} present; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<n> to fan "
            "out host devices")
    return jax.make_mesh((n_data, model_shards), ("data", "model"),
                         devices=devices[:need])


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The batch-parallel axes for this mesh ('pod' folds into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# v5e hardware constants used by the roofline analysis (benchmarks/roofline).
PEAK_BF16_FLOPS = 197e12        # per chip
PEAK_INT8_OPS = 394e12          # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~)
HBM_BYTES = 16e9                # per chip
