"""Sharding rules: parameter, optimizer, batch, cache and activation layouts.

Scheme (DESIGN.md §5):

* **params** — tensor parallel on ``model``: qkv/up projections shard their
  output dim, o/down projections their input dim, embeddings the vocab dim;
  MoE experts shard the expert dim when divisible by the axis (else the FFN
  hidden dim); norms/scales replicate.  The stacked leading layer axis is
  never sharded.
* **optimizer state** — mirrors params (ZeRO-style falls out for free).
* **batch** — leading dim on ``("pod","data")`` (train) / ``("data",)``.
* **KV caches (decode)** — *context parallel*: the sequence axis shards on
  ``model`` (batch on ``data``); softmax/contraction collectives are inserted
  by GSPMD.  For long_500k (batch=1) the sequence shards on both axes.
* **SSM state** — heads on ``model``, batch on ``data``.
* **activations** — constrained batch-sharded between blocks; MoE dispatch
  tensors constrained expert-sharded (this materializes the all-to-all).

Rules are name-based over pytree paths, so every architecture family is
covered by one function.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import data_axes
from repro.models import common as cm


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _model_dim_ok(mesh: Mesh, size: int) -> bool:
    return size % _axis_size(mesh, "model") == 0


def param_spec(path: str, shape: Tuple[int, ...], cfg: ArchConfig,
               mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (path is '/'-joined keys)."""
    rank = len(shape)
    parts = path.split("/")
    stacked = any(p.endswith("blocks") for p in parts)
    # number of leading stacked axes (blocks/L; jamba sub-stacks add one more)
    lead = 0
    if stacked:
        lead = 1
        if any(k in path for k in ("mamba/", "mlp/", "moe/")) and cfg.family == "hybrid":
            lead = 2

    def pad(spec_tail: Tuple) -> P:
        return P(*((None,) * lead + tuple(spec_tail)))

    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    # ---- embeddings ----
    if name == "emb":
        return P("model", None)

    # ---- MoE experts (E, d, f) / (E, f, d); router replicated ----
    if parent == "ffn" or "/moe/" in path or path.endswith("router"):
        if name == "router":
            return pad((None, None))
        if name in ("w_gate", "w_up", "w_down") and rank - lead == 3:
            e = shape[lead]
            if e % _axis_size(mesh, "model") == 0:
                return pad(("model", None, None))
            # expert count not divisible: shard the FFN hidden dim instead
            if name == "w_down":
                return pad((None, "model", None))
            return pad((None, None, "model"))

    # ---- attention / mlp / mamba projections ----
    out_sharded = ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "fc1")
    in_sharded = ("wo", "w_down", "out_proj", "fc2")
    if name in ("w", "qw"):
        owner = parent
        if owner in out_sharded:
            return pad((None, "model"))
        if owner in in_sharded:
            return pad(("model", None))
    if name == "w_scale":
        owner = parent
        if owner in out_sharded:
            return pad(("model",))
        return pad((None,))
    if name == "b":
        owner = parent
        if owner in out_sharded and rank - lead == 1:
            return pad(("model",))
        return pad((None,) * (rank - lead))

    # ---- mamba conv/scalars, norms, everything else: replicated ----
    return P(*((None,) * rank))


def sanitize(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes from dims they do not divide (e.g. vocab 50280 on a
    16-way axis): explicit in_shardings require exact divisibility, and
    replicating an odd-sized embedding is cheaper than padding it."""
    out = []
    for d, ax in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([_axis_size(mesh, a) for a in axes]))
        out.append(ax if shape[d] % size == 0 else None)
    return P(*out)


def param_shardings(specs: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """Map a pytree of ShapeDtypeStructs to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    out = []
    for path, leaf in flat:
        spec = param_spec(_path_str(path), tuple(leaf.shape), cfg, mesh)
        out.append(NamedSharding(mesh, sanitize(spec, tuple(leaf.shape), mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(opt_specs: Any, p_shardings: Any, mesh: Mesh) -> Any:
    """Optimizer state mirrors parameters; scalars replicate.

    OptState = (step, mu, nu) with mu/nu shaped like params (f32) except
    non-trainable leaves collapse to scalars."""
    replicated = NamedSharding(mesh, P())

    def match(moment_specs):
        flat_p = jax.tree.leaves(p_shardings)
        flat_m, treedef = jax.tree.flatten(moment_specs)
        out = []
        for ps, ms in zip(flat_p, flat_m):
            out.append(ps if ms.ndim > 0 else replicated)
        return jax.tree_util.tree_unflatten(treedef, out)

    import repro.optim as optim
    return optim.OptState(step=replicated, mu=match(opt_specs.mu),
                          nu=match(opt_specs.nu))


def batch_shardings(batch_specs: Dict[str, Any], mesh: Mesh,
                    *, batch_size: int) -> Dict[str, Any]:
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    lead = dp if batch_size % dp_size == 0 else (
        ("data",) if batch_size % _axis_size(mesh, "data") == 0 else None)
    out = {}
    for k, s in batch_specs.items():
        spec = sanitize(P(lead, *([None] * (len(s.shape) - 1))), s.shape, mesh)
        out[k] = NamedSharding(mesh, spec)
    return out


def cache_shardings(cache_specs: Any, cfg: ArchConfig, mesh: Mesh,
                    *, batch_size: int) -> Any:
    """Decode-cache layout (context parallel; see module docstring)."""
    data_ok = batch_size % _axis_size(mesh, "data") == 0
    b_ax = "data" if data_ok else None
    # sequence axis sharding: model always; fold data in when batch can't use it
    s_ax = "model" if data_ok else ("data", "model")
    dp_all = data_axes(mesh)

    def spec_for(path, leaf) -> P:
        name = _path_str(path)
        shape = leaf.shape
        if name.endswith("ssm"):         # (L, [n_mamba,] B, H, P, N)
            lead = len(shape) - 4
            return P(*((None,) * lead), b_ax, "model", None, None)
        if name.endswith("conv"):        # (L, [n_mamba,] B, K-1, C)
            lead = len(shape) - 3
            return P(*((None,) * lead), b_ax, None, "model")
        if name.endswith(("xk", "xv")):  # whisper cross KV: (L, B, F, K, D)
            return P(None, b_ax, None, None, None)
        if name.endswith(("k_scale", "v_scale")):  # int8 KV scales (L,B,S,K)
            return P(None, b_ax, s_ax, None)
        # attention KV: (L, B, S, K, D)
        return P(None, b_ax, s_ax, None, None)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    out = [NamedSharding(mesh, sanitize(spec_for(p, l), tuple(l.shape), mesh))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activation constraints (installed as the models' constrain hook)
# ---------------------------------------------------------------------------


def activation_hook(mesh: Mesh, *, batch_sharded: bool,
                    seq_parallel: bool = False):
    dp = data_axes(mesh)

    def hook(x: jax.Array, name: str) -> jax.Array:
        if name == "btd" and x.ndim == 3 and batch_sharded:
            # Megatron-style sequence parallelism: between blocks the
            # activation also shards its sequence dim on "model", turning the
            # per-block TP all-reduce into reduce-scatter + all-gather.
            seq_ax = "model" if (seq_parallel and x.shape[1] %
                                 _axis_size(mesh, "model") == 0) else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, seq_ax, None)))
        if name == "expert_in" and x.ndim == 4:
            e = x.shape[1]
            if e % _axis_size(mesh, "model") == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp if batch_sharded else None,
                                             "model", None, None)))
        return x

    return hook


def install_hook(mesh: Optional[Mesh], *, batch_sharded: bool = True,
                 seq_parallel: bool = False) -> None:
    if mesh is None:
        cm.set_constrain_hook(None)
    else:
        cm.set_constrain_hook(activation_hook(
            mesh, batch_sharded=batch_sharded, seq_parallel=seq_parallel))
