"""Serving launcher: batched requests against any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --reduced \\
      --requests 4 --prompt-len 16 --max-new 32 [--quant SINT] [--cyclic 4]

``--quant`` serves with the paper's int8/int16/int32 quantized linears
(§6.1); ``--cyclic N`` decodes multipart, N layer-segments per token (§6.3).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models.api import get_model
from repro.serving import CyclicDecoder, Engine, Request


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", choices=("SINT", "INT", "DINT"))
    ap.add_argument("--cyclic", type=int, default=0,
                    help="decode multipart with N segments per token")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant:
        cfg = cfg.with_(quant=args.quant)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))

    extras = {}
    if cfg.family == "vlm":
        extras["image_emb"] = jnp.zeros(
            (args.batch_slots, cfg.num_image_tokens, 1152), cfg.dtype)
    elif cfg.family == "audio":
        extras["frames"] = jnp.zeros(
            (args.batch_slots, cfg.encoder_frames, cfg.d_model), cfg.dtype)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new,
                temperature=args.temperature)
        for i in range(args.requests)
    ]

    if args.cyclic and cfg.family in ("dense", "moe", "vlm", "ssm"):
        batch = {"tokens": jnp.asarray(reqs[0].prompt[None]), **{
            k: v[:1] for k, v in extras.items()}}
        cache, logits = api.prefill(params, batch, args.cache_len)
        first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        cd = CyclicDecoder(cfg, params, n_segments=args.cyclic, batch=1,
                           cache_len=args.cache_len)
        t0 = time.time()
        toks, _, stats = cd.decode_tokens(cache, first, args.prompt_len,
                                          args.max_new)
        dt = time.time() - t0
        ct = np.asarray(stats.cycle_times_s)
        print(f"cyclic decode: {len(toks)} tokens in {dt:.2f}s, "
              f"{stats.cycles_per_token} cycles/token, "
              f"cycle p50={np.percentile(ct, 50)*1e3:.1f}ms "
              f"p99={np.percentile(ct, 99)*1e3:.1f}ms")
        print("tokens:", toks)
        return

    engine = Engine(api, params, batch_slots=args.batch_slots,
                    cache_len=args.cache_len, extras=extras)
    done = engine.serve(reqs)
    for c in done:
        print(f"req {c.uid}: prefill {c.prefill_s*1e3:.1f}ms, "
              f"{c.tokens_per_s:.1f} tok/s -> {c.tokens[:16].tolist()}...")


if __name__ == "__main__":
    main()
