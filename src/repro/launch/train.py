"""Training launcher: end-to-end distributed training of any assigned
architecture (reduced or full) on whatever mesh the host provides.

Examples (CPU container — reduced configs):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --reduced \\
      --steps 20 --batch 8 --seq 128

On a real v5e pod the same entry point runs the full config on the
(16,16) production mesh (``--production-mesh``).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs.base import ARCH_IDS, get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch import shardings as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.api import get_model


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)

    mesh = (make_production_mesh() if args.production_mesh else make_host_mesh())
    sh.install_hook(mesh, batch_sharded=True)

    params = api.init(jax.random.PRNGKey(args.seed))
    opt_init, opt_update = make_optimizer(args.lr, args.warmup, args.steps)
    opt_state = opt_init(params)

    p_shard = sh.param_shardings(api.param_specs(), cfg, mesh)
    params = jax.device_put(params, p_shard)

    step_fn = jax.jit(make_train_step(api, opt_update), donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))
    stream = data.batches()

    if args.ckpt_dir:
        import os
        os.makedirs(args.ckpt_dir, exist_ok=True)

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        host_batch = next(stream)
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        if cfg.family == "vlm":
            b, s = batch["tokens"].shape
            text = max(s - cfg.num_image_tokens, 1)
            batch = {
                "tokens": batch["tokens"][:, :text],
                "labels": batch["labels"][:, :text],
                "image_emb": jnp.zeros((b, cfg.num_image_tokens, 1152), cfg.dtype),
            }
        elif cfg.family == "audio":
            b = batch["tokens"].shape[0]
            batch["frames"] = jnp.zeros((b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt / (step + 1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, {"params": params})

    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"{(time.time() - t0):.1f}s total")
    sh.install_hook(None)


if __name__ == "__main__":
    main()
