import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract the roofline inputs.

For each case this:
  1. builds the (16,16) single-pod or (2,16,16) multi-pod mesh,
  2. constructs parameter/optimizer/batch/cache ShapeDtypeStructs (zero
     allocation — weights never materialize),
  3. jits the train/prefill/decode step with explicit in/out shardings,
  4. ``.lower(...).compile()`` — success proves the distribution config is
     coherent (sharding divisibility, collective legality, layout),
  5. records ``memory_analysis()``, ``cost_analysis()`` and the collective
     traffic parsed from the post-SPMD optimized HLO into a JSON blob under
     ``experiments/dryrun/`` for benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quant SINT]
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, ArchConfig, get_config
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_optimizer, make_prefill, make_train_step
from repro.models.api import get_model

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# bytes-on-wire multiplier per collective (ring algorithms; documented
# approximation — see EXPERIMENTS.md §Dry-run)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(pred|[sbuf]\d+|bf16)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum output bytes of every collective op in optimized (post-SPMD) HLO."""
    per_op: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        rhs = rhs.strip()
        matched = None
        for c in _COLLECTIVES:
            # opcode appears right after the output shape(s)
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                matched = c
                break
        if matched is None:
            continue
        if f"{matched}-done(" in rhs:
            continue  # counted at -start
        # output shape(s): everything before the opcode token
        head = rhs.split(matched)[0]
        shapes = _SHAPE_RE.findall(head)
        nbytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        per_op[matched] += nbytes
        counts[matched] += 1
    wire = sum(per_op[c] * _WIRE_FACTOR[c] for c in _COLLECTIVES)
    return {"bytes_by_type": per_op, "counts": counts, "wire_bytes": wire}


def _spec_tree_bytes(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def effective_config(arch: str, shape: str, quant: Optional[str] = None,
                     unroll: bool = False,
                     n_layers: Optional[int] = None,
                     overrides: Optional[dict] = None) -> ArchConfig:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    if n_layers is not None:
        cfg = cfg.with_(n_layers=n_layers)
    if unroll:
        # Full unroll of the layer scan: XLA's cost analysis counts a while
        # body once, so honest FLOP/byte/collective totals need the layers in
        # the HLO.  Compile cost is higher; used by the roofline runs.
        n_stacked = cfg.n_layers // (cfg.attn_period or 1) if cfg.family == "hybrid" else cfg.n_layers
        cfg = cfg.with_(scan_unroll=max(n_stacked, 1))
    shp = INPUT_SHAPES[shape]
    if shape == "long_500k" and shp["kind"] == "decode":
        # sub-quadratic requirement: full-attention archs get the SWA variant
        if cfg.family in ("dense", "moe", "vlm", "audio") and cfg.sliding_window is None:
            cfg = cfg.with_(sliding_window=cfg.swa_for_long,
                            notes=cfg.notes + " [long_500k: SWA substituted]")
    if quant:
        cfg = cfg.with_(quant=quant)
    return cfg


def build_case(arch: str, shape: str, mesh, quant: Optional[str] = None,
               unroll: bool = False, n_layers: Optional[int] = None,
               overrides: Optional[dict] = None):
    """Returns (jitted_fn, arg_specs, meta) ready to lower."""
    cfg = effective_config(arch, shape, quant, unroll, n_layers, overrides)
    api = get_model(cfg)
    shp = INPUT_SHAPES[shape]
    batch, seq = shp["global_batch"], shp["seq_len"]
    kind = shp["kind"]

    sh.install_hook(mesh, batch_sharded=(kind != "decode" or batch > 1),
                    seq_parallel=cfg.seq_parallel)
    p_specs = api.param_specs()
    p_shard = sh.param_shardings(p_specs, cfg, mesh)
    b_specs = api.batch_specs(kind, batch, seq)
    b_shard = sh.batch_shardings(b_specs, mesh, batch_size=batch)

    meta = {
        "arch": arch, "shape": shape, "kind": kind,
        "global_batch": batch, "seq_len": seq,
        "param_bytes": _spec_tree_bytes(p_specs),
        "quant": quant,
    }

    if kind == "train":
        opt_init, opt_update = make_optimizer()
        o_specs = jax.eval_shape(opt_init, p_specs)
        o_shard = sh.opt_shardings(o_specs, p_shard, mesh)
        step = make_train_step(api, opt_update)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (p_specs, o_specs, b_specs)
        meta["opt_bytes"] = _spec_tree_bytes(o_specs)
    elif kind == "prefill":
        step = make_prefill(api, cache_len=seq)
        c_specs = api.cache_specs(batch, seq)
        c_shard = sh.cache_shardings(c_specs, cfg, mesh, batch_size=batch)
        fn = jax.jit(step, in_shardings=(p_shard, b_shard),
                     out_shardings=(c_shard, None))
        args = (p_specs, b_specs)
        meta["cache_bytes"] = _spec_tree_bytes(c_specs)
    else:  # decode
        step = make_decode_step(api)
        c_specs = api.cache_specs(batch, seq)
        c_shard = sh.cache_shardings(c_specs, cfg, mesh, batch_size=batch)
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, b_shard, sh.NamedSharding(mesh, sh.P())),
            out_shardings=(c_shard, None),
            donate_argnums=(1,),
        )
        args = (p_specs, c_specs, b_specs, pos_spec)
        meta["cache_bytes"] = _spec_tree_bytes(c_specs)

    return fn, args, meta


def _compile_case(arch: str, shape: str, mesh, quant, unroll, n_layers=None,
                  overrides=None):
    t0 = time.time()
    fn, args, meta = build_case(arch, shape, mesh, quant, unroll, n_layers,
                                overrides)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, meta, t_lower, t_compile


# models small enough to compile fully unrolled; everything bigger uses the
# L=1 / L=2 extrapolation (total = outer + L*body, body = c2 - c1).
_FULL_UNROLL_BYTES = 10e9


def _case_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "wire_bytes": float(coll["wire_bytes"])}
    for c in _COLLECTIVES:
        out[f"coll_{c}"] = float(coll["bytes_by_type"][c] * _WIRE_FACTOR[c])
    return out


def run_case(arch: str, shape: str, *, multi_pod: bool = False,
             quant: Optional[str] = None, save: bool = True,
             unroll: bool = False, costs: bool = False,
             overrides: Optional[dict] = None,
             tag: Optional[str] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    fn, args, meta = build_case(arch, shape, mesh, quant, unroll,
                                overrides=overrides)
    meta["variant"] = tag
    meta["unrolled"] = unroll
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # some backends lack memory_analysis
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    result = {
        **meta,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "hlo_flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float)) and not k.startswith("utilization")},
        "memory_analysis": mem_d,
        "collectives": coll,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }

    if costs:
        # Honest per-device totals: XLA counts a while(scan) body once, so we
        # recover total = outer + L*body from two small unrolled compiles
        # (L=1, L=2) at full width on the same mesh, or one fully unrolled
        # compile when the model is small enough.
        cfg0 = effective_config(arch, shape, quant, overrides=overrides)
        period = cfg0.attn_period if cfg0.family == "hybrid" else 1
        n_stack = cfg0.n_layers // max(period, 1)
        if meta["param_bytes"] < _FULL_UNROLL_BYTES or n_stack <= 2:
            cu, _, _, tcu = _compile_case(arch, shape, mesh, quant, True,
                                          overrides=overrides)
            result["cost_totals"] = {**_case_costs(cu), "method": "full_unroll",
                                     "compile_s": round(tcu, 2)}
        else:
            c1, _, _, t1 = _compile_case(arch, shape, mesh, quant, True,
                                         n_layers=1 * period, overrides=overrides)
            c2, _, _, t2 = _compile_case(arch, shape, mesh, quant, True,
                                         n_layers=2 * period, overrides=overrides)
            a, b = _case_costs(c1), _case_costs(c2)
            tot = {}
            for k in a:
                body = b[k] - a[k]
                tot[k] = a[k] + (n_stack - 1) * max(body, 0.0)
            result["cost_totals"] = {**tot, "method": "extrapolate_1_2",
                                     "compile_s": round(t1 + t2, 2)}
    sh.install_hook(None)

    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fname = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
        if quant:
            fname += f"__{quant}"
        if unroll:
            fname += "__unrolled"
        if tag:
            fname += f"__{tag}"
        with open(os.path.join(OUT_DIR, fname + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", choices=("SINT", "INT", "DINT"))
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll the layer scan (accurate cost totals)")
    ap.add_argument("--costs", action="store_true",
                    help="also derive honest cost totals (extra compiles)")
    args = ap.parse_args()

    cases = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cases.append((a, s, m))

    failures = 0
    for a, s, m in cases:
        tag = f"{a:24s} {s:12s} {'2x16x16' if m else '16x16 '}"
        try:
            r = run_case(a, s, multi_pod=m, quant=args.quant,
                         unroll=args.unroll, costs=args.costs)
            print(f"OK   {tag} flops={r['hlo_flops']:.3e} "
                  f"bytes={r['hlo_bytes']:.3e} "
                  f"coll={r['collectives']['wire_bytes']:.3e} "
                  f"compile={r['compile_s']}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"FAIL {tag} {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures}/{len(cases)} dry-run cases failed")
    print(f"all {len(cases)} dry-run cases compiled")


if __name__ == "__main__":
    main()
