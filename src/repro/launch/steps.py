"""Jitted train / serve steps with explicit shardings.

``make_train_step``/``make_prefill``/``make_decode_step`` return functions
ready for ``jax.jit(..., in_shardings=..., out_shardings=...)``; the dry-run
lowers them against ShapeDtypeStruct stand-ins and the real launchers execute
them.  Buffers that must never be duplicated (optimizer state, KV caches) are
donated — the ICSML static-memory discipline at cluster scale.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import ModelAPI
from repro.optim import adamw, apply_updates, global_norm, linear_warmup_cosine


def make_optimizer(lr: float = 3e-4, warmup: int = 100, steps: int = 10_000):
    return adamw(linear_warmup_cosine(lr, warmup, steps))


def make_train_step(api: ModelAPI, opt_update) -> Callable:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(api.loss)(params, batch)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return params, opt_state, metrics

    return train_step


def make_prefill(api: ModelAPI, cache_len: int) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(params, batch, cache_len)

    return prefill_step


def make_decode_step(api: ModelAPI) -> Callable:
    def decode_step(params, cache, batch, pos):
        return api.decode(params, cache, batch, pos)

    return decode_step
