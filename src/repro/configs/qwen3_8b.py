"""qwen3-8b [dense]: qk-norm, GQA. [hf:Qwen/Qwen3-8B]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    mlp_kind="swiglu",
    bias=False,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
