from repro.configs.base import ARCH_IDS, INPUT_SHAPES, ArchConfig, all_configs, get_config

__all__ = ["ARCH_IDS", "INPUT_SHAPES", "ArchConfig", "all_configs", "get_config"]
