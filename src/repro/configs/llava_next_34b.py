"""llava-next-34b [vlm]: anyres tiling VLM; language backbone below, vision
encoder + projector stubbed (input_specs provides patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf scaled per assignment]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    mlp_kind="swiglu",
    bias=False,
    rope_theta=1_000_000.0,
    # anyres tiling: base 576 tokens + 4 tiles x 576 = 2880 image tokens
    num_image_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    notes="56 q-heads are not divisible by the 16-way model axis; GSPMD pads "
          "head sharding to 64 (waste recorded in EXPERIMENTS.md).",
)
