"""Architecture configuration schema + registry.

One ``configs/<arch>.py`` per assigned architecture defines ``CONFIG`` with the
exact assigned dimensions (source cited), plus the paper's own models
(``icsml_mlp``, ``msf_detector``).  ``reduced()`` derives the smoke-test
variant (≤2 layers, d_model ≤ 512, ≤4 experts) exercised on CPU.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional, Tuple

import jax.numpy as jnp

ARCH_IDS = (
    "llava_next_34b",
    "mamba2_370m",
    "whisper_base",
    "granite_moe_1b_a400m",
    "command_r_35b",
    "jamba_1_5_large_398b",
    "nemotron_4_340b",
    "qwen3_8b",
    "command_r_plus_104b",
    "mixtral_8x22b",
)

# Input shapes assigned to this paper (global batch, sequence length).
INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""
    # attention features
    qk_norm: bool = False
    mlp_kind: str = "swiglu"         # swiglu | gelu | squared_relu
    bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # native SWA (mixtral)
    swa_for_long: int = 4096         # window substituted on long_500k for
                                     # full-attention archs (DESIGN.md §4)
    parallel_block: bool = False     # command-r residual style
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / jamba mamba layers)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    conv_kernel: int = 4
    # hybrid (jamba): one attention layer per `attn_period` mixer layers
    attn_period: int = 0
    # modality stubs
    num_image_tokens: int = 0        # vlm: anyres patch-embedding prefix
    encoder_frames: int = 0          # audio: encoder sequence length
    # execution policy (the ICSML levers)
    dtype: Any = jnp.bfloat16
    quant: Optional[str] = None      # None | SINT | INT | DINT (serving)
    kv_quant: bool = False           # int8 KV cache (§6.1 applied to state)
    remat: str = "layer"             # layer | none — train remat policy
    scan_unroll: int = 1             # lax.scan unroll for the layer stack
    d_head_override: Optional[int] = None  # pad heads to mesh-divisible count
    seq_parallel: bool = False       # Megatron-SP activation sharding
    moe_group: int = 512             # tokens per MoE dispatch group
    moe_dispatch_dtype: str = "float32"    # dispatch einsum precision
    notes: str = ""

    @property
    def d_head(self) -> int:
        if self.d_head_override:
            return self.d_head_override
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        kw = dict(
            n_layers=2 if self.family != "hybrid" else max(self.attn_period, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 1024),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_headdim=min(self.ssm_headdim, 32) if self.ssm_headdim else 0,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            encoder_frames=min(self.encoder_frames, 32) if self.encoder_frames else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            swa_for_long=64,
        )
        return self.with_(**kw)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
