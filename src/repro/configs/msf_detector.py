"""The §7 case-study detector workloads: the paper's densely connected
classifier (400 inputs = 2 features x 10 readings/s x 20 s, hidden ReLU
layers, 2-class head) plus the unsupervised autoencoder variant — and the
serving-side constants for the fleet detection service
(`repro.serving.streams.StreamEngine` / `examples/detect_fleet.py`)."""

INPUT_SIZE = 400
HIDDEN = (64, 32, 16)
CLASSES = 2

# Unsupervised reconstruction detector: 400-64-16-64-400 autoencoder trained
# on benign windows only (MSE), anomaly score = per-window reconstruction
# error.  The verdict threshold is calibrated to AE_TARGET_FPR false
# positives on held-out normal traces (sim.detector.train_autoencoder).
AE_HIDDEN = (64, 16, 64)
AE_TARGET_FPR = 0.01

# One-class margin detector (Deep-SVDD-style): the §7 trunk embedding
# windows into MARGIN_EMBED dims; anomaly score = squared distance from the
# benign center, threshold = FPR-calibrated margin radius.
MARGIN_EMBED = 16

# Next-step-prediction detector: (WINDOW - 1) readings in, one reading out
# (the ForecastHead asks the serving ring for the extra target reading).
FORECAST_HIDDEN = (64, 32)
WINDOW_SECONDS = 20
READINGS_PER_SECOND = 10
N_FEATURES = 2
SCAN_CYCLE_MS = 100

# Sliding-window featurization (shared by build_dataset and StreamEngine):
# window length in scan cycles and the verdict stride between windows.
WINDOW = WINDOW_SECONDS * READINGS_PER_SECOND   # 200 readings -> 400 features
STRIDE = 10

# PLC-side normalization around the nominal operating point — baked into data
# collection by the paper's porting flow, so serving must apply the identical
# transform: (reading - NORM_MEAN) / NORM_STD per feature (TB0, Wd).
NORM_MEAN = (89.6, 19.18)
NORM_STD = (2.0, 0.5)

# Fleet serving defaults: verdicts must land within one scan cycle of the
# window completing (the §7 real-time budget), across this many plants.
DEADLINE_S = SCAN_CYCLE_MS / 1000.0
FLEET_STREAMS = 16

# Stream-axis sharding: per-device shard of the fleet arena used by the
# device-scaling benchmark rows (a d-device mesh serves d x this many
# plants; benchmarks/detection_bench.py --shard-worker).
STREAMS_PER_DEVICE = 128
