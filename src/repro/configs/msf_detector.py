"""The §7 case-study model: densely connected classifier with 400 inputs
(2 features x 10 readings/s x 20 s) and 4 hidden ReLU layers."""

INPUT_SIZE = 400
HIDDEN = (64, 32, 16)
CLASSES = 2
WINDOW_SECONDS = 20
READINGS_PER_SECOND = 10
N_FEATURES = 2
SCAN_CYCLE_MS = 100
