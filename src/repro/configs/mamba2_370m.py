"""mamba2-370m [ssm]: attention-free SSD (state-space duality).
[arXiv:2405.21060]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                 # attention-free, no separate FFN (mamba2 block)
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,         # d_inner=2048 -> 32 SSD heads
    ssm_groups=1,
    conv_kernel=4,
    source="arXiv:2405.21060",
)
