"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e
top-2. [arXiv:2403.19887]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,            # 9 super-blocks of 8 (1 attention : 7 mamba)
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    mlp_kind="swiglu",
    bias=False,
    n_experts=16,
    top_k=2,
    attn_period=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=128,        # d_inner=16384 -> 128 SSD heads
    ssm_groups=8,
    conv_kernel=4,
    source="arXiv:2403.19887",
)
