"""The paper's own benchmark model family (§5.2): 64-in/64-out dense stacks
with ReLU, plus the §6 quantization/pruning 512x512 layer."""

BENCH_FEATURES = 64          # §5.2 layer-stacking benchmark width
QUANT_LAYER = (512, 512)     # §6.1 isolated hidden layer (Table 2, Fig. 5)
PRUNE_LAYER = (784, 512)     # §6.2 pruning experiments
