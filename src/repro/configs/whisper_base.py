"""whisper-base [audio]: encoder-decoder; mel+conv frontend stubbed
(input_specs provides 1500 frame embeddings). [arXiv:2212.04356]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,             # 6 encoder + 6 decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    mlp_kind="gelu",
    bias=True,
    encoder_frames=1500,
    source="arXiv:2212.04356",
)
